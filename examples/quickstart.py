"""Quickstart: the TailBench++ harness in 40 lines.

Simulates the paper's headline scenario — dynamic clients against a
persistent multi-server deployment — and prints per-client tail latency.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.client import ClientConfig, ConstantQPS, PiecewiseQPS
from repro.core.harness import Experiment, ServerSpec, run

# Three independent clients (Feature 3): different start times, budgets,
# and load shapes (Feature 4).  The server pool persists throughout
# (Features 1+2) behind a load-aware balancer.
clients = [
    ClientConfig(1, ConstantQPS(300), start_time=0.0, total_requests=4000),
    ClientConfig(2, PiecewiseQPS([(0, 100), (10, 500), (20, 100)]),
                 start_time=5.0),
    ClientConfig(3, ConstantQPS(200), start_time=12.0, total_requests=2000),
]

exp = Experiment(
    clients=clients,
    servers=(ServerSpec(0, workers=2), ServerSpec(1, workers=2)),
    app="xapian",                      # one of the 8 TailBench apps
    policy="load_aware",               # paper Fig. 8's better policy
    duration=30.0,
    seed=42,
)

sim = run(exp)
print(f"total requests: {sim.recorder.overall().n}   dropped: {sim.dropped}")
for cid in sim.recorder.clients():
    s = sim.recorder.client(cid)
    print(f"client {cid}: n={s.n:6d}  mean={s.mean*1e3:7.2f}ms  "
          f"p95={s.p95*1e3:7.2f}ms  p99={s.p99*1e3:7.2f}ms")
for sid, srv in sim.servers.items():
    print(f"server {sid}: served={srv.total_served}  "
          f"busy={srv.busy_time:.1f}s")
