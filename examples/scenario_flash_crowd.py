"""Define-your-own-scenario recipe: a flash crowd, twice over.

Builds a custom flash-crowd ``Scenario`` from declarative events, runs it
on BOTH runtime backends — the virtual-time simulator and the wall-clock
``EngineRuntime`` over profile-timed ``StubEngine`` replicas — and prints
the per-interval telemetry side by side.  The same compiled scenario
drives both; only the execution substrate differs.

    PYTHONPATH=src python examples/scenario_flash_crowd.py
"""
from repro.core.harness import ServerSpec
from repro.core.runtime import EngineRuntime, VirtualClock, run_scenario
from repro.core.scenario import ClientArrival, FlashCrowd, Scenario
from repro.scenarios.backends import build_stub_engines

# 1. Declare the scenario: steady 600 QPS, then a 12s viral spike that
#    triples the offered load (an SLO of 25ms makes violations visible).
sc = Scenario(
    name="my-flash-crowd",
    duration=40.0,
    servers=(ServerSpec(0, workers=2), ServerSpec(1, workers=2)),
    events=[
        ClientArrival(0.0, qps=200.0, count=3),          # the base tenants
        FlashCrowd(at=14.0, duration=12.0, peak_qps=1500.0, clients=6),
    ],
    app="xapian",
    policy="jsq",
    slo=0.025,
    seed=42,
)

# 2. Virtual-time backend: deterministic, instant.
sim_rt = run_scenario(sc, "sim")

# 3. Wall-clock backend: same compiled scenario against StubEngine
#    replicas on an accelerated virtual clock (build_stub_engines gives
#    one profile-timed stub per initial server, plus a join factory).
exp = sc.compile()
clock = VirtualClock()
engines, factory = build_stub_engines(exp, clock, seed=42)
eng_rt = EngineRuntime.from_experiment(exp, engines, engine_factory=factory,
                                       clock=clock, sleep=clock.sleep)
eng_rt.run()

print(f"{'t':>3} | {'sim n':>6} {'sim p99':>9} {'viol':>5} | "
      f"{'eng n':>6} {'eng p99':>9} {'viol':>5}")
eng_frames = {f.t: f for f in eng_rt.telemetry.frames()}
for f in sim_rt.telemetry.frames():
    g = eng_frames.get(f.t)
    gcol = (f"{g.n:6d} {g.p99*1e3:8.2f}ms {g.slo_violation_frac:5.2f}"
            if g else " " * 22)
    print(f"{f.t:3d} | {f.n:6d} {f.p99*1e3:8.2f}ms {f.slo_violation_frac:5.2f}"
          f" | {gcol}")

s1, s2 = sim_rt.telemetry.overall(), eng_rt.telemetry.overall()
print(f"\nsim:    n={s1.n}  p99={s1.p99*1e3:.2f}ms")
print(f"engine: n={s2.n}  p99={s2.p99*1e3:.2f}ms")
assert s1.n > 0 and s2.n > 0
