"""Train a ~100M-param LM for a few hundred steps with checkpoint/restart.

Uses the mamba2 family at width 512 (a real reduced config, ~100M params)
on the synthetic Zipf stream; kills itself at step 60 and resumes from the
checkpoint to demonstrate fault tolerance.

    PYTHONPATH=src python examples/train_lm.py
"""
import dataclasses
import os
import shutil
import tempfile

from repro.configs.base import get_config
from repro.launch import train

CKPT = os.path.join(tempfile.gettempdir(), "repro_train_lm_ckpt")
shutil.rmtree(CKPT, ignore_errors=True)

ARGS = ["--arch", "stablelm-3b", "--smoke", "--batch", "8", "--seq", "128",
        "--lr", "1e-3", "--ckpt-dir", CKPT, "--ckpt-every", "30",
        "--log-every", "20"]

print("=== phase 1: train to step 60, checkpointing every 30 ===")
train.main(ARGS + ["--steps", "60"])

print("=== phase 2: 'crash' and resume from the latest checkpoint ===")
loss = train.main(ARGS + ["--steps", "200", "--resume"])
print(f"final loss {loss:.4f}")
assert loss < 7.0
