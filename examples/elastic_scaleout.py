"""Elastic scale-out + straggler mitigation under a diurnal load.

A diurnal (sinusoidal) aggregate load runs against 2 servers; a third
joins at the peak and drains afterwards.  Hedged requests cap the tail
during the transition.  Reports per-interval p99 across the day.

    PYTHONPATH=src python examples/elastic_scaleout.py
"""
from repro.core.client import ClientConfig, DiurnalQPS
from repro.core.harness import Experiment, ServerSpec, run

clients = [ClientConfig(i, DiurnalQPS(base=250, amplitude=200, period=40),
                        seed=i) for i in range(3)]
servers = (ServerSpec(0, workers=2, service_noise=0.5),
           ServerSpec(1, workers=2, service_noise=0.5),
           ServerSpec(2, workers=2, service_noise=0.5, join_at=15.0,
                      drain_at=35.0))
exp = Experiment(clients=clients, servers=servers, app="xapian",
                 policy="jsq", hedge_delay=0.02, duration=45.0, seed=7)
sim = run(exp)
print("t(s)  n      p99(ms)")
for ivl, s in sim.recorder.intervals().items():
    bar = "#" * int(min(s.p99 * 2e3, 60))
    print(f"{ivl:4d} {s.n:6d} {s.p99*1e3:8.2f} {bar}")
print(f"\nserver 2 (elastic) served {sim.servers[2].total_served} requests "
      f"between t=15s and t=35s")
assert sim.servers[2].total_served > 0
