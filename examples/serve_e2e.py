"""End-to-end driver: serve a real JAX model with batched requests.

Two InferenceEngine replicas run a reduced phi3 config; TailBench++
open-loop clients drive them in wall-clock time through a JSQ balancer.
This is the paper's client->LVS->server data flow (Fig. 3) with real
model inference as the service.

    PYTHONPATH=src python examples/serve_e2e.py
"""
import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.client import ClientConfig, ConstantQPS
from repro.core.runtime import EngineRuntime
from repro.models import registry as R
from repro.serving.engine import InferenceEngine

ARCH = "phi3-mini-3.8b-smoke"

cfg = get_config(ARCH)
params = R.init_params(cfg, jax.random.PRNGKey(0))
engines = [InferenceEngine(cfg, params, max_batch=4, max_len=64)
           for _ in range(2)]

print("warming compile caches...")
for e in engines:
    e.submit(np.arange(16), 2, -1)
    e.run_until_idle()

clients = [ClientConfig(0, ConstantQPS(15), end_time=4.0, seed=0),
           ClientConfig(1, ConstantQPS(15), end_time=4.0, seed=1)]
print("serving 4s of open-loop traffic at 30 QPS across 2 replicas...")
rt = EngineRuntime(engines, clients, policy="jsq", duration=4.0,
                   prompt_len=16, max_new_tokens=4, vocab=cfg.vocab_size)
rt.run()
s = rt.telemetry.overall()
print(f"served n={s.n}  mean={s.mean*1e3:.1f}ms  p50={s.p50*1e3:.1f}ms  "
      f"p95={s.p95*1e3:.1f}ms  p99={s.p99*1e3:.1f}ms")
for i, e in enumerate(engines):
    print(f"replica {i}: prefills={e.prefill_count} decode_steps={e.decode_steps}")
assert s.n > 0
