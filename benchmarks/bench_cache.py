"""Result-cache benchmark: warm re-run speedup, bit-identity, planner
cell reuse, and the pipelined chunk path.

Three measurements, one committed record (``BENCH_cache.json``):

1. **Warm fig1-grid re-run** — the paper's Fig. 1 sweep shape (the
   same grid ``bench_vector`` times) run three ways: uncached, cold
   through a fresh cache directory (compute + store overhead), and
   warm (every row served from disk).  The headline gate is the warm
   speedup over the cold run, with every row required bit-identical
   across all three — the cache may only change how fast an answer
   arrives, never which answer arrives.

2. **Planner cell reuse** — ``bench_plan``'s dense provisioning grid
   populates a cache; ``run_plan`` on the same question with that
   cache must then spend almost nothing: ``cell_evals`` counts only
   cells the cache could not serve (gate: <= 5).  Both sides share one
   SeedSequence spawn tree, so key sharing is by construction, not
   coincidence.

3. **Pipelined chunk execution** — the jax warm path with chunks
   double-buffered (device scan of chunk k+1 overlapping host
   finishing of chunk k) vs the strictly serial launch-then-finish
   order, forced into several chunks via ``max_slot_elems``.  Gate:
   pipelining is never slower than 1.10x the sync path and the rows
   are identical.

Usage:
    PYTHONPATH=src python benchmarks/bench_cache.py             # full
    PYTHONPATH=src python benchmarks/bench_cache.py --smoke --check
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "src"))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from benchmarks._record import write_record  # noqa: E402
from benchmarks.bench_vector import build_grid  # noqa: E402
from repro.cache import ResultCache  # noqa: E402
from repro.plan import PlanSpec, run_plan  # noqa: E402
from repro.scenarios import get  # noqa: E402
from repro.sweep import run_sweep  # noqa: E402
from repro.sweep.spec import spawn_seed  # noqa: E402
from repro.vector import (VectorConfig, compile_experiment,  # noqa: E402
                          has_jax, run_cells)

#: warm-over-cold floor: full scale pays real compute cold; smoke
#: grids are small enough that fixed costs compress the ratio
MIN_WARM_SPEEDUP = {"full": 10.0, "smoke": 3.0}
MIN_HIT_FRAC = 0.9
MAX_PLANNER_CELLS = 5
#: pipelining must never cost more than this over strict sync
MAX_PIPELINE_RATIO = 1.10

#: bench_plan's provisioning question, shared seed tree and all
PLAN_FULL = {"qps": 2600.0, "duration": 12.0, "n_clients": 8,
             "policy": "jsq", "slo": 0.02, "n_grid": 24, "reps": 13,
             "steps": 150, "starts": 3, "samples": 16384, "probe_reps": 5}
PLAN_SMOKE = {"qps": 2600.0, "duration": 5.0, "n_clients": 8,
              "policy": "jsq", "slo": 0.02, "n_grid": 8, "reps": 3,
              "steps": 50, "starts": 1, "samples": 2048, "probe_reps": 2}
SEED = 0


def _row_bits(frame) -> list:
    """The frame's rows as an exact comparable (declaration order)."""
    return [(r.index, r.rep, r.params, r.seed, r.stream,
             {k: repr(v) for k, v in r.metrics.items()})
            for r in frame.rows]


def _cell_bits(results) -> list:
    return [(r.n, repr(r.mean), repr(r.p50), repr(r.p95), repr(r.p99),
             r.dropped, r.samples.tobytes(), r.sample_ivl.tobytes())
            for r in results]


# ---------------------------------------------------------------------------
# 1. Warm fig1-grid re-run
# ---------------------------------------------------------------------------
def sweep_section(smoke: bool, cache_root: str) -> dict:
    sweep = build_grid(smoke, "vector")
    cfg = VectorConfig()
    n_tasks = len(sweep.tasks())
    cache_dir = os.path.join(cache_root, "sweep")

    print(f"  fig1 grid ({n_tasks} cells), uncached ...", file=sys.stderr,
          flush=True)
    run_sweep(sweep, vector_config=cfg)       # pay the jit compile once
    t0 = time.perf_counter()
    plain = run_sweep(sweep, vector_config=cfg)
    uncached_wall = time.perf_counter() - t0

    print("  cold through a fresh cache ...", file=sys.stderr, flush=True)
    cold_cache = ResultCache(cache_dir=cache_dir)
    t0 = time.perf_counter()
    cold = run_sweep(sweep, vector_config=cfg, cache=cold_cache)
    cold_wall = time.perf_counter() - t0

    print("  warm re-run ...", file=sys.stderr, flush=True)
    warm_cache = ResultCache(cache_dir=cache_dir)
    t0 = time.perf_counter()
    warm = run_sweep(sweep, vector_config=cfg, cache=warm_cache)
    warm_wall = time.perf_counter() - t0

    hit_frac = warm_cache.stats.hits / max(n_tasks, 1)
    identical = (_row_bits(plain) == _row_bits(cold) == _row_bits(warm))
    speedup = cold_wall / max(warm_wall, 1e-9)
    print(f"    uncached {uncached_wall:.2f}s cold {cold_wall:.2f}s "
          f"warm {warm_wall:.2f}s -> {speedup:.1f}x, "
          f"hits {warm_cache.stats.hits}/{n_tasks}", file=sys.stderr)
    return {
        "tasks": n_tasks,
        "uncached_wall_s": round(uncached_wall, 3),
        "cold_wall_s": round(cold_wall, 3),
        "warm_wall_s": round(warm_wall, 3),
        "store_overhead_frac":
            round(cold_wall / max(uncached_wall, 1e-9) - 1.0, 4),
        "warm_speedup_vs_cold": round(speedup, 2),
        "warm_hits": warm_cache.stats.hits,
        "warm_misses": warm_cache.stats.misses,
        "hit_frac": round(hit_frac, 4),
        "rows_bit_identical": bool(identical),
        "errors": len(plain.errors) + len(cold.errors) + len(warm.errors),
    }


# ---------------------------------------------------------------------------
# 2. Planner cell reuse after a dense sweep
# ---------------------------------------------------------------------------
def planner_section(smoke: bool, cache_root: str) -> dict:
    p = PLAN_SMOKE if smoke else PLAN_FULL
    overrides = {"qps": p["qps"], "duration": p["duration"],
                 "n_clients": p["n_clients"], "policy": p["policy"]}
    cache_dir = os.path.join(cache_root, "plan")
    cfg = VectorConfig()

    progs, seeds = [], []
    for n in range(1, p["n_grid"] + 1):
        sc = get("steady", seed=SEED, slo=p["slo"], n_servers=n,
                 **overrides)
        prog = compile_experiment(sc.compile())
        for rep in range(p["reps"]):
            progs.append(prog)
            seeds.append((spawn_seed(SEED, n, rep), rep))
    print(f"  dense grid ({len(progs)} cells) into the cache ...",
          file=sys.stderr, flush=True)
    grid_cache = ResultCache(cache_dir=cache_dir)
    t0 = time.perf_counter()
    run_cells(progs, seeds, cfg, cache=grid_cache)
    grid_wall = time.perf_counter() - t0

    spec = PlanSpec(scenario="steady", objective="p99", slo=p["slo"],
                    overrides=overrides, steps=p["steps"],
                    starts=p["starts"], samples=p["samples"],
                    probe_reps=p["probe_reps"], reps=p["reps"], seed=SEED)
    print("  planner with the shared cache ...", file=sys.stderr,
          flush=True)
    plan_cache = ResultCache(cache_dir=cache_dir)
    t0 = time.perf_counter()
    res = run_plan(spec, cache=plan_cache)
    plan_wall = time.perf_counter() - t0
    print(f"    n_star={res.n_star} cell_evals={res.cell_evals} "
          f"(cache hits {plan_cache.stats.hits})", file=sys.stderr)
    return {
        "grid_cells": len(progs),
        "grid_wall_s": round(grid_wall, 3),
        "plan_wall_s": round(plan_wall, 3),
        "n_star": res.n_star,
        "feasible": bool(res.feasible),
        "cell_evals_with_cache": res.cell_evals,
        "cache_hits": plan_cache.stats.hits,
        "cache_misses": plan_cache.stats.misses,
    }


# ---------------------------------------------------------------------------
# 3. Pipelined vs sync chunk execution (jax warm path)
# ---------------------------------------------------------------------------
def pipeline_section(smoke: bool) -> dict:
    sweep = build_grid(smoke, "vector")
    from repro.sweep import PointCtx
    progs, seeds = [], []
    for i, params, rep in sweep.tasks():
        seed, stream = sweep.seed_for(i, rep)
        ctx = PointCtx(params=params, index=i, rep=rep, seed=seed,
                       stream=stream)
        obj = sweep.factory(ctx)
        exp = obj.compile() if hasattr(obj, "compile") else obj
        progs.append(compile_experiment(exp))
        seeds.append((seed, stream))
    # force the grid into ~4 chunks so there is something to overlap
    shape = progs[0].active.shape
    per_cell = int(shape[0]) * int(shape[1])
    elems = per_cell * max(1, len(progs) // 4)
    base = dict(backend="jax", impl="ref", max_slot_elems=elems)

    print(f"  pipeline: {len(progs)} cells in ~4 chunks ...",
          file=sys.stderr, flush=True)
    run_cells(progs, seeds, VectorConfig(**base))         # jit warm-up
    t0 = time.perf_counter()
    sync = run_cells(progs, seeds, VectorConfig(**base, pipeline=False))
    sync_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    piped = run_cells(progs, seeds, VectorConfig(**base, pipeline=True))
    piped_wall = time.perf_counter() - t0
    ratio = piped_wall / max(sync_wall, 1e-9)
    print(f"    sync {sync_wall:.2f}s pipelined {piped_wall:.2f}s "
          f"(ratio {ratio:.3f})", file=sys.stderr)
    return {
        "cells": len(progs),
        "chunks": 4,
        "sync_wall_s": round(sync_wall, 3),
        "pipelined_wall_s": round(piped_wall, 3),
        "pipelined_over_sync": round(ratio, 4),
        "bit_identical": bool(_cell_bits(sync) == _cell_bits(piped)),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale; writes the gitignored smoke record")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if any gate fails")
    args = ap.parse_args(argv)
    scale = "smoke" if args.smoke else "full"
    print(f"bench_cache ({scale}), jax={has_jax()}", file=sys.stderr)

    cache_root = tempfile.mkdtemp(prefix="bench_cache.")
    try:
        sweep = sweep_section(args.smoke, cache_root)
        planner = planner_section(args.smoke, cache_root) if has_jax() \
            else None
        pipeline = pipeline_section(args.smoke) if has_jax() else None
    finally:
        shutil.rmtree(cache_root, ignore_errors=True)

    gates = {
        "warm_speedup": bool(sweep["warm_speedup_vs_cold"]
                             >= MIN_WARM_SPEEDUP[scale]),
        "hit_frac": bool(sweep["hit_frac"] >= MIN_HIT_FRAC),
        "rows_bit_identical": sweep["rows_bit_identical"],
        "no_errors": sweep["errors"] == 0,
    }
    if planner is not None:
        gates["planner_cells"] = bool(planner["cell_evals_with_cache"]
                                      <= MAX_PLANNER_CELLS)
    if pipeline is not None:
        gates["pipeline_not_slower"] = bool(pipeline["pipelined_over_sync"]
                                            <= MAX_PIPELINE_RATIO)
        gates["pipeline_bit_identical"] = pipeline["bit_identical"]

    payload = {
        "benchmark": "bench_cache",
        "scale": scale,
        "jax_available": has_jax(),
        "sweep": sweep,
        "planner": planner,
        "pipeline": pipeline,
        "thresholds": {"min_warm_speedup": MIN_WARM_SPEEDUP[scale],
                       "min_hit_frac": MIN_HIT_FRAC,
                       "max_planner_cells": MAX_PLANNER_CELLS,
                       "max_pipeline_ratio": MAX_PIPELINE_RATIO},
        "gates": gates,
    }
    write_record("cache", payload, smoke=args.smoke)
    print(json.dumps({"gates": gates,
                      "warm_speedup": sweep["warm_speedup_vs_cold"],
                      "hit_frac": sweep["hit_frac"]}, indent=1))
    if args.check:
        return 0 if all(gates.values()) else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
