"""Sweep-executor throughput benchmark: serial vs process-parallel.

Runs one declarative 24-point grid (steady scenario: offered QPS x
server count x balancing policy) through ``repro.sweep`` twice — on the
serial executor and on the ``ProcessPoolExecutor`` backend — and writes
``BENCH_sweep.json`` at the repo root with both wall-clock times, the
speedup, and the determinism check (the two frames must be row-for-row
bit-identical; the parallel executor is only a speedup if it is also
the same experiment).

The parallel speedup is bounded by the machine, and nominal core counts
lie on shared hosts (steal time): the bench first CALIBRATES what
process-parallelism the host can actually deliver — the same worker
count running pure-CPU burn tasks — and reports the executor's speedup
both absolutely and as a fraction of that achievable bound.  The
fraction is the machine-independent health figure: ~1.0 means the sweep
executor captures essentially all the parallelism the host offers, on a
2-core laptop or a 64-core server alike.

Usage:
    PYTHONPATH=src python benchmarks/bench_sweep.py            # full grid
    PYTHONPATH=src python benchmarks/bench_sweep.py --workers 8
    PYTHONPATH=src python benchmarks/bench_sweep.py --smoke --check 0.55

``--smoke`` is the CI gate: a small grid, results to
``BENCH_sweep.smoke.json`` (gitignored, uploaded as a workflow
artifact — the committed full-scale record is never clobbered by a
CI-scale run, mirroring the bench_simulator convention).  With
``--check MIN`` the run exits non-zero unless the parallel executor
completed every point without an error row, reproduced the serial rows
bit-identically, and reached at least ``MIN x`` the calibrated
achievable speedup (the RELATIVE floor — on a healthy 4-core runner
0.55 demands ~2x absolute; a steal-throttled 2-vCPU container is not
asked for parallelism its host cannot physically provide).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

sys.path.insert(0, os.path.join(REPO, "src"))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from benchmarks._record import write_record  # noqa: E402
from repro.sweep import Axis, Sweep, run_sweep, scenario_factory  # noqa: E402


def build_sweep(smoke: bool) -> Sweep:
    if smoke:
        axes = (Axis("qps", (400.0, 700.0, 1000.0, 1300.0)),
                Axis("n_servers", (1, 2)),
                Axis("policy", ("round_robin", "jsq")))
        duration = 10.0
    else:
        axes = (Axis("qps", (600.0, 1000.0, 1400.0, 1800.0)),
                Axis("n_servers", (1, 2)),
                Axis("policy", ("round_robin", "jsq", "p2c")))
        duration = 20.0
    return Sweep(name="bench_sweep", factory=scenario_factory("steady"),
                 axes=axes, fixed={"duration": duration, "n_clients": 4},
                 reps=1, base_seed=7,
                 metrics=("n", "mean", "p50", "p95", "p99", "dropped"))


def _burn(n: int) -> int:
    s = 0
    for i in range(n):
        s += i * i
    return s


def calibrate(workers: int, tasks: int, n: int = 2_000_000) -> dict:
    """Achievable process-parallel speedup on THIS host right now:
    identical pure-CPU tasks, serial vs the same ProcessPoolExecutor
    the sweep uses.  This is the fair yardstick on shared machines,
    where nominal cpu_count overstates deliverable parallelism."""
    from concurrent.futures import ProcessPoolExecutor

    from repro.sweep.executor import mp_context
    t0 = time.perf_counter()
    for _ in range(tasks):
        _burn(n)
    serial = time.perf_counter() - t0
    with ProcessPoolExecutor(max_workers=workers,
                             mp_context=mp_context()) as pool:
        pool.submit(_burn, 1000).result()          # absorb pool startup
        t0 = time.perf_counter()
        list(pool.map(_burn, [n] * tasks))
        parallel = time.perf_counter() - t0
    return {"tasks": tasks, "serial_s": round(serial, 3),
            "parallel_s": round(parallel, 3),
            "achievable_speedup": round(serial / parallel, 2)}


def timed(sweep: Sweep, executor: str, workers=None):
    t0 = time.perf_counter()
    frame = run_sweep(sweep, executor=executor, workers=workers,
                      progress=None)
    wall = time.perf_counter() - t0
    return frame, wall


def rows_dump(frame) -> str:
    return json.dumps([r.to_dict() for r in frame.rows])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--check", type=float, default=None,
                    metavar="MIN_SPEEDUP")
    ap.add_argument("--workers", type=int, default=None,
                    help="parallel worker count (default: max(4, cores))")
    args = ap.parse_args(argv)

    cpus = os.cpu_count() or 1
    workers = args.workers if args.workers else max(4, cpus)
    sweep = build_sweep(args.smoke)
    n_points = len(sweep.point_dicts())
    print(f"bench_sweep: {n_points}-point grid, reps={sweep.reps}, "
          f"workers={workers}, cpus={cpus}", file=sys.stderr)

    print("  calibrating achievable parallelism ...", file=sys.stderr,
          flush=True)
    cal = calibrate(workers, tasks=2 * workers,
                    n=400_000 if args.smoke else 2_000_000)
    print(f"    achievable speedup {cal['achievable_speedup']}x "
          f"({workers} workers, {cpus} nominal cpus)", file=sys.stderr)

    print("  serial executor ...", file=sys.stderr, flush=True)
    serial_frame, serial_wall = timed(sweep, "serial")
    print(f"    {serial_wall:.2f}s", file=sys.stderr)
    print(f"  process executor ({workers} workers) ...", file=sys.stderr,
          flush=True)
    par_frame, par_wall = timed(sweep, "process", workers)
    print(f"    {par_wall:.2f}s", file=sys.stderr)

    identical = rows_dump(serial_frame) == rows_dump(par_frame)
    speedup = serial_wall / par_wall if par_wall > 0 else float("inf")
    achievable = cal["achievable_speedup"]
    fraction = speedup / achievable if achievable > 0 else float("nan")
    errors = {"serial": len(serial_frame.errors),
              "parallel": len(par_frame.errors)}
    out = {
        "benchmark": "bench_sweep",
        "grid": {**sweep.describe(), "tasks": len(sweep.tasks())},
        "cpu_count": cpus,
        "workers": workers,
        "calibration": cal,
        "serial": {"wall_s": round(serial_wall, 3),
                   "rows": len(serial_frame.rows),
                   "errors": errors["serial"]},
        "parallel": {"wall_s": round(par_wall, 3),
                     "rows": len(par_frame.rows),
                     "errors": errors["parallel"]},
        "speedup": round(speedup, 2),
        "fraction_of_achievable": round(fraction, 3),
        "rows_bit_identical": identical,
        "acceptance": {
            "grid_points": n_points,
            "meets_3x_absolute": bool(speedup >= 3.0),
            "note": ("meets_3x_absolute requires >= 4 deliverable cores; "
                     "fraction_of_achievable is the machine-independent "
                     "gate (calibration measures what this host's "
                     "scheduler actually provides)"),
        },
    }
    write_record("sweep", out, args.smoke)
    print(json.dumps({k: out[k] for k in ("cpu_count", "workers", "speedup",
                                          "fraction_of_achievable",
                                          "rows_bit_identical")}))

    if args.check is not None:
        ok = True
        if errors["parallel"] or errors["serial"]:
            print(f"CHECK FAILED: error rows {errors}", file=sys.stderr)
            ok = False
        if not identical:
            print("CHECK FAILED: parallel rows diverge from serial rows",
                  file=sys.stderr)
            ok = False
        if fraction < args.check:
            print(f"CHECK FAILED: speedup {speedup:.2f}x is "
                  f"{fraction:.2f} of the achievable {achievable}x "
                  f"< required fraction {args.check}", file=sys.stderr)
            ok = False
        if not ok:
            return 1
        print(f"check passed: speedup={speedup:.2f}x = {fraction:.2f} of "
              f"achievable {achievable}x (floor {args.check}), rows "
              f"bit-identical, no error rows")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
