"""Event-engine throughput benchmark: calendar-queue engine vs seed heap.

Runs the same open-loop multi-client scenario on the rebuilt engine
(``repro.core.simulator``) and on a frozen copy of the seed engine
(``benchmarks/_seed_sim.py``) at 10 / 100 / 1k / 10k servers, targeting
1M requests, and writes ``BENCH_simulator.json`` at the repo root with
events/sec and peak RSS per run.

Both engines run with identical exact-mode recorders for the speed
comparison (equal stats cost); the calendar engine is additionally
measured with the streaming P²/reservoir recorder to show the bounded-
memory path, and a ``batched`` row runs the continuous-batching serve
loop (BatchedService op events) at every scale so the batched hot path
is perf-gated alongside the scalar one.  The calendar rows run with ``fast_clients`` (the rebuilt
engine's vectorized arrival path), so the reported speedup is the whole
rebuilt request path — event queue + client generation — not the
calendar queue in isolation.  The seed engine's O(n_servers) per-request scan makes full
1M-request runs intractable at scale, so its request count is capped per
scale and throughput compared as a rate (the cap is recorded in the
JSON).  Each run executes in its own subprocess so peak-RSS figures are
per-scenario, not cumulative.

Usage:
    PYTHONPATH=src python benchmarks/bench_simulator.py            # full
    PYTHONPATH=src python benchmarks/bench_simulator.py --quick
    PYTHONPATH=src python benchmarks/bench_simulator.py --smoke --check 1.1
    PYTHONPATH=src python benchmarks/bench_simulator.py \
        --single calendar 1000 1000000 exact                       # one run

``--smoke`` is the CI regression gate: small scales, and with
``--check MIN`` the run exits non-zero if the calendar engine's
events/sec advantage over the seed engine at the largest scale falls
below MIN or the exact-mode equivalence check fails — engine-perf
regressions fail CI instead of only showing up in BENCH_simulator.json.
Smoke runs write ``BENCH_simulator.smoke.json`` instead, so the
committed full-scale record at the repo root is never clobbered by a
CI-scale run.
"""
from __future__ import annotations

import json
import os
import resource
import subprocess
import sys
import time

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if REPO not in sys.path:          # `import benchmarks...` from a subprocess
    sys.path.insert(0, REPO)

from benchmarks._record import write_record  # noqa: E402

DURATION = 90.0           # sim horizon (virtual seconds)
TARGET_SPAN = 55.0        # virtual seconds the offered load is spread over
# seed engine request caps per server count (O(n) scan per request)
SEED_CAP = {10: 300_000, 100: 150_000, 1000: 50_000, 10_000: 15_000}
# batched-row request cap per server count: 10 batched servers sustain
# ~10k req/s with the bench BatchedService, so the full 1M-request load
# (~18k req/s offered) can never finish inside the horizon — cap the
# offered load below capacity and compare throughput as a rate, exactly
# like the seed caps above
BATCHED_CAP = {10: 400_000}


def n_clients_for(servers: int) -> int:
    return min(2000, max(8, servers // 4))


def build(engine: str, servers: int, requests: int, stats_mode: str,
          fast_clients: bool = False):
    from repro.core.balancer import RoundRobin
    from repro.core.client import ClientConfig, ConstantQPS
    from repro.core.profiles import (BatchedService, FixedProfile,
                                     TokenLengths, tailbench_profile)
    from repro.core.simulator import SimConfig, SimServer, Simulator

    ncl = n_clients_for(servers)
    budget = max(1, requests // ncl)
    qps = (requests / TARGET_SPAN) / ncl
    # gauges off: the A/B measures the event engine, and the vendored seed
    # engine predates the telemetry sampler
    cfg = SimConfig(duration=DURATION, seed=7, stats_mode=stats_mode,
                    fast_clients=fast_clients, gauges=False)
    profile = tailbench_profile("masstree")
    clients = [ClientConfig(i, ConstantQPS(qps), seed=i + 1,
                            total_requests=budget) for i in range(ncl)]
    if engine == "calendar":
        sim = Simulator(cfg, [SimServer(i) for i in range(servers)],
                        RoundRobin(), profile=profile)
    elif engine == "batched":
        # continuous-batching serve loop: same arrival machinery, but
        # servers run BatchedService op events (prefill + decode steps)
        # instead of per-request finish events — the serve-loop hot path
        # this row perf-gates
        service = BatchedService("bench", t_memory=5e-4,
                                 t_compute_per_seq=6.25e-5,
                                 t_prefill_per_token=1e-5)
        lengths = TokenLengths(prompt_median=32, prompt_sigma=0.4,
                               new_median=8, new_sigma=0.4,
                               prompt_max=128, new_max=32)
        sim = Simulator(cfg, [SimServer(i, service_model=service,
                                        max_batch=8)
                              for i in range(servers)],
                        RoundRobin(), profile=FixedProfile("tok", 0.0),
                        lengths=lengths, service_model=service)
    elif engine == "seed":
        from benchmarks._seed_sim import SeedSimServer, SeedSimulator
        sim = SeedSimulator(cfg, [SeedSimServer(i) for i in range(servers)],
                            RoundRobin(), profile=profile)
    else:
        raise ValueError(engine)
    for c in clients:
        sim.add_client(c)
    return sim


def run_single(engine: str, servers: int, requests: int,
               stats_mode: str) -> dict:
    import gc
    # identical conditions for both engines: no GC pauses mid-measurement
    gc.disable()
    sim = build(engine, servers, requests, stats_mode,
                fast_clients=(engine == "calendar"))
    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0
    s = sim.recorder.overall()
    return {
        "engine": engine,
        "servers": servers,
        "clients": n_clients_for(servers),
        "requests": requests,
        "completed": s.n,
        "events": sim.events,
        "wall_s": round(wall, 3),
        "events_per_sec": round(sim.events / wall) if wall > 0 else None,
        "peak_rss_mb": round(
            resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0, 1),
        "stats_mode": stats_mode,
        "p99_ms": round(s.p99 * 1e3, 4),
    }


def spawn(engine: str, servers: int, requests: int, stats_mode: str,
          repeats: int = 1) -> dict:
    """One scenario in a fresh subprocess (isolated peak RSS).

    ``repeats`` reruns the scenario and keeps the fastest row: events/sec
    noise from neighbor contention is strictly one-sided (contention only
    slows a run down), so best-of-N is the fair estimate of engine speed
    — the speedup-comparison rows use it so the recorded ratios are not
    artifacts of whichever row drew the noisier seconds."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    print(f"  {engine:>8} servers={servers:<6} requests={requests:<8} "
          f"mode={stats_mode} ...", file=sys.stderr, flush=True)
    best = None
    for _ in range(max(1, repeats)):
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--single",
             engine, str(servers), str(requests), stats_mode],
            cwd=REPO, env=env, capture_output=True, text=True, check=True)
        row = json.loads(proc.stdout.strip().splitlines()[-1])
        if best is None or row["events_per_sec"] > best["events_per_sec"]:
            best = row
    print(f"           -> {best['events_per_sec']:,} events/s, "
          f"{best['peak_rss_mb']} MB peak RSS, {best['wall_s']}s",
          file=sys.stderr, flush=True)
    return best


def equivalence_check() -> dict:
    """Both engines, same small config, exact mode: results must match."""
    a = build("calendar", 20, 20_000, "exact")
    b = build("seed", 20, 20_000, "exact")
    a.run()
    b.run()
    sa, sb = a.recorder.overall(), b.recorder.overall()
    identical = (a.recorder.all == b.recorder.all)
    return {"servers": 20, "requests": 20_000,
            "calendar": [sa.n, sa.p50, sa.p95, sa.p99],
            "seed": [sb.n, sb.p50, sb.p95, sb.p99],
            "identical": identical}


def main(argv: list[str]) -> int:
    if argv[:1] == ["--single"]:
        engine, servers, requests, stats_mode = argv[1:5]
        row = run_single(engine, int(servers), int(requests), stats_mode)
        print(json.dumps(row))
        return 0

    quick = "--quick" in argv
    smoke = "--smoke" in argv
    check = None
    if "--check" in argv:
        check = float(argv[argv.index("--check") + 1])
    if smoke:
        requests, scales = 60_000, [10, 100]
    elif quick:
        requests, scales = 200_000, [10, 100, 1000]
    else:
        requests, scales = 1_000_000, [10, 100, 1000, 10_000]

    print(f"bench_simulator: scales={scales} target_requests={requests}",
          file=sys.stderr)
    # best-of-3 on the speedup-comparison rows for full runs; smoke/quick
    # trade precision for CI latency (their gate floor has a wide margin)
    reps = 1 if (smoke or quick) else 3
    rows = []
    for s in scales:
        rows.append(spawn("calendar", s, requests, "exact", repeats=reps))
        rows.append(spawn("seed", s, min(requests, SEED_CAP[s]), "exact",
                          repeats=reps))
        rows.append(spawn("batched", s, min(requests, BATCHED_CAP.get(s, requests)),
                          "exact"))
    for s in [x for x in (1000, 10_000) if x in scales]:
        rows.append(spawn("calendar", s, requests, "streaming"))

    speedup = {}
    for s in scales:
        cal = next(r for r in rows if r["engine"] == "calendar"
                   and r["servers"] == s and r["stats_mode"] == "exact")
        seed = next(r for r in rows if r["engine"] == "seed"
                    and r["servers"] == s)
        speedup[str(s)] = round(cal["events_per_sec"] / seed["events_per_sec"], 2)

    print("bench_simulator: running exact-mode equivalence check ...",
          file=sys.stderr)
    equiv = equivalence_check()

    at_1k = speedup.get("1000")
    top = str(max(scales))
    # continuous-batching serve loop, perf-gated like the scalar path:
    # the batched row must complete its full request budget and keep its
    # events/sec within a floor fraction of the scalar calendar engine
    # at the same scale (its events are decode/prefill ops, so absolute
    # rates are comparable but not identical)
    BATCHED_REL_FLOOR = 0.15
    batched_rel = {}
    batched_complete = True
    for s in scales:
        cal = next(r for r in rows if r["engine"] == "calendar"
                   and r["servers"] == s and r["stats_mode"] == "exact")
        bat = next(r for r in rows if r["engine"] == "batched"
                   and r["servers"] == s)
        batched_rel[str(s)] = round(
            bat["events_per_sec"] / cal["events_per_sec"], 3)
        if bat["completed"] != bat["requests"]:
            batched_complete = False
    out = {
        "benchmark": "bench_simulator",
        "scenario": {"duration_s": DURATION, "target_span_s": TARGET_SPAN,
                     "app": "masstree", "policy": "round_robin",
                     "seed_engine_request_caps": SEED_CAP,
                     "batched_request_caps": BATCHED_CAP},
        "rows": rows,
        "speedup_vs_seed_events_per_sec": speedup,
        "acceptance": {"speedup_at_1000_servers": at_1k,
                       "meets_5x": bool(at_1k and at_1k >= 5.0),
                       "exact_mode_bit_identical": equiv["identical"],
                       "batched_completed_all": batched_complete,
                       "batched_rel_events_per_sec": batched_rel,
                       "batched_rel_floor": BATCHED_REL_FLOOR},
        "equivalence_check": equiv,
    }
    write_record("simulator", out, smoke)
    print(json.dumps(out["acceptance"], indent=1))
    print(f"speedup vs seed engine: {speedup}")
    if check is not None:
        ok = True
        if not equiv["identical"]:
            print("CHECK FAILED: exact-mode results diverge from the seed "
                  "engine", file=sys.stderr)
            ok = False
        if speedup[top] < check:
            print(f"CHECK FAILED: speedup at {top} servers is "
                  f"{speedup[top]}x < required {check}x", file=sys.stderr)
            ok = False
        if not batched_complete:
            print("CHECK FAILED: batched serve loop did not complete its "
                  "request budget", file=sys.stderr)
            ok = False
        if batched_rel[top] < BATCHED_REL_FLOOR:
            print(f"CHECK FAILED: batched events/sec at {top} servers is "
                  f"{batched_rel[top]}x the scalar engine < floor "
                  f"{BATCHED_REL_FLOOR}x", file=sys.stderr)
            ok = False
        if not ok:
            return 1
        print(f"check passed: speedup@{top}={speedup[top]}x >= {check}x, "
              f"exact mode bit-identical, batched@{top}="
              f"{batched_rel[top]}x >= {BATCHED_REL_FLOOR}x")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
