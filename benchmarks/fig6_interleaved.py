"""Paper Fig. 6: interleaved client arrival pattern (features 1+2+3).

xapian, 1 server; clients start at 0/15/35s with budgets 10000/7000/5000 at
200 QPS each.  Per-interval p99 per client; when clients 1+2 finish, client
3's latency drops back to client 1's solo level.

A one-point ``repro.sweep`` declaration with per-client telemetry
capture — the per-interval series in the ``SweepRow`` carries exactly
what ``MetricsPipeline.series``/``window`` exposed on the live run, so
the figure CSV is bit-identical to the pre-sweep output.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.client import ClientConfig, ConstantQPS
from repro.core.harness import Experiment, ServerSpec
from repro.sweep import PointCtx, Sweep, run_sweep, series_window


def _point(ctx: PointCtx) -> Experiment:
    clients = [
        ClientConfig(1, ConstantQPS(200), start_time=0.0, total_requests=10000),
        ClientConfig(2, ConstantQPS(200), start_time=15.0, total_requests=7000),
        ClientConfig(3, ConstantQPS(200), start_time=35.0, total_requests=5000),
    ]
    return Experiment(clients=clients, servers=(ServerSpec(0, workers=2),),
                      app="xapian", duration=70.0, seed=ctx.seed)


SWEEP = Sweep(name="fig6_interleaved", factory=_point, reps=1,
              base_seed=11, seeder="fixed", metrics=(),
              telemetry=True, per_client=True)


def main() -> str:
    t0 = time.time()
    frame = run_sweep(SWEEP, progress=None).raise_errors()
    series = frame.rows[0].series
    rows = []
    for cid in (1, 2, 3):
        for r in series:
            if r["cid"] == cid:
                rows.append({"client": cid, "t": r["t"], "n": r["n"],
                             "p99_ms": f"{r['p99'] * 1e3:.3f}"})
    # check the paper's observation: client 3 alone (~t>52) ≈ client 1 solo (~t<14)
    solo1 = series_window(series, "p99", 2, 13, cid=1)
    solo3 = series_window(series, "p99", 53, cid=3)
    ratio = np.nanmean(solo3) / np.nanmean(solo1) if solo1 and solo3 else float("nan")
    emit("fig6_interleaved", rows, t0, f"solo3_vs_solo1_p99_ratio={ratio:.2f}")
    return f"ratio={ratio:.2f}"


if __name__ == "__main__":
    main()
