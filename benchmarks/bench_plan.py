"""Planner benchmark: gradient-based capacity planning vs the dense
provisioning grid it replaces.

One provisioning question — the smallest steady/jsq fleet whose exact
p99 meets the SLO — answered two ways on the SAME exact vector
runtime and the SAME SeedSequence spawn tree:

1. **Dense grid**: every integer fleet in the box at full repetition
   count, the way a sweep would answer it.  ``n_grid * reps`` exact
   cell evaluations; the optimum is the smallest fleet whose mean
   objective meets the target.
2. **Gradient planner** (``repro.plan``): Adam through the smoothed
   surrogate, then the integer probe ladder re-verified on the exact
   runtime.  ``PlanResult.cell_evals`` counts every exact cell the
   planner consumed.

The committed record (``BENCH_plan.json``) carries the acceptance
gates: the planner's answer must sit inside the grid optimum's 95% CI
at >=10x fewer cell evaluations, the finite-difference gradient checks
must pass, the best start's loss history must descend, and the
continuous optimum must land within tolerance of the hard-twin
bisection oracle (``analytic_capacity``).  A ``--smoke`` run writes
the gitignored ``BENCH_plan.smoke.json`` at CI scale and ``--check``
exits non-zero if any smoke gate fails.

Usage:
    PYTHONPATH=src python benchmarks/bench_plan.py              # full
    PYTHONPATH=src python benchmarks/bench_plan.py --smoke --check
"""
from __future__ import annotations

import argparse
import os
import sys
import time

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "src"))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

import numpy as np  # noqa: E402

from benchmarks._record import write_record  # noqa: E402
from repro.plan import (PlanConfig, PlanSpec, analytic_capacity,  # noqa: E402
                        build_plan_data, plan_loss, run_plan)
from repro.scenarios import get  # noqa: E402
from repro.sweep.spec import spawn_seed  # noqa: E402
from repro.vector import (VectorConfig, compile_experiment,  # noqa: E402
                          has_jax, run_cells)

#: the provisioning question, at full and CI scale
FULL = {"qps": 2600.0, "duration": 12.0, "n_clients": 8, "policy": "jsq",
        "slo": 0.02, "n_grid": 24, "reps": 13,
        "steps": 150, "starts": 3, "samples": 16384, "probe_reps": 5}
SMOKE = {"qps": 2600.0, "duration": 5.0, "n_clients": 8, "policy": "jsq",
         "slo": 0.02, "n_grid": 8, "reps": 3,
         "steps": 50, "starts": 1, "samples": 2048, "probe_reps": 2}

SEED = 0
#: continuous-optimum tolerance vs the bisection oracle (servers)
ANALYTIC_TOL = 0.75
ANALYTIC_REL = 0.25
#: full-run headline requirement: grid cells / planner cells
MIN_CELL_SPEEDUP = 10.0


def _mean_ci95(vals) -> tuple:
    vals = np.asarray(vals, float)
    m = float(vals.mean())
    if vals.size < 2:
        return m, float("nan")
    return m, float(1.96 * vals.std(ddof=1) / np.sqrt(vals.size))


def _overrides(p: dict) -> dict:
    return {"qps": p["qps"], "duration": p["duration"],
            "n_clients": p["n_clients"], "policy": p["policy"]}


def dense_grid(p: dict) -> dict:
    """Answer the question the sweep way: every fleet size, full reps,
    one batched exact run."""
    cfg = VectorConfig()
    progs, seeds, labels = [], [], []
    for n in range(1, p["n_grid"] + 1):
        sc = get("steady", seed=SEED, slo=p["slo"], n_servers=n,
                 **_overrides(p))
        prog = compile_experiment(sc.compile())
        for rep in range(p["reps"]):
            progs.append(prog)
            seeds.append((spawn_seed(SEED, n, rep), rep))
            labels.append(n)
    t0 = time.perf_counter()
    results = run_cells(progs, seeds, cfg)
    wall = time.perf_counter() - t0
    rows = []
    for n in range(1, p["n_grid"] + 1):
        vals = [r.p99 for r, k in zip(results, labels) if k == n]
        mean, ci = _mean_ci95(vals)
        rows.append({"n": n, "p99_mean": mean, "p99_ci95": ci,
                     "meets": bool(mean <= p["slo"])})
    feasible = [r for r in rows if r["meets"]]
    opt = feasible[0] if feasible else None
    return {"cells": len(progs), "wall_s": round(wall, 3),
            "n_opt": None if opt is None else opt["n"],
            "p99_mean": None if opt is None else opt["p99_mean"],
            "p99_ci95": None if opt is None else opt["p99_ci95"],
            "rows": rows}


def fd_checks(p: dict) -> dict:
    """End-to-end d(plan_loss)/d(capacity) vs central differences, in
    float64 — the same gate tests/test_plan.py enforces."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    data = build_plan_data("steady", slo=p["slo"], objective="p99",
                           overrides=_overrides(p),
                           samples=min(p["samples"], 4096), seed=SEED)
    cfg = PlanConfig()
    rows = []
    with enable_x64():
        def loss(x):
            return plan_loss({"capacity": x}, data, cfg)[0]

        for x0 in (2.5, 4.0, 6.0):
            x = jnp.asarray(x0, jnp.float64)
            g = float(jax.grad(loss)(x))
            eps = 1e-4
            fd = (float(loss(x + eps)) - float(loss(x - eps))) / (2 * eps)
            ok = abs(g - fd) <= 2e-2 * max(abs(fd), abs(g)) + 1e-8
            rows.append({"x": x0, "grad": g, "fd": fd, "ok": ok})
    return {"rows": rows, "passed": all(r["ok"] for r in rows)}


def run_planner(p: dict) -> tuple:
    spec = PlanSpec(scenario="steady", objective="p99", slo=p["slo"],
                    overrides=_overrides(p), steps=p["steps"],
                    starts=p["starts"], samples=p["samples"],
                    probe_reps=p["probe_reps"], reps=p["reps"], seed=SEED)
    t0 = time.perf_counter()
    res = run_plan(spec)
    wall = time.perf_counter() - t0
    return res, wall


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale; writes the gitignored smoke record")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if any gate fails")
    args = ap.parse_args(argv)
    if not has_jax():
        print("bench_plan needs jax (the planner differentiates the "
              "surrogate)", file=sys.stderr)
        return 1
    p = SMOKE if args.smoke else FULL

    fd = fd_checks(p)
    print(f"fd gradient checks: {'PASS' if fd['passed'] else 'FAIL'}")

    grid = dense_grid(p)
    print(f"dense grid: {grid['cells']} cells in {grid['wall_s']}s -> "
          f"n_opt={grid['n_opt']} p99={grid['p99_mean']}")

    res, plan_wall = run_planner(p)
    hist = res.starts[res.best_start]["history"]
    head = max(1, min(5, len(hist) // 4))
    loss_descends = bool(hist[-1] <= hist[0] and
                         np.mean(hist[-head:]) <= np.mean(hist[:head]))

    data = build_plan_data("steady", slo=p["slo"], objective="p99",
                           overrides=_overrides(p), samples=p["samples"],
                           seed=SEED)
    x_a = analytic_capacity(data)
    x = res.params["capacity"]
    analytic_ok = bool(abs(x - x_a) <= max(ANALYTIC_TOL,
                                           ANALYTIC_REL * x_a))

    v = res.verified or {}
    ci_overlap = None
    if grid["n_opt"] is not None and v:
        gap = abs(v["mean"] - grid["p99_mean"])
        allow = grid["p99_ci95"] + (0.0 if np.isnan(v["ci95"])
                                    else v["ci95"])
        ci_overlap = bool(gap <= allow)
    speedup = grid["cells"] / max(res.cell_evals, 1)
    same_fleet = bool(grid["n_opt"] == res.n_star)

    gates = {"fd_checks": fd["passed"],
             "loss_descends": loss_descends,
             "analytic_tolerance": analytic_ok,
             "ci_overlap_vs_grid": ci_overlap,
             "exact_verified_feasible": bool(res.feasible)}
    if not args.smoke:
        gates["cell_speedup_10x"] = bool(speedup >= MIN_CELL_SPEEDUP)

    payload = {
        "benchmark": "bench_plan",
        "scale": "smoke" if args.smoke else "full",
        "problem": {**p, "seed": SEED, "objective": "p99",
                    "scenario": "steady"},
        "fd": fd,
        "grid": grid,
        "planner": {
            "continuous_capacity": x,
            "analytic_capacity": round(x_a, 4),
            "best_start": res.best_start,
            "loss_first": hist[0], "loss_last": hist[-1],
            "n_star": res.n_star,
            "verified": v,
            "probes": res.probes,
            "cell_evals": res.cell_evals,
            "wall_s": round(plan_wall, 3),
        },
        "headline": {
            "grid_cells": grid["cells"],
            "planner_cells": res.cell_evals,
            "cell_speedup": round(speedup, 2),
            "wall_speedup": round(grid["wall_s"] / max(plan_wall, 1e-9),
                                  2),
            "same_fleet_as_grid": same_fleet,
        },
        "gates": gates,
    }
    write_record("plan", payload, smoke=args.smoke)
    print(f"planner: {res.cell_evals} cells in {round(plan_wall, 3)}s -> "
          f"n_star={res.n_star} (grid n_opt={grid['n_opt']}); "
          f"cell speedup {round(speedup, 1)}x")
    for k, ok in gates.items():
        print(f"gate {k}: {'PASS' if ok else 'FAIL' if ok is False else 'n/a'}")
    if args.check:
        return 0 if all(v is not False for v in gates.values()) else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
