"""Beyond paper: hedged requests + request-level policies under server noise.

Tail-at-scale scenario: 3 noisy servers (log-sigma 1.0); compare p99 with
and without hedging at several hedge delays, plus JSQ vs P2C vs RR."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.client import ClientConfig, ConstantQPS
from repro.core.harness import Experiment, ServerSpec, run_repeated


def main() -> str:
    t0 = time.time()
    rows = []
    servers = tuple(ServerSpec(i, service_noise=1.0) for i in range(3))
    base_p99 = None
    best = (None, 1.0)
    for label, hedge in (("none", None), ("5ms", 0.005), ("10ms", 0.01),
                         ("25ms", 0.025)):
        clients = [ClientConfig(i, ConstantQPS(40), seed=4) for i in range(4)]
        exp = Experiment(clients=clients, servers=servers, app="xapian",
                         duration=20.0, policy="jsq", hedge_delay=hedge, seed=4)
        (p99, ci), _ = run_repeated(exp, reps=9)
        rows.append({"hedge": label, "p99_ms": f"{p99*1e3:.3f}",
                     "ci95": f"{ci*1e3:.3f}"})
        if label == "none":
            base_p99 = p99
        elif p99 / base_p99 < best[1]:
            best = (label, p99 / base_p99)
    emit("hedging", rows, t0,
         f"best_hedge={best[0]};p99_cut={1-best[1]:.1%}")
    return f"cut={1-best[1]:.1%}"


if __name__ == "__main__":
    main()
