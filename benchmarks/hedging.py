"""Beyond paper: hedged requests + request-level policies under server noise.

Tail-at-scale scenario: 3 noisy servers (log-sigma 1.0); compare p99 with
and without hedging at several hedge delays, plus JSQ vs P2C vs RR.

Declared as a ``repro.sweep`` grid over the hedge-delay axis at the
paper's 13 repetitions (the old script hand-picked ``reps=9``), using
the default collision-free ``"spawn"`` seeder.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.client import ClientConfig, ConstantQPS
from repro.core.harness import Experiment, ServerSpec
from repro.sweep import Axis, PointCtx, Sweep, run_sweep

HEDGES = (("none", None), ("5ms", 0.005), ("10ms", 0.01), ("25ms", 0.025))
REPS = 13


def _point(ctx: PointCtx) -> Experiment:
    delay = dict(HEDGES)[ctx.params["hedge"]]
    clients = [ClientConfig(i, ConstantQPS(40), seed=4) for i in range(4)]
    servers = tuple(ServerSpec(i, service_noise=1.0) for i in range(3))
    return Experiment(clients=clients, servers=servers, app="xapian",
                      duration=20.0, policy="jsq", hedge_delay=delay,
                      seed=ctx.seed)


SWEEP = Sweep(name="hedging", factory=_point,
              axes=(Axis("hedge", tuple(label for label, _ in HEDGES)),),
              reps=REPS, base_seed=4, metrics=("p99",))


def main() -> str:
    t0 = time.time()
    frame = run_sweep(SWEEP, progress=None).raise_errors()
    rows = []
    base_p99 = None
    best = (None, 1.0)
    for agg in frame.aggregate("p99"):
        label, p99, ci = agg["params"]["hedge"], agg["mean"], agg["ci95"]
        rows.append({"hedge": label, "p99_ms": f"{p99*1e3:.3f}",
                     "ci95": f"{ci*1e3:.3f}"})
        if label == "none":
            base_p99 = p99
        elif p99 / base_p99 < best[1]:
            best = (label, p99 / base_p99)
    emit("hedging", rows, t0,
         f"best_hedge={best[0]};p99_cut={1-best[1]:.1%}")
    return f"cut={1-best[1]:.1%}"


if __name__ == "__main__":
    main()
