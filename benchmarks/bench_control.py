"""Closed-loop control benchmark: SLO attainment vs provisioned cost.

Two measurements, one committed record (``BENCH_control.json``):

1. **Attainment/cost frontier under a flash crowd** — the same
   flash-crowd trace served four ways: a static 2-server fleet
   (under-provisioned), a static 6-server fleet (peak-provisioned), a
   reactive threshold autoscaler drawing on a standby pool, and an
   AIMD admission shedder (brownout).  Attainment is
   ``1 - slo_frac`` with shed/timed-out/failed requests counted as
   violations (the honest denominator); cost is integrated
   server-seconds from the control log.  Gates: the autoscaler beats
   static-small attainment while staying under static-big cost — the
   closed loop actually buys the middle of the frontier.

2. **Retry-storm contrast** — the same overload burst under naive
   immediate retries vs capped/jittered/budgeted backoff.  Gates:
   backoff serves >= 1.3x the naive goodput and issues < 1/5 the
   retries — the metastable-congestion result the resilience stack
   exists to demonstrate.

Usage:
    PYTHONPATH=src python benchmarks/bench_control.py             # full
    PYTHONPATH=src python benchmarks/bench_control.py --smoke --check
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "src"))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from benchmarks._record import write_record  # noqa: E402
from repro.core.harness import ServerSpec  # noqa: E402
from repro.core.runtime import run_scenario  # noqa: E402
from repro.scenarios import get  # noqa: E402
from repro.sweep.executor import _slo_frac  # noqa: E402

#: the reactive loop must beat the under-provisioned fleet by this much
MIN_ATTAINMENT_GAIN = 0.05
#: ... while spending at most this fraction of peak provisioning
MAX_COST_VS_STATIC_BIG = 0.95
MIN_BACKOFF_GOODPUT_RATIO = 1.3
MAX_BACKOFF_RETRY_FRAC = 0.2

SCALE = {"full": {"duration": 45.0, "reps": 5},
         "smoke": {"duration": 18.0, "reps": 2}}
SEED = 0
#: tight enough that the flash crowd actually violates it on an
#: under-provisioned fleet (the scenario default 250ms never would)
SLO = 0.02


def _cost_server_seconds(sc, rt) -> float:
    """Integrated active-server-seconds from the run's control log."""
    n0 = sum(1 for s in sc.servers if not s.standby)
    steps = [(t, p["n"]) for t, k, p in getattr(rt, "control_log", [])
             if k == "set_scale"]
    cost, t_prev, n_prev = 0.0, 0.0, n0
    for t, n in steps:
        cost += n_prev * (min(t, sc.duration) - t_prev)
        t_prev, n_prev = min(t, sc.duration), n
    return cost + n_prev * (sc.duration - t_prev)


def _arm(name: str, sc, rep: int) -> dict:
    rt = run_scenario(sc, "sim", rep=rep)
    s = rt.telemetry.overall()
    frac = _slo_frac(rt, sc.slo)
    return {"arm": name, "rep": rep, "n": s.n,
            "p99_ms": round(s.p99 * 1e3, 3),
            "shed": int(getattr(rt, "shed", 0)),
            "slo_frac": round(frac, 5),
            "attainment": round(1.0 - frac, 5),
            "cost_server_s": round(_cost_server_seconds(sc, rt), 2)}


def _frontier_arms(duration: float, seed: int):
    base = dict(seed=seed, duration=duration, slo=SLO)
    small = get("flash-crowd-autoscale", **base)
    small.control = None                       # 2 active + idle standby
    big = get("flash-crowd-autoscale", **base)
    big.control = None
    big.servers = tuple(ServerSpec(i, workers=2) for i in range(6))
    auto = get("flash-crowd-autoscale", **base)
    shed = get("flash-crowd-autoscale", **base,
               controller="admission_shedder")
    return [("static-small", small), ("static-big", big),
            ("autoscaler", auto), ("shedder", shed)]


def frontier_section(smoke: bool) -> dict:
    cfg = SCALE["smoke" if smoke else "full"]
    rows = []
    for rep in range(cfg["reps"]):
        for name, sc in _frontier_arms(cfg["duration"], SEED):
            rows.append(_arm(name, sc, rep))
            print(f"  {rows[-1]}", file=sys.stderr, flush=True)

    def agg(name, key):
        xs = [r[key] for r in rows if r["arm"] == name]
        return sum(xs) / len(xs)

    summary = {name: {"attainment": round(agg(name, "attainment"), 5),
                      "cost_server_s": round(agg(name, "cost_server_s"), 2),
                      "p99_ms": round(agg(name, "p99_ms"), 3)}
               for name in ("static-small", "static-big", "autoscaler",
                            "shedder")}
    return {"duration_s": cfg["duration"], "reps": cfg["reps"],
            "arms": rows, "summary": summary}


def retry_storm_section(smoke: bool) -> dict:
    cfg = SCALE["smoke" if smoke else "full"]
    out = {}
    for mode in ("naive", "backoff"):
        ns, tos, rets, p99s = [], [], [], []
        for rep in range(cfg["reps"]):
            rt = run_scenario(get("retry-storm", seed=SEED, mode=mode,
                                  duration=cfg["duration"]), "sim",
                              rep=rep)
            s = rt.telemetry.overall()
            ns.append(s.n)
            tos.append(rt.timeouts)
            rets.append(rt.retries)
            p99s.append(s.p99)
        out[mode] = {"goodput": round(sum(ns) / len(ns), 1),
                     "timeouts": round(sum(tos) / len(tos), 1),
                     "retries": round(sum(rets) / len(rets), 1),
                     "p99_ms": round(sum(p99s) / len(p99s) * 1e3, 3)}
        print(f"  retry-storm {mode}: {out[mode]}", file=sys.stderr,
              flush=True)
    naive, backoff = out["naive"], out["backoff"]
    out["goodput_ratio"] = round(backoff["goodput"]
                                 / max(naive["goodput"], 1.0), 3)
    out["retry_ratio"] = round(backoff["retries"]
                               / max(naive["retries"], 1.0), 4)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale; writes the gitignored smoke record")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if any gate fails")
    args = ap.parse_args(argv)
    scale = "smoke" if args.smoke else "full"
    print(f"bench_control ({scale})", file=sys.stderr)

    frontier = frontier_section(args.smoke)
    storm = retry_storm_section(args.smoke)

    summ = frontier["summary"]
    gates = {
        "autoscaler_beats_static_small": bool(
            summ["autoscaler"]["attainment"]
            >= summ["static-small"]["attainment"] + MIN_ATTAINMENT_GAIN),
        "autoscaler_cheaper_than_static_big": bool(
            summ["autoscaler"]["cost_server_s"]
            <= MAX_COST_VS_STATIC_BIG * summ["static-big"]["cost_server_s"]),
        "shedder_beats_static_small": bool(
            summ["shedder"]["attainment"]
            > summ["static-small"]["attainment"]),
        "backoff_goodput": bool(storm["goodput_ratio"]
                                >= MIN_BACKOFF_GOODPUT_RATIO),
        "backoff_retry_discipline": bool(storm["retry_ratio"]
                                         <= MAX_BACKOFF_RETRY_FRAC),
    }

    payload = {
        "benchmark": "bench_control",
        "scale": scale,
        "frontier": frontier,
        "retry_storm": storm,
        "thresholds": {
            "min_attainment_gain": MIN_ATTAINMENT_GAIN,
            "max_cost_vs_static_big": MAX_COST_VS_STATIC_BIG,
            "min_backoff_goodput_ratio": MIN_BACKOFF_GOODPUT_RATIO,
            "max_backoff_retry_frac": MAX_BACKOFF_RETRY_FRAC,
        },
        "gates": gates,
    }
    write_record("control", payload, smoke=args.smoke)
    print(json.dumps({"gates": gates, "summary": summ,
                      "goodput_ratio": storm["goodput_ratio"]}, indent=1))
    if args.check:
        return 0 if all(gates.values()) else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
