"""Vector-runtime benchmark: grid throughput + statistical-equivalence gate.

Two measurements, one committed record (``BENCH_vector.json``):

1. **Points/sec on the fig1 grid shape** — the paper's Fig. 1 sweep (9
   offered-QPS points, 3 clients, one 6-worker xapian server, 15s
   horizon) at the paper's 13 repetitions = 117 (point, rep) cells.
   The serial event engine replays them one scalar run at a time; the
   vector backend executes the whole grid as ONE batched array program
   (jax ``lax.scan`` under ``jit``, plus the pure-NumPy fallback).
   The jax row reports cold (includes the one-time jit compile) and
   warm wall clocks; the speedup headline is the warm figure, with the
   compile cost recorded alongside — a real sweep pays it once per
   grid shape.

2. **The fig4-style equivalence gate** — the vector backend is the
   statistically-equivalent fast lane, not a bit-identical one, so the
   record carries the evidence: for every canonical scenario, 13
   seeded repetitions per backend and a per-metric (p50/p95/p99) gate:
   95% CI overlap (with a small relative slack) OR Welch's H0
   retained.  CI runs the same gate at smoke scale.

Usage:
    PYTHONPATH=src python benchmarks/bench_vector.py            # full
    PYTHONPATH=src python benchmarks/bench_vector.py --smoke --check 3.0
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "src"))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

import numpy as np  # noqa: E402

from benchmarks._record import write_record  # noqa: E402
from repro.core.client import ClientConfig, ConstantQPS  # noqa: E402
from repro.core.harness import Experiment, ServerSpec  # noqa: E402
from repro.core.runtime import SimulatorRuntime  # noqa: E402
from repro.core.stats import confidence95, welch_ttest  # noqa: E402
from repro.scenarios import get, names  # noqa: E402
from repro.sweep import Axis, PointCtx, Sweep, run_sweep  # noqa: E402
from repro.sweep.executor import run_vector_tasks  # noqa: E402
from repro.sweep.spec import spawn_seed  # noqa: E402
from repro.vector import VectorConfig, VectorRuntime, has_jax  # noqa: E402

FULL_QPS = (100, 250, 500, 1000, 2000, 3000, 4000, 4600, 5200)
SMOKE_QPS = (200, 500, 1000, 2000)
METRICS = ("p50", "p95", "p99")
#: relative slack on the CI-overlap test (razor-thin CI pairs must not
#: turn realization noise into a gate failure)
REL_SLACK = 0.10
#: committed full-grid warm points/sec of the jax path before the
#: kernelized dispatch landed — the refactor must not fall below it
JAX_BASELINE_PPS = 52.36


def _fig1_point(ctx: PointCtx) -> Experiment:
    qps = ctx.params["qps"]
    clients = [ClientConfig(i, ConstantQPS(qps / 3), seed=1)
               for i in range(3)]
    return Experiment(clients=clients, servers=(ServerSpec(0, workers=6),),
                      duration=ctx.params["duration"], app="xapian",
                      seed=ctx.seed)


def build_grid(smoke: bool, runtime: str) -> Sweep:
    qps = SMOKE_QPS if smoke else FULL_QPS
    return Sweep(name="bench_vector_fig1", factory=_fig1_point,
                 axes=(Axis("qps", qps),),
                 fixed={"duration": 6.0 if smoke else 15.0},
                 reps=3 if smoke else 13, base_seed=1, seeder="spawn",
                 runtime=runtime,
                 metrics=("n", "mean", "p50", "p95", "p99"))


def time_grid(sweep: Sweep, config=None) -> tuple:
    t0 = time.perf_counter()
    if config is None:
        frame = run_sweep(sweep, executor="serial", progress=None)
    else:
        tasks = [(k, i, params, rep)
                 for k, (i, params, rep) in enumerate(sweep.tasks())]
        rows = run_vector_tasks(sweep, tasks, config=config)
        frame = type("F", (), {"rows": list(rows.values()),
                               "errors": [r for r in rows.values()
                                          if not r.ok]})
    wall = time.perf_counter() - t0
    return frame, wall


def bucket_histogram(sweep: Sweep, cfg: VectorConfig) -> dict:
    """Cells per (family, padded (T, S) bucket) — the shapes the jit
    cache actually compiles for."""
    from repro.vector import compile_experiment
    from repro.vector.runtime import _plan_groups
    progs = []
    for i, params, rep in sweep.tasks():
        seed, stream = sweep.seed_for(i, rep)
        ctx = PointCtx(params=params, index=i, rep=rep, seed=seed,
                       stream=stream)
        obj = sweep.factory(ctx)
        exp = obj.compile() if hasattr(obj, "compile") else obj
        progs.append(compile_experiment(exp, dt=cfg.dt))
    return {f"{'batched' if batched else 'scalar'}:{T}x{S}": len(idxs)
            for batched, (T, S), idxs in _plan_groups(progs, cfg)}


def _vector_row(label: str, cfg: VectorConfig, sweep: Sweep, n_tasks: int,
                sim_wall: float) -> dict:
    print(f"  vector backend ({label}) ...", file=sys.stderr, flush=True)
    _, cold = time_grid(sweep, config=cfg)
    frame, warm = time_grid(sweep, config=cfg)
    warm = min(cold, warm)
    print(f"    cold {cold:.2f}s warm {warm:.2f}s", file=sys.stderr)
    row = {
        "cold_wall_s": round(cold, 3),      # includes jit compile
        "warm_wall_s": round(warm, 3),
        "points_per_sec": round(n_tasks / warm, 2),
        "speedup_vs_sim": round(sim_wall / warm, 2),
        "cold_speedup_vs_sim": round(sim_wall / cold, 2),
        "errors": len(frame.errors)}
    if cfg.resolve_backend() == "jax":
        row["impl"] = cfg.resolve_impl()
        row["n_devices"] = cfg.resolve_devices()
        row["bucket_hist"] = bucket_histogram(sweep, cfg)
    return row


def grid_rows(smoke: bool, impl: str = "auto") -> dict:
    n_tasks = len(build_grid(smoke, "sim").tasks())
    print(f"  serial event engine ({n_tasks} cells) ...", file=sys.stderr,
          flush=True)
    sim_frame, sim_wall = time_grid(build_grid(smoke, "sim"))
    print(f"    {sim_wall:.2f}s", file=sys.stderr)
    out = {"tasks": n_tasks,
           "sim": {"wall_s": round(sim_wall, 3),
                   "points_per_sec": round(n_tasks / sim_wall, 2),
                   "errors": len(sim_frame.errors)}}
    rows = [("numpy", VectorConfig(backend="numpy"))]
    if has_jax():
        jax_cfg = VectorConfig(backend="jax", impl=impl)
        rows.append(("jax", jax_cfg))
        if jax_cfg.resolve_impl() != "pallas":
            # off-TPU the auto path runs the jnp reference; also record
            # the interpret-mode Pallas row (the kernel bodies compiled
            # through the interpreter — bit-identical, slower)
            rows.append(("jax_pallas",
                         VectorConfig(backend="jax", impl="pallas")))
    sweep = build_grid(smoke, "vector")
    for label, cfg in rows:
        out[f"vector_{label}"] = _vector_row(label, cfg, sweep, n_tasks,
                                             sim_wall)
    return out


# ---------------------------------------------------------------------------
# Equivalence gate (fig4 methodology: repeated seeded runs per backend)
# ---------------------------------------------------------------------------
def _run_reps(name: str, backend: str, reps: int, duration=None,
              impl: str = "auto") -> dict:
    vals: dict[str, list] = {m: [] for m in METRICS}
    kw = {} if duration is None else {"duration": duration}
    cfg = VectorConfig(impl=impl)
    for rep in range(reps):
        exp = get(name, seed=spawn_seed(0x6A7E, 0, rep), **kw).compile()
        rt = SimulatorRuntime(exp, rep=rep) if backend == "sim" \
            else VectorRuntime(exp, rep=rep, config=cfg)
        rt.run()
        s = rt.telemetry.overall()
        for m in METRICS:
            vals[m].append(getattr(s, m))
    return vals


def equivalence_gate(smoke: bool, impl: str = "auto") -> dict:
    reps = 5 if smoke else 13
    rows = []
    all_pass = True
    for name in names():
        # smoke shortens the horizon — except batched-serving, whose
        # occupancy ramp needs its full default horizon to compare
        duration = None if (not smoke or name == "batched-serving") \
            else 12.0
        print(f"  equivalence: {name} ({reps} reps x 2 backends) ...",
              file=sys.stderr, flush=True)
        sim_vals = _run_reps(name, "sim", reps, duration)
        vec_vals = _run_reps(name, "vector", reps, duration, impl)
        for m in METRICS:
            ms, cs = confidence95(sim_vals[m])
            mv, cv = confidence95(vec_vals[m])
            gap = abs(ms - mv)
            slack = (0.0 if np.isnan(cs) else cs) + \
                (0.0 if np.isnan(cv) else cv) + REL_SLACK * ms
            w = welch_ttest(sim_vals[m], vec_vals[m])
            retained = bool(abs(w.t_stat) < 2 and w.p_value > 0.05) \
                if not np.isnan(w.t_stat) else False
            ok = bool(gap <= slack or retained)
            all_pass &= ok
            rows.append({"scenario": name, "metric": m,
                         "sim_mean": ms, "sim_ci95": cs,
                         "vector_mean": mv, "vector_ci95": cv,
                         "ci_overlap": bool(gap <= slack),
                         "welch_t": round(w.t_stat, 3),
                         "welch_p": round(w.p_value, 4),
                         "welch_retained": retained,
                         "passed": ok})
            if not ok:
                print(f"    GATE FAIL {name}/{m}: sim {ms:.6g}+-{cs:.2g} "
                      f"vs vector {mv:.6g}+-{cv:.2g}", file=sys.stderr)
    return {"reps": reps, "rel_slack": REL_SLACK, "rows": rows,
            "all_passed": bool(all_pass)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--check", type=float, default=None, metavar="MIN_X",
                    help="exit non-zero unless the jax (or numpy-fallback) "
                         "warm speedup reaches MIN_X and the equivalence "
                         "gate passes")
    ap.add_argument("--impl", default="auto",
                    choices=["auto", "ref", "pallas"],
                    help="pin the jax path's kernel impl (auto honors "
                         "REPRO_FORCE_IMPL; all impls are bit-identical)")
    args = ap.parse_args(argv)

    print(f"bench_vector: fig1 grid shape "
          f"({'smoke' if args.smoke else 'full'}), jax={has_jax()}",
          file=sys.stderr)
    grid = grid_rows(args.smoke, args.impl)
    print("bench_vector: equivalence gate ...", file=sys.stderr)
    equiv = equivalence_gate(args.smoke, args.impl)

    # the headline backend is whichever vector path is fastest HERE: on
    # CI-scale smoke grids the jit compile can leave numpy ahead; at
    # full scale jax wins
    vec_keys = [k for k in grid if k.startswith("vector_")]
    best = max((grid[k] for k in vec_keys),
               key=lambda r: r["speedup_vs_sim"])
    out = {
        "benchmark": "bench_vector",
        "grid_shape": {"qps_points": list(SMOKE_QPS if args.smoke
                                          else FULL_QPS),
                       "reps": 3 if args.smoke else 13,
                       "duration_s": 6.0 if args.smoke else 15.0},
        "jax_available": has_jax(),
        "grid": grid,
        "equivalence": equiv,
        "acceptance": {
            "speedup_vs_serial_event_engine": best["speedup_vs_sim"],
            "meets_20x": bool(best["speedup_vs_sim"] >= 20.0),
            "numpy_fallback_speedup":
                grid["vector_numpy"]["speedup_vs_sim"],
            "numpy_meets_5x":
                bool(grid["vector_numpy"]["speedup_vs_sim"] >= 5.0),
            "equivalence_all_passed": equiv["all_passed"],
            "note": ("speedups are warm-path (one jit compile per grid "
                     "shape is paid once and recorded as cold_wall_s); "
                     "the equivalence gate is CI-overlap OR Welch-"
                     "retained per scenario x metric vs the exact "
                     "event engine"),
        },
    }
    if "vector_jax" in grid:
        pps = grid["vector_jax"]["points_per_sec"]
        out["acceptance"]["jax_warm_points_per_sec"] = pps
        out["acceptance"]["jax_impl"] = grid["vector_jax"]["impl"]
        out["acceptance"]["n_devices"] = grid["vector_jax"]["n_devices"]
        # the absolute floor is a full-grid number; smoke grids run a
        # different shape, so their gate is the relative --check instead
        if not args.smoke:
            out["acceptance"]["meets_committed_jax_baseline"] = \
                bool(pps >= JAX_BASELINE_PPS)
    write_record("vector", out, args.smoke)
    print(json.dumps(out["acceptance"], indent=1))

    if args.check is not None:
        ok = True
        errs = sum(v.get("errors", 0) for v in grid.values()
                   if isinstance(v, dict))
        if errs:
            print(f"CHECK FAILED: {errs} error rows", file=sys.stderr)
            ok = False
        if best["speedup_vs_sim"] < args.check:
            print(f"CHECK FAILED: vector speedup "
                  f"{best['speedup_vs_sim']}x < required {args.check}x",
                  file=sys.stderr)
            ok = False
        if not equiv["all_passed"]:
            print("CHECK FAILED: equivalence gate", file=sys.stderr)
            ok = False
        if not ok:
            return 1
        print(f"check passed: speedup={best['speedup_vs_sim']}x >= "
              f"{args.check}x, equivalence gate green "
              f"({len(equiv['rows'])} scenario-metric pairs)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
