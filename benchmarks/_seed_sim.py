"""Frozen copy of the seed discrete-event engine (commit 42b2234).

Kept verbatim — binary heap, per-request closure allocation, O(n) alive-
server scan per routed request, list-based server queues with O(n)
``pop(0)``/``remove`` — so ``bench_simulator.py`` can A/B the rebuilt
calendar-queue engine against the exact algorithmic profile it replaced.
Only two deviations from the seed source:

* the recorder honors ``cfg.stats_mode`` so both engines pay identical
  stats costs in a comparison run;
* an ``events`` counter in ``run()`` (the benchmark's numerator).

Do not use outside benchmarks; the production engine lives in
``repro.core.simulator``.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional

import numpy as np

from repro.core.client import ClientConfig, ClientGenerator
from repro.core.request import Request
from repro.core.simulator import SimConfig
from repro.core.stats import LatencyRecorder


class SeedSimServer:
    def __init__(self, server_id: int, workers: int = 1, speed: float = 1.0,
                 service_noise: float = 0.0):
        self.server_id = server_id
        self.workers = workers
        self.speed = speed
        self.service_noise = service_noise
        self._rng = np.random.default_rng((9176, server_id))
        self.queue: list[Request] = []
        self.busy = 0
        self.connected: set[int] = set()
        self.accepting = True
        self.draining = False
        self.total_served = 0
        self.busy_time = 0.0

    def connect(self, client_id: int) -> bool:
        if not self.accepting:
            return False
        self.connected.add(client_id)
        return True

    def disconnect(self, client_id: int):
        self.connected.discard(client_id)

    def enqueue(self, req: Request, now: float, sim: "SeedSimulator"):
        req.server_id = self.server_id
        req.enqueued = now
        if self.busy < self.workers:
            self._start(req, now, sim)
        else:
            self.queue.append(req)

    def _start(self, req: Request, now: float, sim: "SeedSimulator"):
        twin = getattr(req, "_twin", None)
        if twin is not None and twin.started is None:
            srv = sim.servers.get(twin.server_id)
            if srv is not None and twin in srv.queue:
                srv.queue.remove(twin)
        self.busy += 1
        req.started = now
        dur = req.service_demand / self.speed
        if self.service_noise > 0.0:
            dur *= float(np.exp(self.service_noise * self._rng.standard_normal()))
        self.busy_time += dur
        sim.schedule(now + dur, lambda t, r=req: self._finish(r, t, sim))

    def _finish(self, req: Request, now: float, sim: "SeedSimulator"):
        self.busy -= 1
        req.completed = now
        self.total_served += 1
        sim.on_completion(req)
        if self.queue:
            self._start(self.queue.pop(0), now, sim)

    def load(self) -> int:
        return self.busy + len(self.queue)


class SeedSimulator:
    def __init__(self, cfg: SimConfig, servers: list[SeedSimServer], balancer,
                 profile=None):
        self.cfg = cfg
        self.servers = {s.server_id: s for s in servers}
        self.balancer = balancer
        self.profile = profile
        self.recorder = LatencyRecorder(cfg.interval, mode=cfg.stats_mode)
        self._heap: list = []
        self._seq = itertools.count()
        self._req_ids = itertools.count()
        self.now = 0.0
        self.events = 0
        self.clients: dict[int, ClientGenerator] = {}
        self.assignment: dict[int, int] = {}
        self.dropped = 0
        self.completed_per_client: dict[int, int] = {}
        self._legacy_started = cfg.legacy_expected_clients == 0
        self._legacy_initial: set[int] = set()
        self._legacy_hold: list[Request] = []
        self._legacy_terminated = False

    def schedule(self, t: float, fn: Callable[[float], None]):
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def run(self):
        while self._heap:
            t, _, fn = heapq.heappop(self._heap)
            if t > self.cfg.duration:
                break
            self.now = t
            fn(t)
            self.events += 1
        return self.recorder

    def add_client(self, ccfg: ClientConfig):
        gen = ClientGenerator(ccfg, self.profile)
        self.clients[ccfg.client_id] = gen
        self.schedule(ccfg.start_time, lambda t, c=ccfg: self._connect(c, t))

    def _connect(self, ccfg: ClientConfig, t: float):
        cid = ccfg.client_id
        if self.cfg.legacy_mode:
            if self._legacy_started and cid not in self._legacy_initial:
                self.dropped += 1
                return
            self._legacy_initial.add(cid)
        server = self.balancer.assign(self.clients[cid], self._alive_servers())
        if server is None or not server.connect(cid):
            self.dropped += 1
            return
        self.assignment[cid] = server.server_id
        if self.cfg.legacy_mode and not self._legacy_started:
            if len(self._legacy_initial) >= self.cfg.legacy_expected_clients:
                self._legacy_started = True
                for req in self._legacy_hold:
                    self._route(req, self.now)
                self._legacy_hold.clear()
        self._pump(cid)

    def _pump(self, cid: int):
        gen = self.clients[cid]
        if self.cfg.legacy_mode and self.cfg.legacy_requests_per_client is not None:
            if gen.sent >= self.cfg.legacy_requests_per_client:
                self._client_done(cid)
                return
        nxt = gen.next_arrival()
        if nxt is None:
            self._client_done(cid)
            return
        t, demand = nxt
        self.schedule(t, lambda tt, c=cid, d=demand: self._emit(c, d, tt))

    def _emit(self, cid: int, demand: float, t: float):
        req = Request(next(self._req_ids), cid, t, demand)
        if self.cfg.legacy_mode and not self._legacy_started:
            self._legacy_hold.append(req)
        elif self.cfg.legacy_mode and self._legacy_terminated:
            self.dropped += 1
        else:
            self._route(req, t)
        self._pump(cid)

    def _route(self, req: Request, t: float):
        sid = self.assignment.get(req.client_id)
        server = self.balancer.route(req, self._alive_servers(),
                                     self.servers.get(sid) if sid is not None else None)
        if server is None:
            self.dropped += 1
            return
        server.enqueue(req, t, self)
        if self.cfg.hedge_delay is not None:
            self.schedule(t + self.cfg.hedge_delay,
                          lambda tt, r=req: self._maybe_hedge(r, tt))

    def _maybe_hedge(self, req: Request, t: float):
        if req.completed is not None or req.hedged:
            return
        others = [s for s in self._alive_servers()
                  if s.server_id != req.server_id]
        if not others:
            return
        req.hedged = True
        clone = Request(req.req_id, req.client_id, req.created,
                        req.service_demand, hedged=True)
        clone._primary = req
        clone._twin = req
        req._twin = clone
        target = min(others, key=lambda s: s.load())
        target.enqueue(clone, t, self)

    def _client_done(self, cid: int):
        sid = self.assignment.pop(cid, None)
        if sid is not None:
            self.servers[sid].disconnect(cid)
        self.clients.pop(cid, None)
        if self.cfg.legacy_mode and not self.clients:
            self._legacy_terminated = True
        self.completed_per_client[cid] = self.completed_per_client.get(cid, 0)

    def on_completion(self, req: Request):
        primary = getattr(req, "_primary", None)
        if primary is not None:
            if getattr(primary, "_recorded", False):
                return
            primary.started = req.started
            primary.completed = req.completed
            primary.server_id = req.server_id
            req = primary
        if getattr(req, "_recorded", False):
            return
        req._recorded = True
        self.recorder.record(req)
        c = self.completed_per_client
        c[req.client_id] = c.get(req.client_id, 0) + 1

    def _alive_servers(self) -> list[SeedSimServer]:
        return [s for s in self.servers.values() if not s.draining]

    def add_server(self, server: SeedSimServer, at: float):
        def _add(t):
            self.servers[server.server_id] = server
        self.schedule(at, _add)

    def drain_server(self, server_id: int, at: float):
        def _drain(t):
            self.servers[server_id].draining = True
            self.servers[server_id].accepting = False
        self.schedule(at, _drain)
