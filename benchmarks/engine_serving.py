"""End-to-end: real JAX engine served by the TailBench++ harness (wall-clock).

A smoke-scale model behind 2 InferenceEngine replicas; open-loop clients at
two rates; reports p50/p95/p99 wall-clock latency.  Validates that the
harness <-> engine integration (Fig. 3's data flow) actually runs."""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.client import ClientConfig, ConstantQPS
from repro.core.runtime import EngineRuntime
from repro.models import registry as R
from repro.serving.engine import InferenceEngine


def main() -> str:
    t0 = time.time()
    cfg = get_config("phi3-mini-3.8b-smoke")
    params = R.init_params(cfg, jax.random.PRNGKey(0))
    rows = []
    p99 = None
    for qps in (20, 60):
        engines = [InferenceEngine(cfg, params, max_batch=4, max_len=64)
                   for _ in range(2)]
        # warm the compile caches outside the timed window
        for e in engines:
            e.submit(jax.numpy.arange(16), 2, -1)
            e.run_until_idle()
        clients = [ClientConfig(i, ConstantQPS(qps / 2), end_time=3.0, seed=i)
                   for i in range(2)]
        rt = EngineRuntime(engines, clients, policy="jsq", duration=3.0,
                           prompt_len=16, max_new_tokens=4,
                           vocab=cfg.vocab_size)
        rt.run()
        s = rt.telemetry.overall()
        rows.append({"qps": qps, "n": s.n, "p50_ms": f"{s.p50*1e3:.1f}",
                     "p95_ms": f"{s.p95*1e3:.1f}", "p99_ms": f"{s.p99*1e3:.1f}"})
        p99 = s.p99
    emit("engine_serving", rows, t0, f"p99_ms={p99*1e3:.1f}")
    return "ok"


if __name__ == "__main__":
    main()
