"""Paper Fig. 4 + Table 4: TailBench (legacy) vs TailBench++ equivalence.

For each of the 8 apps, run both harness modes over a QPS range with 13
repetitions each (independent seeds per mode, like independent runs on a
real testbed), then Welch's t-test on the mean/p95/p99 distributions.
The null hypothesis (no behavioral difference) must be retained everywhere:
|t| < 2 and p > 0.05 — the paper's validation methodology."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.harness import run
from repro.core.legacy import legacy_experiment, plusplus_equivalent
from repro.core.stats import welch_ttest

QPS_RANGE = {          # per-app load points (scaled to service time)
    "masstree": (500, 2000), "silo": (400, 1500), "xapian": (100, 400),
    "img-dnn": (100, 300), "specjbb": (150, 500), "shore": (40, 120),
    "moses": (4, 10), "sphinx": (0.3, 0.8),
}
REPS = 13
METRICS = ("mean", "p95", "p99")
# slow apps need longer (virtual) windows to accumulate a sample
DURATION = {"sphinx": 150.0, "moses": 40.0}


def main() -> str:
    t0 = time.time()
    rows = []
    all_retained = True
    for app, qs in QPS_RANGE.items():
        legacy_vals = {m: [] for m in METRICS}
        pp_vals = {m: [] for m in METRICS}
        for qps in qs:
            for rep in range(REPS):
                seed = 1000 * rep + hash(app) % 997
                dur = DURATION.get(app, 12.0)
                leg = legacy_experiment(3, qps / 3,
                                        requests_per_client=int(qps * dur / 3),
                                        app=app, duration=dur, seed=seed)
                pp = plusplus_equivalent(legacy_experiment(
                    3, qps / 3, requests_per_client=int(qps * dur / 3),
                    app=app, duration=dur, seed=seed + 500_000))
                s_l = run(leg).telemetry.overall()
                s_p = run(pp).telemetry.overall()
                for m in METRICS:
                    legacy_vals[m].append(getattr(s_l, m))
                    pp_vals[m].append(getattr(s_p, m))
        for m in METRICS:
            w = welch_ttest(legacy_vals[m], pp_vals[m])
            retained = abs(w.t_stat) < 2 and w.p_value > 0.05
            all_retained &= retained
            rows.append({"app": app, "metric": m,
                         "t_stat": round(w.t_stat, 3),
                         "p_value": round(w.p_value, 3),
                         "H0_retained": retained})
    emit("fig4_table4_equivalence", rows, t0, f"H0_retained_all={all_retained}")
    return f"H0_retained_all={all_retained}"


if __name__ == "__main__":
    main()
