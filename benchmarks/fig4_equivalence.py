"""Paper Fig. 4 + Table 4: TailBench (legacy) vs TailBench++ equivalence.

For each of the 8 apps, run both harness modes over a QPS range with 13
repetitions each (independent seeds per mode, like independent runs on a
real testbed), then Welch's t-test on the mean/p95/p99 distributions.
The null hypothesis (no behavioral difference) must be retained everywhere:
|t| < 2 and p > 0.05 — the paper's validation methodology.

Declared as a ``repro.sweep`` over explicit points (app x per-app QPS x
harness variant, 13 repetitions each).  Per-app seeds come from a
stable digest (``zlib.crc32``), so the run is deterministic in any
process — the old ``hash(app)`` derivation silently depended on
``PYTHONHASHSEED``.
"""
from __future__ import annotations

import time
import zlib

from benchmarks.common import emit
from repro.core.legacy import legacy_experiment, plusplus_equivalent
from repro.core.stats import welch_ttest
from repro.sweep import PointCtx, Sweep, run_sweep

QPS_RANGE = {          # per-app load points (scaled to service time)
    "masstree": (500, 2000), "silo": (400, 1500), "xapian": (100, 400),
    "img-dnn": (100, 300), "specjbb": (150, 500), "shore": (40, 120),
    "moses": (4, 10), "sphinx": (0.3, 0.8),
}
REPS = 13
METRICS = ("mean", "p95", "p99")
# slow apps need longer (virtual) windows to accumulate a sample
DURATION = {"sphinx": 150.0, "moses": 40.0}


def app_seed(app: str, rep: int) -> int:
    """Stable per-(app, rep) seed: crc32 digest, never ``hash()``."""
    return 1000 * rep + zlib.crc32(app.encode()) % 997


def _point(ctx: PointCtx):
    app, qps = ctx.params["app"], ctx.params["qps"]
    seed = app_seed(app, ctx.rep)
    dur = DURATION.get(app, 12.0)
    if ctx.params["variant"] == "legacy":
        return legacy_experiment(3, qps / 3,
                                 requests_per_client=int(qps * dur / 3),
                                 app=app, duration=dur, seed=seed)
    # the ++ harness runs as an independent testbed: independent seeds
    return plusplus_equivalent(legacy_experiment(
        3, qps / 3, requests_per_client=int(qps * dur / 3),
        app=app, duration=dur, seed=seed + 500_000))


SWEEP = Sweep(name="fig4_equivalence", factory=_point, mode="points",
              points=tuple({"app": app, "qps": qps, "variant": variant}
                           for app, qs in QPS_RANGE.items()
                           for qps in qs
                           for variant in ("legacy", "plusplus")),
              reps=REPS, seeder="fixed", metrics=METRICS)


def main() -> str:
    t0 = time.time()
    frame = run_sweep(SWEEP, progress=None).raise_errors()
    rows = []
    all_retained = True
    for app in QPS_RANGE:
        for m in METRICS:
            w = welch_ttest(frame.values(m, app=app, variant="legacy"),
                            frame.values(m, app=app, variant="plusplus"))
            retained = abs(w.t_stat) < 2 and w.p_value > 0.05
            all_retained &= retained
            rows.append({"app": app, "metric": m,
                         "t_stat": round(w.t_stat, 3),
                         "p_value": round(w.p_value, 3),
                         "H0_retained": retained})
    emit("fig4_table4_equivalence", rows, t0, f"H0_retained_all={all_retained}")
    return f"H0_retained_all={all_retained}"


if __name__ == "__main__":
    main()
