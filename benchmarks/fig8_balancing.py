"""Paper Fig. 8: round-robin vs load-aware balancing, 2 servers, 3 clients
(500/200/200 QPS).  Load-aware isolates the heavy client; round-robin can
co-locate it with another client, hurting its p99.

Declared as a ``repro.sweep`` grid over the policy axis with 13
repetitions and per-client summary capture.  The ``"rep"`` seeder
replays the historical ``for seed in range(13)`` loop (the repetition
index IS the experiment seed and the clients derive their streams from
it), keeping the figure CSV bit-identical to the pre-sweep output.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.client import ClientConfig, ConstantQPS
from repro.core.harness import Experiment, ServerSpec
from repro.sweep import Axis, PointCtx, Sweep, run_sweep

POLICIES = ("round_robin", "load_aware", "jsq", "p2c")


def _point(ctx: PointCtx) -> Experiment:
    seed = ctx.seed
    clients = [ClientConfig(1, ConstantQPS(500), seed=seed),
               ClientConfig(2, ConstantQPS(200), seed=seed + 99),
               ClientConfig(3, ConstantQPS(200), seed=seed + 198)]
    return Experiment(clients=clients,
                      servers=(ServerSpec(0), ServerSpec(1)),
                      app="xapian", duration=15.0,
                      policy=ctx.params["policy"], seed=seed)


SWEEP = Sweep(name="fig8_balancing", factory=_point,
              axes=(Axis("policy", POLICIES),), reps=13,
              base_seed=0, seeder="rep", metrics=(), per_client=True)


def main() -> str:
    t0 = time.time()
    frame = run_sweep(SWEEP, progress=None).raise_errors()
    rows = []
    worst = {}
    for policy in POLICIES:
        per_client = {c: [r.clients[str(c)]["p99"]
                          for r in frame.ok_rows
                          if r.params["policy"] == policy]
                      for c in (1, 2, 3)}
        for c in (1, 2, 3):
            rows.append({"policy": policy, "client": c,
                         "p99_ms": f"{np.mean(per_client[c])*1e3:.3f}"})
        worst[policy] = max(np.mean(per_client[c]) for c in (1, 2, 3))
    gain = worst["round_robin"] / worst["load_aware"]
    emit("fig8_balancing", rows, t0, f"rr_vs_load_aware_worst_p99={gain:.2f}x")
    return f"gain={gain:.2f}x"


if __name__ == "__main__":
    main()
