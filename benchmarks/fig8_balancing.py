"""Paper Fig. 8: round-robin vs load-aware balancing, 2 servers, 3 clients
(500/200/200 QPS).  Load-aware isolates the heavy client; round-robin can
co-locate it with another client, hurting its p99."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.client import ClientConfig, ConstantQPS
from repro.core.harness import Experiment, ServerSpec, run


def main() -> str:
    t0 = time.time()
    rows = []
    worst = {}
    for policy in ("round_robin", "load_aware", "jsq", "p2c"):
        per_client = {1: [], 2: [], 3: []}
        for seed in range(13):
            clients = [ClientConfig(1, ConstantQPS(500), seed=seed),
                       ClientConfig(2, ConstantQPS(200), seed=seed + 99),
                       ClientConfig(3, ConstantQPS(200), seed=seed + 198)]
            exp = Experiment(clients=clients,
                             servers=(ServerSpec(0), ServerSpec(1)),
                             app="xapian", duration=15.0, policy=policy,
                             seed=seed)
            sim = run(exp)
            for c in (1, 2, 3):
                per_client[c].append(sim.telemetry.client(c).p99)
        for c in (1, 2, 3):
            rows.append({"policy": policy, "client": c,
                         "p99_ms": f"{np.mean(per_client[c])*1e3:.3f}"})
        worst[policy] = max(np.mean(per_client[c]) for c in (1, 2, 3))
    gain = worst["round_robin"] / worst["load_aware"]
    emit("fig8_balancing", rows, t0, f"rr_vs_load_aware_worst_p99={gain:.2f}x")
    return f"gain={gain:.2f}x"


if __name__ == "__main__":
    main()
