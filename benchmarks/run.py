"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one line per benchmark); full
result tables land in artifacts/bench/*.csv.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (engine_serving, fig1_qps_latency, fig4_equivalence,
                            fig5_multiserver, fig6_interleaved,
                            fig7_dynamic_qps, fig8_balancing, fig_batching,
                            hedging, roofline_table)
    benches = [fig1_qps_latency, fig4_equivalence, fig5_multiserver,
               fig6_interleaved, fig7_dynamic_qps, fig8_balancing,
               fig_batching, hedging, roofline_table, engine_serving]
    print("name,us_per_call,derived")
    failures = 0
    for b in benches:
        try:
            b.main()
        except Exception:
            failures += 1
            name = b.__name__.split(".")[-1]
            print(f"{name},-1,FAILED")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
