"""Shared benchmark helpers: CSV emission + artifact dir."""
from __future__ import annotations

import os
import time

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def emit(name: str, rows: list[dict], t0: float, derived: str = "") -> None:
    """Print the run.py contract line + write the full CSV artifact."""
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, name + ".csv")
    if rows:
        cols = list(rows[0])
        with open(path, "w") as f:
            f.write(",".join(cols) + "\n")
            for r in rows:
                f.write(",".join(str(r[c]) for c in cols) + "\n")
    us = (time.time() - t0) * 1e6
    print(f"{name},{us:.0f},{derived}")
