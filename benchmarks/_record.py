"""Shared benchmark-record plumbing.

Every bench follows the same convention: a FULL run writes the
committed record at the repo root (``BENCH_<name>.json`` — the numbers
the README/acceptance cite), while a ``--smoke`` run writes a
gitignored sibling (``BENCH_<name>.smoke.json``) that CI uploads as a
workflow artifact — a CI-scale run must never clobber the committed
full-scale record.  This module is that convention in one place
(``bench_simulator``/``bench_sweep``/``bench_vector`` all write
through it).
"""
from __future__ import annotations

import json
import os

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def record_paths(name: str) -> tuple[str, str]:
    """-> (committed full-run path, gitignored smoke path)."""
    return (os.path.join(REPO, f"BENCH_{name}.json"),
            os.path.join(REPO, f"BENCH_{name}.smoke.json"))


def write_record(name: str, payload: dict, smoke: bool,
                 indent: int = 1) -> str:
    """Write the record to the path the run class owns; -> the path."""
    full, smoke_path = record_paths(name)
    path = smoke_path if smoke else full
    with open(path, "w") as f:
        json.dump(payload, f, indent=indent)
        f.write("\n")
    print(f"wrote {path}")
    return path
