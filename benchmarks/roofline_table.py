"""Beyond-paper deliverable: roofline table over dry-run artifacts
(single-pod 16x16).  One row per (arch x shape) with the three terms,
dominant bottleneck, and useful-FLOPs ratio."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.launch.roofline import analyze, load_results


def main() -> str:
    t0 = time.time()
    rows = []
    dominated = {"compute": 0, "memory": 0, "collective": 0}
    for r in load_results(multi_pod=False):
        a = analyze(r)
        dominated[a.dominant] += 1
        rows.append({
            "arch": a.arch, "shape": a.shape,
            "compute_s": f"{a.compute_s:.4e}", "memory_s": f"{a.memory_s:.4e}",
            "collective_s": f"{a.collective_s:.4e}", "dominant": a.dominant,
            "model_flops": f"{a.model_flops:.3e}",
            "hlo_flops": f"{a.hlo_flops:.3e}",
            "useful_ratio": f"{a.useful_ratio:.3f}",
            "roofline_fraction": f"{a.roofline_fraction:.3f}",
        })
    n = len(rows)
    emit("roofline_table", rows, t0,
         f"cells={n};compute={dominated['compute']};"
         f"memory={dominated['memory']};collective={dominated['collective']}")
    return f"cells={n}"


if __name__ == "__main__":
    main()
