"""Paper Fig. 7 + Table 5: one client varying QPS every 10s.

100 -> 300 -> 500 -> 600 -> 800 -> 100 QPS; per-interval mean/p95/p99.
Expected: latency tracks load, burstiness near saturation (40-50s window),
and the first/last intervals match (same 100 QPS).

A one-point ``repro.sweep`` declaration with telemetry capture; window
statistics come from the row's per-interval series (bit-identical to
the live ``MetricsPipeline.window`` values).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.client import ClientConfig, PiecewiseQPS
from repro.core.harness import Experiment, ServerSpec
from repro.sweep import PointCtx, Sweep, run_sweep, series_window

TABLE5 = [(0, 100), (10, 300), (20, 500), (30, 600), (40, 800), (50, 100)]


def _point(ctx: PointCtx) -> Experiment:
    return Experiment(clients=[ClientConfig(0, PiecewiseQPS(TABLE5))],
                      servers=(ServerSpec(0, workers=1),),
                      app="xapian", duration=60.0, seed=ctx.seed)


SWEEP = Sweep(name="fig7_dynamic_qps", factory=_point, reps=1,
              base_seed=13, seeder="fixed", metrics=(), telemetry=True)


def main() -> str:
    t0 = time.time()
    frame = run_sweep(SWEEP, progress=None).raise_errors()
    series = frame.rows[0].series
    rows = [{"t": r["t"], "n": r["n"], "mean_ms": f"{r['mean']*1e3:.3f}",
             "p95_ms": f"{r['p95']*1e3:.3f}", "p99_ms": f"{r['p99']*1e3:.3f}"}
            for r in series if r["cid"] == -1]
    first = np.nanmean(series_window(series, "p99", 2, 9))
    last = np.nanmean(series_window(series, "p99", 52, 59))
    peak = np.nanmax(series_window(series, "p99", 41, 50))
    sym = last / first
    emit("fig7_dynamic_qps", rows, t0,
         f"first_vs_last_p99_ratio={sym:.2f};peak_p99_ms={peak*1e3:.1f}")
    return f"sym={sym:.2f}"


if __name__ == "__main__":
    main()
