"""Paper Fig. 7 + Table 5: one client varying QPS every 10s.

100 -> 300 -> 500 -> 600 -> 800 -> 100 QPS; per-interval mean/p95/p99.
Expected: latency tracks load, burstiness near saturation (40-50s window),
and the first/last intervals match (same 100 QPS)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.client import ClientConfig, PiecewiseQPS
from repro.core.harness import Experiment, ServerSpec, run

TABLE5 = [(0, 100), (10, 300), (20, 500), (30, 600), (40, 800), (50, 100)]


def main() -> str:
    t0 = time.time()
    exp = Experiment(clients=[ClientConfig(0, PiecewiseQPS(TABLE5))],
                     servers=(ServerSpec(0, workers=1),),
                     app="xapian", duration=60.0, seed=13)
    sim = run(exp)
    rows = []
    for ivl, s in sim.telemetry.series().items():
        rows.append({"t": ivl, "n": s.n, "mean_ms": f"{s.mean*1e3:.3f}",
                     "p95_ms": f"{s.p95*1e3:.3f}", "p99_ms": f"{s.p99*1e3:.3f}"})
    first = np.nanmean(sim.telemetry.window("p99", 2, 9))
    last = np.nanmean(sim.telemetry.window("p99", 52, 59))
    peak = np.nanmax(sim.telemetry.window("p99", 41, 50))
    sym = last / first
    emit("fig7_dynamic_qps", rows, t0,
         f"first_vs_last_p99_ratio={sym:.2f};peak_p99_ms={peak*1e3:.1f}")
    return f"sym={sym:.2f}"


if __name__ == "__main__":
    main()
