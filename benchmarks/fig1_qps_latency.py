"""Paper Fig. 1: QPS vs latency for the web-search app (xapian).

Three clients, one server; sweep offered QPS; report mean/p95/p99.
Reproduces the shape: flat low-ms latency then a knee near saturation."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.client import ClientConfig, ConstantQPS
from repro.core.harness import Experiment, run


def main() -> str:
    t0 = time.time()
    rows = []
    knee = None
    prev_p99 = None
    for qps in (100, 250, 500, 1000, 2000, 3000, 4000, 4600, 5200):
        clients = [ClientConfig(i, ConstantQPS(qps / 3), seed=1) for i in range(3)]
        # xapian server: 6 workers -> capacity ~4.4k QPS (paper: degrades >4000)
        from repro.core.harness import ServerSpec
        exp = Experiment(clients=clients, servers=(ServerSpec(0, workers=6),),
                         duration=15.0, app="xapian", seed=1)
        s = run(exp).telemetry.overall()
        rows.append({"qps": qps, "n": s.n, "mean_ms": s.mean * 1e3,
                     "p95_ms": s.p95 * 1e3, "p99_ms": s.p99 * 1e3})
        if prev_p99 and s.p99 > 3 * prev_p99 and knee is None:
            knee = qps
        prev_p99 = s.p99
    emit("fig1_qps_latency", rows, t0, f"knee_qps={knee}")
    return f"knee_qps={knee}"


if __name__ == "__main__":
    main()
