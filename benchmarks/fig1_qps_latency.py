"""Paper Fig. 1: QPS vs latency for the web-search app (xapian).

Three clients, one server; sweep offered QPS; report mean/p95/p99.
Reproduces the shape: flat low-ms latency then a knee near saturation.

Declared as a ``repro.sweep`` grid: one axis (offered QPS), one
repetition, fixed seed — bit-identical to the historical hand-rolled
loop (seeder ``"fixed"`` replays ``seed=1`` / rep-stream 0 per point).
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.client import ClientConfig, ConstantQPS
from repro.core.harness import Experiment, ServerSpec
from repro.sweep import Axis, PointCtx, Sweep, run_sweep

QPS_AXIS = (100, 250, 500, 1000, 2000, 3000, 4000, 4600, 5200)


def _point(ctx: PointCtx) -> Experiment:
    qps = ctx.params["qps"]
    clients = [ClientConfig(i, ConstantQPS(qps / 3), seed=1)
               for i in range(3)]
    # xapian server: 6 workers -> capacity ~4.4k QPS (paper: degrades >4000)
    return Experiment(clients=clients, servers=(ServerSpec(0, workers=6),),
                      duration=15.0, app="xapian", seed=ctx.seed)


SWEEP = Sweep(name="fig1_qps_latency", factory=_point,
              axes=(Axis("qps", QPS_AXIS),), reps=1,
              base_seed=1, seeder="fixed",
              metrics=("n", "mean", "p95", "p99"))


def main() -> str:
    t0 = time.time()
    frame = run_sweep(SWEEP, progress=None).raise_errors()
    rows = []
    knee = None
    prev_p99 = None
    for r in frame.rows:
        m = r.metrics
        rows.append({"qps": r.params["qps"], "n": m["n"],
                     "mean_ms": m["mean"] * 1e3, "p95_ms": m["p95"] * 1e3,
                     "p99_ms": m["p99"] * 1e3})
        if prev_p99 and m["p99"] > 3 * prev_p99 and knee is None:
            knee = r.params["qps"]
        prev_p99 = m["p99"]
    emit("fig1_qps_latency", rows, t0, f"knee_qps={knee}")
    return f"knee_qps={knee}"


if __name__ == "__main__":
    main()
