"""Paper Fig. 5: single- vs multi-server characterization, all 8 apps.

3 clients -> 1 vs 2 servers via round-robin LVS; p95/p99 with 95% CIs over
13 repetitions.  Expected: multi-server lowers tail latency for most apps;
apps whose bottleneck is not the server queue benefit least."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.client import ClientConfig, ConstantQPS
from repro.core.harness import Experiment, ServerSpec, run
from repro.core.stats import confidence95

# silo/specjbb run far from server saturation (the paper observes they do
# not benefit from a second server — their bottleneck is not the queue).
LOAD = {"masstree": 1500, "silo": 300, "xapian": 450, "img-dnn": 350,
        "specjbb": 150, "shore": 100, "moses": 9, "sphinx": 0.75}
DURATION = {"sphinx": 120.0, "moses": 40.0}
# multi-threaded servers: one instance already absorbs the offered load
WORKERS = {"silo": 8, "specjbb": 8}


def main() -> str:
    t0 = time.time()
    rows = []
    improved = 0
    for app, qps in LOAD.items():
        res = {}
        for n_srv in (1, 2):
            clients = [ClientConfig(i, ConstantQPS(qps / 3)) for i in range(3)]
            w = WORKERS.get(app, 1)
            exp = Experiment(clients=clients,
                             servers=tuple(ServerSpec(i, workers=w)
                                           for i in range(n_srv)),
                             app=app, duration=DURATION.get(app, 12.0),
                             policy="round_robin")
            from dataclasses import replace as _rp
            vals = {"p95": [], "p99": []}
            for rep in range(13):
                sim = run(_rp(exp, seed=exp.seed + 1000 * (rep + 1)))
                s_all = sim.telemetry.overall()
                vals["p95"].append(s_all.p95)
                vals["p99"].append(s_all.p99)
            for pct in ("p95", "p99"):
                mean, ci = confidence95(vals[pct])
                res[(n_srv, pct)] = (mean, ci)
                rows.append({"app": app, "servers": n_srv, "pct": pct,
                             "latency_s": f"{mean:.6f}", "ci95": f"{ci:.6f}"})
        # significant improvement = p99 gap larger than both CIs
        gap = res[(1, "p99")][0] - res[(2, "p99")][0]
        if gap > res[(1, "p99")][1] + res[(2, "p99")][1]:
            improved += 1
    emit("fig5_multiserver", rows, t0, f"apps_significantly_improved={improved}/8")
    return f"apps_significantly_improved={improved}/8"


if __name__ == "__main__":
    main()
