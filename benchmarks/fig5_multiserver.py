"""Paper Fig. 5: single- vs multi-server characterization, all 8 apps.

3 clients -> 1 vs 2 servers via round-robin LVS; p95/p99 with 95% CIs over
13 repetitions.  Expected: multi-server lowers tail latency for most apps;
apps whose bottleneck is not the server queue benefit least.

Declared as a ``repro.sweep`` grid (app x server-count, the paper's 13
repetitions) instead of the old hand-rolled repetition loop.  The custom
seeder replays that loop's exact derivation — ``seed + 1000*(rep+1)``
with repetition stream 0 — so the figure CSV is bit-identical to the
pre-sweep output.  (New sweeps should prefer the default ``"spawn"``
seeder, which cannot collide across grid points.)
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.client import ClientConfig, ConstantQPS
from repro.core.harness import Experiment, ServerSpec
from repro.sweep import Axis, PointCtx, Sweep, run_sweep

# silo/specjbb run far from server saturation (the paper observes they do
# not benefit from a second server — their bottleneck is not the queue).
LOAD = {"masstree": 1500, "silo": 300, "xapian": 450, "img-dnn": 350,
        "specjbb": 150, "shore": 100, "moses": 9, "sphinx": 0.75}
DURATION = {"sphinx": 120.0, "moses": 40.0}
# multi-threaded servers: one instance already absorbs the offered load
WORKERS = {"silo": 8, "specjbb": 8}
REPS = 13


def _point(ctx: PointCtx) -> Experiment:
    app, n_srv = ctx.params["app"], ctx.params["servers"]
    qps = LOAD[app]
    clients = [ClientConfig(i, ConstantQPS(qps / 3)) for i in range(3)]
    w = WORKERS.get(app, 1)
    return Experiment(clients=clients,
                      servers=tuple(ServerSpec(i, workers=w)
                                    for i in range(n_srv)),
                      app=app, duration=DURATION.get(app, 12.0),
                      policy="round_robin", seed=ctx.seed)


def _legacy_loop_seed(base: int, index: int, rep: int) -> tuple:
    """The pre-sweep repetition loop perturbed only the experiment seed
    (repetition stream stayed 0)."""
    return base + 1000 * (rep + 1), 0


SWEEP = Sweep(name="fig5_multiserver", factory=_point,
              axes=(Axis("app", tuple(LOAD)), Axis("servers", (1, 2))),
              reps=REPS, base_seed=0, seeder=_legacy_loop_seed,
              metrics=("p95", "p99"))


def main() -> str:
    t0 = time.time()
    frame = run_sweep(SWEEP, progress=None).raise_errors()
    agg = {pct: {(a["params"]["app"], a["params"]["servers"]):
                 (a["mean"], a["ci95"]) for a in frame.aggregate(pct)}
           for pct in ("p95", "p99")}
    rows = []
    improved = 0
    for app in LOAD:
        res = {}
        for n_srv in (1, 2):
            for pct in ("p95", "p99"):
                mean, ci = agg[pct][(app, n_srv)]
                res[(n_srv, pct)] = (mean, ci)
                rows.append({"app": app, "servers": n_srv, "pct": pct,
                             "latency_s": f"{mean:.6f}", "ci95": f"{ci:.6f}"})
        # significant improvement = p99 gap larger than both CIs
        gap = res[(1, "p99")][0] - res[(2, "p99")][0]
        if gap > res[(1, "p99")][1] + res[(2, "p99")][1]:
            improved += 1
    emit("fig5_multiserver", rows, t0, f"apps_significantly_improved={improved}/8")
    return f"apps_significantly_improved={improved}/8"


if __name__ == "__main__":
    main()
