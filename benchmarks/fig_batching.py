"""Batching validation sweep: does the simulator predict the engine's knee?

For several ``max_batch`` settings, sweep offered QPS over the
``batched-serving`` scenario on BOTH backends — the virtual-time
simulator and the wall-clock ``EngineRuntime`` driving
``BatchedStubEngine`` replicas (the same ``BatchedService`` +
``BatchScheduler`` dynamics the real engine's scheduler follows) — and
compare the p99-vs-QPS curves and their knees.

Declared as one ``repro.sweep`` grid with the RUNTIME BACKEND as an
axis (``runtime=sim,engine``): the executor builds the right runtime
per point, so the sim-vs-engine A/B is just another swept dimension.

The knee is the offered QPS at which p99 crosses ``KNEE_FACTOR`` x the
low-load p99 (log-interpolated between sweep points).  The acceptance
criterion is sim-predicted knees within 15% of the engine backend at
every max_batch — the measurement-fidelity property "Tell-Tale Tail
Latencies" demands of a service model: tail percentiles are only
trustworthy if the model matches the deployed server's concurrency.

Usage:
    PYTHONPATH=src:. python benchmarks/fig_batching.py           # full
    PYTHONPATH=src:. python benchmarks/fig_batching.py --quick
"""
from __future__ import annotations

import math
import sys
import time

from benchmarks.common import emit
from repro.core.profiles import TokenLengths
from repro.scenarios import get
from repro.scenarios.canonical import default_batched_service
from repro.sweep import Axis, PointCtx, Sweep, run_sweep

KNEE_FACTOR = 3.0          # p99 crossing vs the lowest swept load
MAX_BATCHES = (2, 4, 8)
N_SERVERS = 1
N_CLIENTS = 3
SEED = 13


def capacity_estimate(service, lengths, max_batch: int) -> float:
    """Requests/sec the fleet sustains at full occupancy.  Decode steps
    amortize across the batch (mean output tokens x step cost / slots),
    but prefills do NOT: the scheduler runs one op at a time, so every
    request serializes its full prefill on the server."""
    mean_new = lengths.mean_new_tokens
    decode_s = mean_new * service.step_time(max_batch) / max_batch
    prefill_s = service.prefill_time(int(lengths.prompt_median))
    return N_SERVERS / (decode_s + prefill_s)


def point_qps(max_batch: int, frac: float) -> float:
    service, lengths = default_batched_service(), TokenLengths()
    return round(frac * capacity_estimate(service, lengths, max_batch), 1)


def _point(ctx: PointCtx):
    service, lengths = default_batched_service(), TokenLengths()
    return get("batched-serving", seed=ctx.seed,
               duration=ctx.params["duration"],
               qps=point_qps(ctx.params["max_batch"], ctx.params["frac"]),
               n_clients=N_CLIENTS, n_servers=N_SERVERS,
               max_batch=ctx.params["max_batch"],
               service=service, lengths=lengths)


def knee_qps(points: list[tuple]) -> float:
    """Offered QPS where p99 crosses KNEE_FACTOR x the low-load p99,
    log-interpolated between the bracketing sweep points (inf if the
    sweep never saturates)."""
    base = points[0][1]
    thresh = KNEE_FACTOR * base
    for (q0, p0), (q1, p1) in zip(points, points[1:]):
        if p0 <= thresh < p1:
            f = (math.log(thresh) - math.log(p0)) \
                / (math.log(p1) - math.log(p0))
            return q0 + f * (q1 - q0)
    return float("inf")


def build_sweep(quick: bool) -> Sweep:
    duration = 8.0 if quick else 20.0
    fracs = ([0.4, 0.8, 1.0, 1.2] if quick
             else [0.3, 0.5, 0.7, 0.85, 0.95, 1.05, 1.15, 1.3])
    return Sweep(name="fig_batching", factory=_point,
                 axes=(Axis("max_batch", MAX_BATCHES),
                       Axis("frac", tuple(fracs)),
                       Axis("runtime", ("sim", "engine"))),
                 fixed={"duration": duration}, reps=1,
                 base_seed=SEED, seeder="fixed",
                 metrics=("n", "p50", "p95", "p99"))


def main() -> str:
    quick = "--quick" in sys.argv[1:]
    sweep = build_sweep(quick)
    t0 = time.time()
    frame = run_sweep(sweep, progress=None).raise_errors()
    rows, pts = [], {}
    for r in frame.rows:
        mb, backend = r.params["max_batch"], r.params["runtime"]
        qps, m = point_qps(mb, r.params["frac"]), r.metrics
        pts.setdefault((mb, backend), []).append((qps, m["p99"]))
        rows.append({"max_batch": mb, "backend": backend,
                     "offered_qps": qps, "n": m["n"],
                     "p50_ms": m["p50"] * 1e3, "p95_ms": m["p95"] * 1e3,
                     "p99_ms": m["p99"] * 1e3})
    ratios = {}
    for mb in MAX_BATCHES:
        cap = capacity_estimate(default_batched_service(), TokenLengths(), mb)
        k_sim, k_eng = knee_qps(pts[(mb, "sim")]), knee_qps(pts[(mb, "engine")])
        ratios[mb] = k_sim / k_eng if k_eng not in (0.0, float("inf")) \
            else float("nan")
        print(f"max_batch={mb}: capacity~{cap:.0f} qps, "
              f"knee sim={k_sim:.1f} engine={k_eng:.1f} "
              f"ratio={ratios[mb]:.3f}", file=sys.stderr)
    # a non-finite ratio means a max_batch setting was never actually
    # validated (the sweep found no knee on one backend) — that is a
    # failure, not a pass; never let max() silently drop a NaN
    worst = max((abs(r - 1.0) if math.isfinite(r) else float("inf"))
                for r in ratios.values())
    ok = worst <= 0.15
    derived = (f"knee_ratio_max_err={worst:.3f},within_15pct={ok},"
               + ",".join(f"mb{m}={r:.3f}" for m, r in ratios.items()))
    emit("fig_batching", rows, t0, derived)
    if not ok:
        print(f"FAIL: sim-vs-engine knee disagreement {worst:.1%} > 15%",
              file=sys.stderr)
        return derived
    return derived


if __name__ == "__main__":
    out = main()
    sys.exit(0 if "within_15pct=True" in out else 1)
