"""AdamW with memory-frugal moment dtypes (bf16 m / fp32 v by default).

The first moment tolerates bf16 (magnitude tracking); the second moment
needs fp32 (tiny values squared).  This is what lets jamba-398B training fit
a single 256-chip v5e pod under FSDP (see EXPERIMENTS.md §Dry-run memory).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    m_dtype: str = "bfloat16"      # bf16 first moment (ZeRO-friendly)
    v_dtype: str = "float32"
    schedule: str = "cosine"       # cosine | constant (post-warmup shape)


def init_opt_state(params, cfg: OptConfig):
    m = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, cfg.m_dtype), params)
    v = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, cfg.v_dtype), params)
    return {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}


def abstract_opt_state(abstract_params, cfg: OptConfig):
    m = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(cfg.m_dtype)), abstract_params)
    v = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(cfg.v_dtype)), abstract_params)
    return {"m": m, "v": v, "step": jax.ShapeDtypeStruct((), jnp.int32)}


def lr_at(cfg: OptConfig, step):
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    if cfg.schedule != "cosine":
        raise ValueError(f"unknown schedule {cfg.schedule!r}")
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(F32))) for l in leaves))


def adamw_update(params, grads, opt_state, cfg: OptConfig):
    """-> (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(F32)
    bc2 = 1 - b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m_new = b1 * m.astype(F32) + (1 - b1) * g
        v_new = b2 * v.astype(F32) + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        p_new = p.astype(F32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree_util.tree_map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
