"""Training step: chunked cross-entropy + grad accumulation + AdamW.

The unembed+softmax is scanned over sequence chunks so the (B,S,V) logits
tensor is never materialized (gemma3's 262k vocab would otherwise dominate
activation memory).  Gradient accumulation scans microbatches with fp32
grad accumulators.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import registry as R
from repro.training.optimizer import OptConfig, adamw_update

F32 = jnp.float32


def chunked_ce_loss(cfg: ArchConfig, params: dict, hidden: jax.Array,
                    targets: jax.Array, chunk: int = 512):
    """hidden: (B,S,D); targets: (B,S) with -1 = masked. -> (loss, metrics)."""
    from repro.util import cost_mode
    b, s, d = hidden.shape
    if cost_mode():
        chunk = s
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    hs = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(b, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, args):
        h, t = args
        from repro.models.layers import unembed
        logits = unembed(cfg, params, h).astype(F32)          # (B,chunk,V)
        mask = (t >= 0).astype(F32)
        tc = jnp.maximum(t, 0)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * mask
        correct = (jnp.argmax(logits, -1) == tc).astype(F32) * mask
        loss_sum, mask_sum, acc_sum = carry
        return (loss_sum + ce.sum(), mask_sum + mask.sum(),
                acc_sum + correct.sum()), None

    (loss_sum, mask_sum, acc_sum), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), (hs, ts))
    denom = jnp.maximum(mask_sum, 1.0)
    return loss_sum / denom, {"acc": acc_sum / denom, "tokens": mask_sum}


def make_loss_fn(cfg: ArchConfig, *, impl: str = "auto",
                 moe_impl: str = "dispatch", remat: bool = True):
    def loss_fn(params, batch):
        hidden = R.lm_hidden(cfg, params, batch, impl=impl, moe_impl=moe_impl,
                             remat=remat)
        return chunked_ce_loss(cfg, params, hidden, batch["targets"])
    return loss_fn


def make_train_step(cfg: ArchConfig, opt_cfg: OptConfig, *, impl: str = "auto",
                    moe_impl: str = "dispatch", remat: bool = True,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch leading dim = global batch; with microbatches>1 it is split and
    grads are accumulated in fp32 (overlap-friendly: each microbatch's
    reduce-scatter pipelines with the next microbatch's compute under XLA).
    """
    loss_fn = make_loss_fn(cfg, impl=impl, moe_impl=moe_impl, remat=remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, metrics, grads = single(params, batch)
        else:
            k = microbatches
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)

            def body(carry, b_i):
                loss_a, grads_a = carry
                loss, metrics, grads = single(params, b_i)
                grads_a = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(F32) / k, grads_a, grads)
                return (loss_a + loss / k, grads_a), metrics

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, F32), params)
            (loss, grads_f32), metrics = jax.lax.scan(body, (jnp.zeros(()), zeros), mb)
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
            grads = jax.tree_util.tree_map(
                lambda g, p: g.astype(p.dtype), grads_f32, params)
        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step
