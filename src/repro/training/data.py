"""Deterministic, resumable synthetic data pipeline.

Serves (tokens, targets) language-model batches from a counter-based PRNG:
``state`` is just the step index, so checkpoint/restore resumes the stream
bit-exactly (fault-tolerance test relies on this).  A host-side prefetch
thread hides generation latency behind the train step.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2          # token distribution skew (matches LM zipf)


class SyntheticLM:
    """Markov-ish synthetic token stream with Zipf-distributed vocabulary."""

    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = step
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self._p = p / p.sum()

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    @classmethod
    def from_state(cls, cfg: DataConfig, state: dict) -> "SyntheticLM":
        assert state["seed"] == cfg.seed, "data seed mismatch on restore"
        return cls(cfg, step=int(state["step"]))

    def next_batch(self) -> dict:
        rng = np.random.default_rng((self.cfg.seed, self.step))
        self.step += 1
        c = self.cfg
        toks = rng.choice(c.vocab_size, size=(c.batch, c.seq_len + 1), p=self._p)
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class Prefetcher:
    """One-deep host prefetch (hides np generation behind device step)."""

    def __init__(self, it, depth: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        while not self._stop.is_set():
            try:
                self._q.put(self._it.next_batch(), timeout=0.5)
            except queue.Full:
                continue

    def next_batch(self):
        return self._q.get()

    def close(self):
        self._stop.set()
