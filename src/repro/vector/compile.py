"""Lower an ``Experiment`` onto the vector runtime's array program.

``compile_experiment`` turns one compiled scenario point into a
``VectorProgram``: per-slot per-server offered-rate arrays (after a
scalar replay of the connection-level balancer assignment), capacity /
speed / liveness schedules, exact service-law moments for the CLT work
aggregation, and the batched-service token laws.  A program is built
ONCE per sweep point and shared by every repetition — repetitions
differ only in their RNG draws, which the runtime derives per cell.

Approximation contract (what makes this the statistically-equivalent
fast lane rather than a bit-identical replay):

* arrivals are slotted non-homogeneous Poisson (exact for the open-loop
  generators up to slot discretization);
* connection-level policies (round-robin, load-aware, least-
  connections) are replayed exactly as client->server rate assignment;
  request-level policies (jsq, p2c) become per-slot water-filling of
  the least-backlogged accepting servers — the fluid limit of JSQ;
* request hedging has no fluid analogue and is surfaced through
  ``unsupported`` (the scenario CLI prints the skip) instead of being
  silently dropped.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.harness import Experiment

#: request-level policies (per-slot water-fill); everything else is
#: replayed as connection-level assignment
FREE_POLICIES = ("jsq", "p2c")


class VectorCompileError(ValueError):
    """The experiment uses a feature the vector backend cannot lower."""


#: (schedule fingerprint, n_slots, repr(dt)) -> NaN-cleaned rate array.
#: Grids that sweep only capacity/policy repeat the SAME ``QPSSchedule``
#: across every cell; the rate evaluation (trace interpolation, diurnal
#: curves) is the dominant compile cost there, so compute it once per
#: unique schedule.  Cached arrays are frozen (read-only views) and the
#: memo is content-keyed, so sharing cannot change any program's bits.
_RATE_CACHE: OrderedDict = OrderedDict()
_RATE_CACHE_CAP = 256


def _schedule_rates(schedule, centers: np.ndarray, n_slots: int,
                    dt: float) -> np.ndarray:
    from repro.cache.fingerprint import Unfingerprintable, fingerprint
    try:
        key = (fingerprint(schedule), n_slots, repr(float(dt)))
    except Unfingerprintable:
        r = np.asarray(schedule.rate_array(centers), float)
        return np.where(np.isnan(r), 0.0, r)
    r = _RATE_CACHE.get(key)
    if r is None:
        r = np.asarray(schedule.rate_array(centers), float)
        r = np.where(np.isnan(r), 0.0, r)
        r.setflags(write=False)
        _RATE_CACHE[key] = r
        while len(_RATE_CACHE) > _RATE_CACHE_CAP:
            _RATE_CACHE.popitem(last=False)
    else:
        _RATE_CACHE.move_to_end(key)
    return r


@dataclass
class VectorProgram:
    """Structure-of-arrays form of one experiment point."""
    dt: float
    n_slots: int
    duration: float
    interval: float
    slo: Optional[float]
    server_ids: list                    # column -> server_id
    workers: np.ndarray                 # [S] capacity slots per server
    speed: np.ndarray                   # [T, S] execution speed factor
    active: np.ndarray                  # [T, S] 1.0 while serving capacity
    accepting: np.ndarray               # [T, S] 1.0 while routable
    fail_slot: np.ndarray               # [S] failing slot index, -1 = never
    rate_conn: np.ndarray               # [T, S] connection-assigned QPS
    rate_free: np.ndarray               # [T] request-level-routed QPS
    # scalar service law (per-server: execution noise folds in)
    work_mean: np.ndarray               # [S] E[service work] seconds
    work_var: np.ndarray                # [S] Var[service work]
    noise_sigma: np.ndarray             # [S] log-sigma of execution noise
    profile: object = None              # per-request demand law (sampling)
    # batched continuous-batching law
    batched: bool = False
    service: object = None              # BatchedService when batched
    lengths: object = None              # TokenLengths when batched
    max_batch: int = 8
    prefill_mean: float = 0.0           # E[prefill seconds] per request
    prefill_var: float = 0.0
    new_mean: float = 1.0               # E[decode tokens] per request
    new_var: float = 0.0
    refused_clients: int = 0            # connects the balancer refused
    # admission control (fluid limit): per-slot admit fraction applied by
    # Poisson thinning (statistically exact for Poisson arrivals), and
    # the shed-rate timeline it implies.  None = fully open throughout.
    admit: Optional[np.ndarray] = None  # [T] admitted fraction
    shed_rate: Optional[np.ndarray] = None   # [T] shed QPS
    # actions the control pre-pass emitted: (t_applied, kind, params),
    # same shape as the event backends' ``control_log``
    control_actions: list = field(default_factory=list)
    unsupported: list = field(default_factory=list)

    @property
    def n_servers(self) -> int:
        return len(self.server_ids)


# ---------------------------------------------------------------------------
# Connection-assignment replay (scalar, once per point)
# ---------------------------------------------------------------------------
class _ReplayPolicy:
    """Replays the ``Balancer.assign`` criterion of the named policy
    over the scenario's connect/end/join/drain/fail timeline — a few
    dozen scalar steps per point, never per request."""

    def __init__(self, name: str):
        self.name = name
        self.rr = 0
        self.subscribed: dict[int, float] = {}       # sid -> offered QPS
        self.client_sub: dict[int, tuple] = {}       # cid -> (sid, qps)
        self.conn_count: dict[int, int] = {}         # sid -> live clients

    def assign(self, cid: int, qps: float, alive: list) -> Optional[int]:
        if not alive:
            return None
        if self.name == "load_aware":
            sid = min(alive, key=lambda s: self.subscribed.get(s, 0.0))
            self.subscribed[sid] = self.subscribed.get(sid, 0.0) + qps
            self.client_sub[cid] = (sid, qps)
        elif self.name == "least_connections":
            sid = min(alive, key=lambda s: self.conn_count.get(s, 0))
        else:                       # round_robin and the jsq/p2c stand-in
            sid = alive[self.rr % len(alive)]
            self.rr += 1
        self.conn_count[sid] = self.conn_count.get(sid, 0) + 1
        return sid

    def release(self, cid: int, sid: Optional[int]) -> None:
        sub = self.client_sub.pop(cid, None)
        if sub is not None:
            s, qps = sub
            self.subscribed[s] = max(0.0, self.subscribed.get(s, 0.0) - qps)
        if sid is not None and self.conn_count.get(sid, 0) > 0:
            self.conn_count[sid] -= 1


def compile_experiment(exp: Experiment, dt: float = 0.005) -> VectorProgram:
    from repro.core.profiles import TokenLengths

    if exp.legacy_mode:
        raise VectorCompileError("vector backend does not support "
                                 "legacy_mode (use the event engine)")
    n_slots = max(1, int(math.ceil(exp.duration / dt)))
    centers = (np.arange(n_slots) + 0.5) * dt

    # ---- server schedules --------------------------------------------------
    specs = list(exp.servers)
    server_ids = [s.server_id for s in specs]
    col = {sid: j for j, sid in enumerate(server_ids)}
    S = len(specs)
    workers = np.array([float(s.workers if s.workers else 1) for s in specs])
    speed = np.tile(np.array([float(s.speed) for s in specs]), (n_slots, 1))
    active = np.ones((n_slots, S))
    accepting = np.ones((n_slots, S))
    fail_slot = np.full(S, -1, dtype=np.int64)
    noise_sigma = np.array([float(s.service_noise) for s in specs])
    drain_slots: list[tuple] = []               # (slot, col) re-assert marks
    for j, s in enumerate(specs):
        if s.join_at > 0.0:
            k = min(int(s.join_at / dt), n_slots)
            active[:k, j] = 0.0
            accepting[:k, j] = 0.0
        if s.drain_at is not None:
            k = min(int(s.drain_at / dt), n_slots)
            accepting[k:, j] = 0.0
            drain_slots.append((k, j))
        if s.standby:
            # standby pool: no capacity and no routing until a scale
            # action activates the column
            active[:, j] = 0.0
            accepting[:, j] = 0.0

    unsupported = []
    policy_changes: list[tuple] = []            # (t, seq, policy-name)
    admission_changes: list[tuple] = []         # (t, seq, params)
    scale_changes: list[tuple] = []             # (t, seq, n)
    if exp.hedge_delay is not None:
        from repro.core.scenario import Injection
        unsupported.append(Injection(0.0, "set_hedge",
                                     {"delay": exp.hedge_delay}))
    # retries and circuit breaking are per-request mechanisms with no
    # fluid analogue — surface them instead of silently ignoring
    if exp.retry is not None:
        from repro.core.scenario import Injection
        unsupported.append(Injection(0.0, "set_retry",
                                     {"policy": exp.retry}))
    if exp.breaker is not None:
        from repro.core.scenario import Injection
        unsupported.append(Injection(0.0, "set_breaker",
                                     {"spec": exp.breaker}))
    for inj in exp.injections:
        if inj.kind == "server_fail":
            j = col[inj.params["server_id"]]
            k = min(int(inj.at / dt), n_slots)
            active[k:, j] = 0.0
            accepting[k:, j] = 0.0
            fail_slot[j] = k if k < n_slots else -1
        elif inj.kind == "server_speed":
            j = col[inj.params["server_id"]]
            k = min(int(inj.at / dt), n_slots)
            speed[k:, j] *= float(inj.params["factor"])
        elif inj.kind == "server_drain":
            j = col[inj.params["server_id"]]
            k = min(int(inj.at / dt), n_slots)
            accepting[k:, j] = 0.0
            drain_slots.append((k, j))
        elif inj.kind == "set_policy":
            policy_changes.append((inj.at, inj.seq, inj.params["policy"]))
        elif inj.kind == "set_admission":
            admission_changes.append((inj.at, inj.seq, dict(inj.params)))
        elif inj.kind == "set_scale":
            scale_changes.append((inj.at, inj.seq, int(inj.params["n"])))
        else:           # set_hedge/set_retry/set_breaker, injected joins
            unsupported.append(inj)
    policy_changes.sort(key=lambda c: (c[0], c[1]))

    # ---- per-client offered rates ------------------------------------------
    # rate[c, t], plus each client's connect time and effective end
    clients = list(exp.clients)
    rates = np.zeros((len(clients), n_slots))
    ends = np.full(len(clients), exp.duration)
    for i, c in enumerate(clients):
        r = _schedule_rates(c.schedule, centers, n_slots, dt)
        end = min(c.end_time, exp.duration) if c.end_time is not None \
            else exp.duration
        masked = np.where((centers >= c.start_time) & (centers < end),
                          r, 0.0)
        if c.total_requests is not None:
            # fluid budget stop: zero the rate once the expected arrival
            # count crosses the client's request budget
            end = min(end, _budget_stop(masked, dt, c.total_requests))
            masked = np.where(centers < end, masked, 0.0)
        rates[i] = masked
        ends[i] = end

    # ---- closed-loop control: fluid pre-pass -------------------------------
    # Replays the controller against the fluid backlog model (offered
    # rate vs capacity), emitting the same set_admission/set_scale
    # actions the event backends would apply — lag and cooldown
    # included.  Latency percentiles have no cheap fluid analogue, so
    # the observation's p99/slo_frac are NaN; the shipped policies act
    # on utilization and queue depth, which the model does carry.
    control_actions: list = []
    if exp.control is not None:
        from repro.core.scenario import Injection
        if getattr(exp.resolved_service(), "kind", "scalar") == "batched":
            unsupported.append(Injection(0.0, "control",
                                         {"spec": exp.control}))
        else:
            m0 = exp.resolved_profile().moments()[0]
            w_mean = m0 * np.exp(noise_sigma ** 2 / 2.0)
            adm_c, scale_c = _control_prepass(
                exp.control, rates.sum(axis=0), active, accepting, speed,
                workers, w_mean, specs, server_ids, fail_slot, drain_slots,
                admission_changes, scale_changes, dt, n_slots)
            admission_changes = admission_changes + adm_c
            scale_changes = scale_changes + scale_c
            control_actions = sorted(
                [(t, "set_admission", dict(p)) for t, _, p in adm_c]
                + [(t, "set_scale", {"n": n}) for t, _, n in scale_c],
                key=lambda a: a[0])

    # ---- scale timeline ----------------------------------------------------
    # apply chronologically so a scale-out cannot clobber a later drain
    # (each action re-asserts failures and still-future drain marks)
    scale_changes.sort(key=lambda c: (c[0], c[1]))
    for at, _seq, n in scale_changes:
        k = min(int(at / dt), n_slots)
        _apply_scale_action(active, accepting, k, n, specs, server_ids,
                            fail_slot, drain_slots, at)

    # ---- assignment replay -------------------------------------------------
    # chronological events; ties follow the simulator's scheduling order
    # (connects first, then joins/drains, then injections — and
    # same-kind injections at identical timestamps interleave in
    # declaration order via the compiled (at, seq) stamp)
    events: list[tuple] = []
    for i, c in enumerate(clients):
        events.append((c.start_time, 0, i, "connect", i))
        events.append((ends[i], 3, i, "end", i))
    for j, s in enumerate(specs):
        if s.join_at > 0.0:
            events.append((s.join_at, 1, j, "join", j))
        if s.drain_at is not None:
            events.append((s.drain_at, 1, j, "drain", j))
    for inj in exp.injections:
        if inj.kind == "server_fail":
            events.append((inj.at, 2, inj.seq, "fail",
                           col[inj.params["server_id"]]))
    for at, seq, pol in policy_changes:
        events.append((at, 2, seq, "policy", pol))
    for at, seq, n in scale_changes:
        events.append((at, 2, seq, "scale", n))
    events.sort(key=lambda e: (e[0], e[1], e[2]))

    if isinstance(exp.policy, str):
        policy = exp.policy
    else:                       # balancer instance: map back to its name
        policy = {"RoundRobin": "round_robin", "LoadAware": "load_aware",
                  "LeastConnections": "least_connections",
                  "JoinShortestQueue": "jsq", "PowerOfTwo": "p2c",
                  }.get(type(exp.policy).__name__, "round_robin")
    replay = _ReplayPolicy(policy)
    free_mode = policy in FREE_POLICIES

    rate_conn = np.zeros((n_slots, S))
    rate_free = np.zeros(n_slots)
    assignment: dict[int, int] = {}            # client idx -> server col
    seg_start: dict[int, float] = {}           # client idx -> segment start
    alive_cols: list[int] = [j for j, s in enumerate(specs)
                             if s.join_at == 0.0 and not s.standby]
    drained: set[int] = set()
    failed_cols: set[int] = set()
    refused = 0

    def slot_range(t0: float, t1: float) -> slice:
        a = np.searchsorted(centers, t0)
        b = np.searchsorted(centers, min(t1, exp.duration))
        return slice(int(a), int(b))

    def close_segment(i: int, t: float) -> None:
        t0 = seg_start.pop(i, None)
        if t0 is None:
            return
        sl = slot_range(t0, t)
        if free_mode or i not in assignment:
            rate_free[sl] += rates[i, sl]
        else:
            rate_conn[sl, assignment[i]] += rates[i, sl]

    def _rehome(i: int, t: float) -> None:
        """Close the client's segment and reassign it through the
        policy (the fallback keeps it pumping as request-routed)."""
        close_segment(i, t)
        replay.release(i, assignment.pop(i, None))
        c = clients[i]
        sid = replay.assign(i, c.schedule.rate(t), alive_cols)
        seg_start[i] = t
        if sid is not None:
            assignment[i] = sid

    live: set[int] = set()
    for t, _, _, kind, arg in events:
        if kind == "connect":
            i = arg
            c = clients[i]
            qps = c.schedule.rate(c.start_time)
            sid = replay.assign(i, qps, alive_cols)
            if sid is None:
                refused += 1
                continue
            assignment[i] = sid
            seg_start[i] = t
            live.add(i)
        elif kind == "end":
            i = arg
            if i not in live:
                continue
            close_segment(i, t)
            replay.release(i, assignment.pop(i, None))
            live.discard(i)
        elif kind == "join":
            j = arg
            if j not in alive_cols and j not in drained:
                alive_cols.append(j)
        elif kind == "drain":
            j = arg
            drained.add(j)
            if j in alive_cols:
                alive_cols.remove(j)
            # existing clients keep their assignment (sim semantics)
        elif kind == "fail":
            j = arg
            drained.add(j)
            failed_cols.add(j)
            if j in alive_cols:
                alive_cols.remove(j)
            # clients on the failed server re-home through the policy; a
            # client no accepting server will take keeps pumping as
            # request-routed (water-filled) traffic, like the sim's
            # per-request choose() fallback
            for i in sorted(i for i, s in assignment.items() if s == j):
                _rehome(i, t)
        elif kind == "scale":
            # mirror Simulator.scale_to: the first n existing, non-failed
            # servers (in server-id order) serve; the rest drain and hand
            # their clients back through the policy
            pool = [j for j in range(S)
                    if j not in failed_cols
                    and (specs[j].standby or specs[j].join_at <= t)]
            pool.sort(key=lambda j: server_ids[j])
            target = set(pool[:arg])
            for j in pool:
                if j in target and j not in alive_cols:
                    alive_cols.append(j)
                    drained.discard(j)
                elif j not in target and j in alive_cols:
                    alive_cols.remove(j)
                    drained.add(j)
                    for i in sorted(i for i, s_ in assignment.items()
                                    if s_ == j):
                        _rehome(i, t)
        elif kind == "policy":
            new_free = arg in FREE_POLICIES
            if new_free != free_mode:
                for i in list(live):
                    close_segment(i, t)
                    seg_start[i] = t
            free_mode = new_free
            replay.name = arg
    for i in list(live):
        close_segment(i, exp.duration)

    # ---- admission control: Poisson thinning -------------------------------
    # An admitted fraction f applied to a Poisson arrival stream IS a
    # Poisson stream at f*rate (thinning) — statistically exact for the
    # probabilistic controller; a token bucket's fluid limit is the rate
    # cap min(offered, R), i.e. f = min(1, R/offered) per slot.
    admit_arr = None
    shed_rate = None
    if admission_changes:
        offered_total = rate_conn.sum(axis=1) + rate_free
        admit_arr = np.ones(n_slots)
        for at, _seq, p in sorted(admission_changes,
                                  key=lambda c: (c[0], c[1])):
            k = min(int(at / dt), n_slots)
            a, r = p.get("admit"), p.get("rate")
            if r is not None:
                seg = offered_total[k:]
                admit_arr[k:] = np.where(seg > 0.0,
                                         np.minimum(1.0, r
                                                    / np.maximum(seg, 1e-300)),
                                         1.0)
            elif a is None or a >= 1.0:
                admit_arr[k:] = 1.0
            else:
                admit_arr[k:] = max(float(a), 0.0)
        if np.all(admit_arr >= 1.0 - 1e-12):
            admit_arr = None
        else:
            shed_rate = offered_total * (1.0 - admit_arr)
            rate_conn = rate_conn * admit_arr[:, None]
            rate_free = rate_free * admit_arr

    # ---- service laws ------------------------------------------------------
    service = exp.resolved_service()
    batched = getattr(service, "kind", "scalar") == "batched"
    prog = VectorProgram(
        dt=dt, n_slots=n_slots, duration=exp.duration,
        interval=exp.interval, slo=exp.slo, server_ids=server_ids,
        workers=workers, speed=speed, active=active, accepting=accepting,
        fail_slot=fail_slot, rate_conn=rate_conn, rate_free=rate_free,
        work_mean=np.ones(S), work_var=np.zeros(S),
        noise_sigma=noise_sigma, refused_clients=refused,
        admit=admit_arr, shed_rate=shed_rate,
        control_actions=control_actions, unsupported=unsupported)
    if batched:
        lengths = exp.resolved_lengths() or TokenLengths()
        (pm, pv), (nm, nv) = lengths.moments()
        # prefill seconds = max(tp * prompt, t_memory): moments over the
        # integer prompt pmf, floored at the weight-pass time
        pf_m, pf_v = _prefill_moments(service, lengths)
        prog.batched = True
        prog.service = service
        prog.lengths = lengths
        prog.max_batch = int(specs[0].max_batch or 8)
        prog.workers = np.array([float(s.max_batch or 8) for s in specs])
        prog.prefill_mean, prog.prefill_var = pf_m, pf_v
        prog.new_mean, prog.new_var = nm, nv
    else:
        profile = exp.resolved_profile()
        m, v = profile.moments()
        e2 = v + m * m
        # execution noise is multiplicative log-normal per server: fold
        # its moments into the per-server work law
        nf1 = np.exp(noise_sigma ** 2 / 2.0)
        nf2 = np.exp(2.0 * noise_sigma ** 2)
        prog.work_mean = m * nf1
        prog.work_var = np.maximum(e2 * nf2 - prog.work_mean ** 2, 0.0)
        prog.profile = profile
    return prog


def _apply_scale_action(active: np.ndarray, accepting: np.ndarray, k: int,
                        n: int, specs, server_ids, fail_slot: np.ndarray,
                        drain_slots, t: float) -> None:
    """Write one ``set_scale`` action into the capacity schedules at slot
    ``k``: the first ``n`` existing, non-failed servers (server-id order)
    serve from here; the rest stop accepting (their residual backlog
    still drains, matching ``server_drain`` semantics).  Failures and
    still-future drain marks are re-asserted so a scale-out cannot
    resurrect a dead server or erase a scheduled drain."""
    n_slots = active.shape[0]
    pool = [j for j in range(len(specs))
            if not (fail_slot[j] != -1 and fail_slot[j] <= k)
            and (specs[j].standby or specs[j].join_at <= t)]
    pool.sort(key=lambda j: server_ids[j])
    for j in pool[:n]:
        active[k:, j] = 1.0
        accepting[k:, j] = 1.0
    for j in pool[n:]:
        accepting[k:, j] = 0.0
    for j in range(len(specs)):
        fs = fail_slot[j]
        if fs != -1 and fs < n_slots:
            active[fs:, j] = 0.0
            accepting[fs:, j] = 0.0
    for kd, j in drain_slots:
        if kd >= k:
            accepting[kd:, j] = 0.0


def _control_prepass(spec, offered: np.ndarray, active: np.ndarray,
                     accepting: np.ndarray, speed: np.ndarray,
                     workers: np.ndarray, w_mean: np.ndarray, specs,
                     server_ids, fail_slot: np.ndarray, drain_slots,
                     inj_admissions, inj_scales, dt: float,
                     n_slots: int) -> tuple[list, list]:
    """Replay the controller against the fluid backlog model.

    Steps the total offered rate against fleet capacity slot by slot,
    maintaining a global backlog ``U`` (work-seconds); at each control
    interval it builds an ``Observation`` (util, queue depth, served
    count — p99/slo_frac are NaN in the fluid world) and lets the policy
    act, honoring cooldown and actuation lag.  Injected admission/scale
    timelines are applied inside the stepping so the controller sees
    their effects.  Returns the controller-emitted ``(t, seq, params)``
    admission changes and ``(t, seq, n)`` scale changes; control seqs
    start at 10**6, ordering them after compiled injections at identical
    timestamps (the event backends schedule lagged actions the same way).
    """
    import heapq as _heapq
    import itertools as _it

    from repro.control import ControlLoop
    from repro.control.policy import Observation

    loop = ControlLoop(spec)
    act2 = active.copy()
    acc2 = accepting.copy()
    ctrl_seq = _it.count(10 ** 6)
    pending: list = []                 # (slot, seq, kind, payload)
    for at, seq, p in inj_admissions:
        _heapq.heappush(pending, (min(int(at / dt), n_slots), seq,
                                  "set_admission", dict(p)))
    for at, seq, n in inj_scales:
        _heapq.heappush(pending, (min(int(at / dt), n_slots), seq,
                                  "set_scale", (n, at)))
    out_adm: list = []
    out_scale: list = []
    admit_p: Optional[float] = None    # probabilistic admit fraction
    rate_cap: Optional[float] = None   # token-bucket rate cap
    fleet_w = float(w_mean.mean()) if len(w_mean) else 1.0
    U = 0.0                            # backlog, work-seconds
    served_win = 0.0                   # served requests since last tick
    next_tick = spec.interval
    cap_w = workers * speed / np.maximum(w_mean, 1e-12)   # [T, S] req/s
    for k in range(n_slots):
        while pending and pending[0][0] <= k:
            _, _, kind, payload = _heapq.heappop(pending)
            if kind == "set_admission":
                a, r = payload.get("admit"), payload.get("rate")
                if r is not None:
                    admit_p, rate_cap = None, float(r)
                elif a is None or a >= 1.0:
                    admit_p, rate_cap = None, None
                else:
                    admit_p, rate_cap = max(float(a), 0.0), None
            else:
                n, at = payload
                _apply_scale_action(act2, acc2, k, n, specs, server_ids,
                                    fail_slot, drain_slots, at)
        off = float(offered[k])
        if rate_cap is not None:
            f = min(1.0, rate_cap / off) if off > 0.0 else 1.0
        elif admit_p is not None:
            f = admit_p
        else:
            f = 1.0
        lam = off * f
        cap = float((acc2[k] * cap_w[k]).sum())
        serve = min(cap, lam + U / dt)
        U = max(U + (lam - serve) * dt, 0.0)
        served_win += serve * dt
        t_end = (k + 1) * dt
        while next_tick <= t_end + 1e-12:
            nact = int(np.count_nonzero(acc2[min(k, n_slots - 1)]))
            util = 1.0 if U > 1e-9 else (min(lam / cap, 1.0)
                                         if cap > 0.0 else 1.0)
            obs = Observation(t=next_tick, n=int(round(served_win)),
                              qps=served_win / spec.interval,
                              p99=float("nan"), mean=float("nan"),
                              util=util, qdepth=U / max(fleet_w, 1e-12),
                              slo_frac=float("nan"), n_active=max(nact, 1),
                              admit=f)
            served_win = 0.0
            for kind, params in loop.tick(obs, next_tick):
                due = next_tick + spec.lag
                seq = next(ctrl_seq)
                k_due = min(int(due / dt), n_slots)
                if kind == "set_admission":
                    out_adm.append((due, seq, dict(params)))
                    _heapq.heappush(pending, (k_due, seq, "set_admission",
                                              dict(params)))
                elif kind == "set_scale":
                    n = int(params["n"])
                    out_scale.append((due, seq, n))
                    _heapq.heappush(pending, (k_due, seq, "set_scale",
                                              (n, due)))
            next_tick += spec.interval
    return out_adm, out_scale


def _budget_stop(rate: np.ndarray, dt: float, budget: int) -> float:
    """Absolute stop time of a budgeted client (expected-count crossing)."""
    cum = np.cumsum(rate) * dt
    idx = int(np.searchsorted(cum, float(budget)))
    if idx >= len(rate):
        return math.inf
    return (idx + 1) * dt


def _prefill_moments(service, lengths) -> tuple[float, float]:
    """Exact moments of ``prefill_time(prompt)`` over the clipped
    integer prompt law (shared pmf: ``TokenLengths.int_pmf``)."""
    from repro.core.profiles import TokenLengths
    ks, pmf = TokenLengths.int_pmf(lengths.prompt_median,
                                   lengths.prompt_sigma,
                                   lengths.prompt_max)
    pf = np.maximum(service.t_prefill_per_token * ks, service.t_memory)
    m = float(pmf @ pf)
    return m, max(float(pmf @ (pf * pf)) - m * m, 0.0)
