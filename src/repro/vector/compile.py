"""Lower an ``Experiment`` onto the vector runtime's array program.

``compile_experiment`` turns one compiled scenario point into a
``VectorProgram``: per-slot per-server offered-rate arrays (after a
scalar replay of the connection-level balancer assignment), capacity /
speed / liveness schedules, exact service-law moments for the CLT work
aggregation, and the batched-service token laws.  A program is built
ONCE per sweep point and shared by every repetition — repetitions
differ only in their RNG draws, which the runtime derives per cell.

Approximation contract (what makes this the statistically-equivalent
fast lane rather than a bit-identical replay):

* arrivals are slotted non-homogeneous Poisson (exact for the open-loop
  generators up to slot discretization);
* connection-level policies (round-robin, load-aware, least-
  connections) are replayed exactly as client->server rate assignment;
  request-level policies (jsq, p2c) become per-slot water-filling of
  the least-backlogged accepting servers — the fluid limit of JSQ;
* request hedging has no fluid analogue and is surfaced through
  ``unsupported`` (the scenario CLI prints the skip) instead of being
  silently dropped.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.harness import Experiment

#: request-level policies (per-slot water-fill); everything else is
#: replayed as connection-level assignment
FREE_POLICIES = ("jsq", "p2c")


class VectorCompileError(ValueError):
    """The experiment uses a feature the vector backend cannot lower."""


#: (schedule fingerprint, n_slots, repr(dt)) -> NaN-cleaned rate array.
#: Grids that sweep only capacity/policy repeat the SAME ``QPSSchedule``
#: across every cell; the rate evaluation (trace interpolation, diurnal
#: curves) is the dominant compile cost there, so compute it once per
#: unique schedule.  Cached arrays are frozen (read-only views) and the
#: memo is content-keyed, so sharing cannot change any program's bits.
_RATE_CACHE: OrderedDict = OrderedDict()
_RATE_CACHE_CAP = 256


def _schedule_rates(schedule, centers: np.ndarray, n_slots: int,
                    dt: float) -> np.ndarray:
    from repro.cache.fingerprint import Unfingerprintable, fingerprint
    try:
        key = (fingerprint(schedule), n_slots, repr(float(dt)))
    except Unfingerprintable:
        r = np.asarray(schedule.rate_array(centers), float)
        return np.where(np.isnan(r), 0.0, r)
    r = _RATE_CACHE.get(key)
    if r is None:
        r = np.asarray(schedule.rate_array(centers), float)
        r = np.where(np.isnan(r), 0.0, r)
        r.setflags(write=False)
        _RATE_CACHE[key] = r
        while len(_RATE_CACHE) > _RATE_CACHE_CAP:
            _RATE_CACHE.popitem(last=False)
    else:
        _RATE_CACHE.move_to_end(key)
    return r


@dataclass
class VectorProgram:
    """Structure-of-arrays form of one experiment point."""
    dt: float
    n_slots: int
    duration: float
    interval: float
    slo: Optional[float]
    server_ids: list                    # column -> server_id
    workers: np.ndarray                 # [S] capacity slots per server
    speed: np.ndarray                   # [T, S] execution speed factor
    active: np.ndarray                  # [T, S] 1.0 while serving capacity
    accepting: np.ndarray               # [T, S] 1.0 while routable
    fail_slot: np.ndarray               # [S] failing slot index, -1 = never
    rate_conn: np.ndarray               # [T, S] connection-assigned QPS
    rate_free: np.ndarray               # [T] request-level-routed QPS
    # scalar service law (per-server: execution noise folds in)
    work_mean: np.ndarray               # [S] E[service work] seconds
    work_var: np.ndarray                # [S] Var[service work]
    noise_sigma: np.ndarray             # [S] log-sigma of execution noise
    profile: object = None              # per-request demand law (sampling)
    # batched continuous-batching law
    batched: bool = False
    service: object = None              # BatchedService when batched
    lengths: object = None              # TokenLengths when batched
    max_batch: int = 8
    prefill_mean: float = 0.0           # E[prefill seconds] per request
    prefill_var: float = 0.0
    new_mean: float = 1.0               # E[decode tokens] per request
    new_var: float = 0.0
    refused_clients: int = 0            # connects the balancer refused
    unsupported: list = field(default_factory=list)

    @property
    def n_servers(self) -> int:
        return len(self.server_ids)


# ---------------------------------------------------------------------------
# Connection-assignment replay (scalar, once per point)
# ---------------------------------------------------------------------------
class _ReplayPolicy:
    """Replays the ``Balancer.assign`` criterion of the named policy
    over the scenario's connect/end/join/drain/fail timeline — a few
    dozen scalar steps per point, never per request."""

    def __init__(self, name: str):
        self.name = name
        self.rr = 0
        self.subscribed: dict[int, float] = {}       # sid -> offered QPS
        self.client_sub: dict[int, tuple] = {}       # cid -> (sid, qps)
        self.conn_count: dict[int, int] = {}         # sid -> live clients

    def assign(self, cid: int, qps: float, alive: list) -> Optional[int]:
        if not alive:
            return None
        if self.name == "load_aware":
            sid = min(alive, key=lambda s: self.subscribed.get(s, 0.0))
            self.subscribed[sid] = self.subscribed.get(sid, 0.0) + qps
            self.client_sub[cid] = (sid, qps)
        elif self.name == "least_connections":
            sid = min(alive, key=lambda s: self.conn_count.get(s, 0))
        else:                       # round_robin and the jsq/p2c stand-in
            sid = alive[self.rr % len(alive)]
            self.rr += 1
        self.conn_count[sid] = self.conn_count.get(sid, 0) + 1
        return sid

    def release(self, cid: int, sid: Optional[int]) -> None:
        sub = self.client_sub.pop(cid, None)
        if sub is not None:
            s, qps = sub
            self.subscribed[s] = max(0.0, self.subscribed.get(s, 0.0) - qps)
        if sid is not None and self.conn_count.get(sid, 0) > 0:
            self.conn_count[sid] -= 1


def compile_experiment(exp: Experiment, dt: float = 0.005) -> VectorProgram:
    from repro.core.profiles import TokenLengths

    if exp.legacy_mode:
        raise VectorCompileError("vector backend does not support "
                                 "legacy_mode (use the event engine)")
    n_slots = max(1, int(math.ceil(exp.duration / dt)))
    centers = (np.arange(n_slots) + 0.5) * dt

    # ---- server schedules --------------------------------------------------
    specs = list(exp.servers)
    server_ids = [s.server_id for s in specs]
    col = {sid: j for j, sid in enumerate(server_ids)}
    S = len(specs)
    workers = np.array([float(s.workers if s.workers else 1) for s in specs])
    speed = np.tile(np.array([float(s.speed) for s in specs]), (n_slots, 1))
    active = np.ones((n_slots, S))
    accepting = np.ones((n_slots, S))
    fail_slot = np.full(S, -1, dtype=np.int64)
    noise_sigma = np.array([float(s.service_noise) for s in specs])
    for j, s in enumerate(specs):
        if s.join_at > 0.0:
            k = min(int(s.join_at / dt), n_slots)
            active[:k, j] = 0.0
            accepting[:k, j] = 0.0
        if s.drain_at is not None:
            k = min(int(s.drain_at / dt), n_slots)
            accepting[k:, j] = 0.0

    unsupported = []
    policy_changes: list[tuple] = []            # (t, policy-name)
    if exp.hedge_delay is not None:
        from repro.core.scenario import Injection
        unsupported.append(Injection(0.0, "set_hedge",
                                     {"delay": exp.hedge_delay}))
    for inj in exp.injections:
        if inj.kind == "server_fail":
            j = col[inj.params["server_id"]]
            k = min(int(inj.at / dt), n_slots)
            active[k:, j] = 0.0
            accepting[k:, j] = 0.0
            fail_slot[j] = k if k < n_slots else -1
        elif inj.kind == "server_speed":
            j = col[inj.params["server_id"]]
            k = min(int(inj.at / dt), n_slots)
            speed[k:, j] *= float(inj.params["factor"])
        elif inj.kind == "server_drain":
            j = col[inj.params["server_id"]]
            k = min(int(inj.at / dt), n_slots)
            accepting[k:, j] = 0.0
        elif inj.kind == "set_policy":
            policy_changes.append((inj.at, inj.params["policy"]))
        else:                       # set_hedge, server_join via injection
            unsupported.append(inj)
    policy_changes.sort(key=lambda c: c[0])

    # ---- per-client offered rates ------------------------------------------
    # rate[c, t], plus each client's connect time and effective end
    clients = list(exp.clients)
    rates = np.zeros((len(clients), n_slots))
    ends = np.full(len(clients), exp.duration)
    for i, c in enumerate(clients):
        r = _schedule_rates(c.schedule, centers, n_slots, dt)
        end = min(c.end_time, exp.duration) if c.end_time is not None \
            else exp.duration
        masked = np.where((centers >= c.start_time) & (centers < end),
                          r, 0.0)
        if c.total_requests is not None:
            # fluid budget stop: zero the rate once the expected arrival
            # count crosses the client's request budget
            end = min(end, _budget_stop(masked, dt, c.total_requests))
            masked = np.where(centers < end, masked, 0.0)
        rates[i] = masked
        ends[i] = end

    # ---- assignment replay -------------------------------------------------
    # chronological events; ties follow the simulator's scheduling order
    # (connects first, then joins/drains, then injections)
    events: list[tuple] = []
    for i, c in enumerate(clients):
        events.append((c.start_time, 0, "connect", i))
        events.append((ends[i], 3, "end", i))
    for j, s in enumerate(specs):
        if s.join_at > 0.0:
            events.append((s.join_at, 1, "join", j))
        if s.drain_at is not None:
            events.append((s.drain_at, 1, "drain", j))
    for inj in exp.injections:
        if inj.kind == "server_fail":
            events.append((inj.at, 2, "fail", col[inj.params["server_id"]]))
    for at, pol in policy_changes:
        events.append((at, 2, "policy", pol))
    events.sort(key=lambda e: (e[0], e[1]))

    if isinstance(exp.policy, str):
        policy = exp.policy
    else:                       # balancer instance: map back to its name
        policy = {"RoundRobin": "round_robin", "LoadAware": "load_aware",
                  "LeastConnections": "least_connections",
                  "JoinShortestQueue": "jsq", "PowerOfTwo": "p2c",
                  }.get(type(exp.policy).__name__, "round_robin")
    replay = _ReplayPolicy(policy)
    free_mode = policy in FREE_POLICIES

    rate_conn = np.zeros((n_slots, S))
    rate_free = np.zeros(n_slots)
    assignment: dict[int, int] = {}            # client idx -> server col
    seg_start: dict[int, float] = {}           # client idx -> segment start
    alive_cols: list[int] = [j for j, s in enumerate(specs)
                             if s.join_at == 0.0]
    drained: set[int] = set()
    refused = 0

    def slot_range(t0: float, t1: float) -> slice:
        a = np.searchsorted(centers, t0)
        b = np.searchsorted(centers, min(t1, exp.duration))
        return slice(int(a), int(b))

    def close_segment(i: int, t: float) -> None:
        t0 = seg_start.pop(i, None)
        if t0 is None:
            return
        sl = slot_range(t0, t)
        if free_mode or i not in assignment:
            rate_free[sl] += rates[i, sl]
        else:
            rate_conn[sl, assignment[i]] += rates[i, sl]

    live: set[int] = set()
    for t, _, kind, arg in events:
        if kind == "connect":
            i = arg
            c = clients[i]
            qps = c.schedule.rate(c.start_time)
            sid = replay.assign(i, qps, alive_cols)
            if sid is None:
                refused += 1
                continue
            assignment[i] = sid
            seg_start[i] = t
            live.add(i)
        elif kind == "end":
            i = arg
            if i not in live:
                continue
            close_segment(i, t)
            replay.release(i, assignment.pop(i, None))
            live.discard(i)
        elif kind == "join":
            j = arg
            if j not in alive_cols and j not in drained:
                alive_cols.append(j)
        elif kind == "drain":
            j = arg
            drained.add(j)
            if j in alive_cols:
                alive_cols.remove(j)
            # existing clients keep their assignment (sim semantics)
        elif kind == "fail":
            j = arg
            drained.add(j)
            if j in alive_cols:
                alive_cols.remove(j)
            # clients on the failed server re-home through the policy
            for i in sorted(i for i, s in assignment.items() if s == j):
                close_segment(i, t)
                replay.release(i, assignment.pop(i, None))
                c = clients[i]
                sid = replay.assign(i, c.schedule.rate(t), alive_cols)
                if sid is None:
                    # no accepting server: the sim keeps such clients
                    # pumping, routing per-request through the policy's
                    # choose() fallback — model them as request-routed
                    # (water-filled) traffic from here on
                    seg_start[i] = t
                    continue
                assignment[i] = sid
                seg_start[i] = t
        elif kind == "policy":
            new_free = arg in FREE_POLICIES
            if new_free != free_mode:
                for i in list(live):
                    close_segment(i, t)
                    seg_start[i] = t
            free_mode = new_free
            replay.name = arg
    for i in list(live):
        close_segment(i, exp.duration)

    # ---- service laws ------------------------------------------------------
    service = exp.resolved_service()
    batched = getattr(service, "kind", "scalar") == "batched"
    prog = VectorProgram(
        dt=dt, n_slots=n_slots, duration=exp.duration,
        interval=exp.interval, slo=exp.slo, server_ids=server_ids,
        workers=workers, speed=speed, active=active, accepting=accepting,
        fail_slot=fail_slot, rate_conn=rate_conn, rate_free=rate_free,
        work_mean=np.ones(S), work_var=np.zeros(S),
        noise_sigma=noise_sigma, refused_clients=refused,
        unsupported=unsupported)
    if batched:
        lengths = exp.resolved_lengths() or TokenLengths()
        (pm, pv), (nm, nv) = lengths.moments()
        # prefill seconds = max(tp * prompt, t_memory): moments over the
        # integer prompt pmf, floored at the weight-pass time
        pf_m, pf_v = _prefill_moments(service, lengths)
        prog.batched = True
        prog.service = service
        prog.lengths = lengths
        prog.max_batch = int(specs[0].max_batch or 8)
        prog.workers = np.array([float(s.max_batch or 8) for s in specs])
        prog.prefill_mean, prog.prefill_var = pf_m, pf_v
        prog.new_mean, prog.new_var = nm, nv
    else:
        profile = exp.resolved_profile()
        m, v = profile.moments()
        e2 = v + m * m
        # execution noise is multiplicative log-normal per server: fold
        # its moments into the per-server work law
        nf1 = np.exp(noise_sigma ** 2 / 2.0)
        nf2 = np.exp(2.0 * noise_sigma ** 2)
        prog.work_mean = m * nf1
        prog.work_var = np.maximum(e2 * nf2 - prog.work_mean ** 2, 0.0)
        prog.profile = profile
    return prog


def _budget_stop(rate: np.ndarray, dt: float, budget: int) -> float:
    """Absolute stop time of a budgeted client (expected-count crossing)."""
    cum = np.cumsum(rate) * dt
    idx = int(np.searchsorted(cum, float(budget)))
    if idx >= len(rate):
        return math.inf
    return (idx + 1) * dt


def _prefill_moments(service, lengths) -> tuple[float, float]:
    """Exact moments of ``prefill_time(prompt)`` over the clipped
    integer prompt law (shared pmf: ``TokenLengths.int_pmf``)."""
    from repro.core.profiles import TokenLengths
    ks, pmf = TokenLengths.int_pmf(lengths.prompt_median,
                                   lengths.prompt_sigma,
                                   lengths.prompt_max)
    pf = np.maximum(service.t_prefill_per_token * ks, service.t_memory)
    m = float(pmf @ pf)
    return m, max(float(pmf @ (pf * pf)) - m * m, 0.0)
