"""Vector runtime: whole sweep grids as batched array programs.

The third execution backend.  Where the event engine replays every
(point, repetition) cell through a scalar Python loop, the vector
runtime lays the ENTIRE grid out structure-of-arrays — axes
``(cell, time_slot, server)`` with ``cell = point x repetition`` — and
advances fixed-step queueing dynamics for every cell simultaneously
under ``jax.jit`` + ``lax.scan`` (pure-NumPy fallback when jax is
absent).  Arrival counts come from the same ``QPSSchedule`` laws
evaluated as arrays, service costs from the same
``ScalarService``/``BatchedService`` laws (roofline step law applied
per slot), balancer policies become batched water-fill/rotation
updates, and p50/p95/p99 are extracted in one ``np.partition`` pass
per cell.

It is the *statistically equivalent* fast lane, not a bit-identical
one: results match the exact event engine under CI-overlap/Welch gates
(see ``benchmarks/bench_vector.py``), which is the sound trade for
affording more repetitions ("Sampling in Cloud Benchmarking") — exact
mode stays the default and bit-identical.
"""
from repro.vector.compile import VectorCompileError, VectorProgram, compile_experiment
from repro.vector.runtime import (VectorConfig, VectorResult, VectorRuntime,
                                  has_jax, run_cells)
from repro.vector.telemetry import VectorTelemetry

__all__ = [
    "VectorCompileError", "VectorProgram", "compile_experiment",
    "VectorConfig", "VectorResult", "VectorRuntime", "VectorTelemetry",
    "has_jax", "run_cells",
]
