"""MetricsPipeline-compatible telemetry over a ``VectorResult``.

The vector backend has no per-request recorder — its latency numbers
come from bounded per-cell samples ("Sampling in Cloud Benchmarking":
sound percentiles from bounded collection).  This adapter exposes the
same read surface the figure scripts and the sweep executor consume
from ``MetricsPipeline``: ``overall()``, ``series()``, ``window()``,
``frames()``, ``to_rows()``.  Per-client views are not tracked by the
fluid model: ``clients()`` is empty and ``client()`` returns the empty
summary.
"""
from __future__ import annotations

import numpy as np

from repro.core.stats import (IntervalFrame, Summary, quantiles_partition,
                              slo_violation_frac)
from repro.vector.runtime import VectorResult


class VectorTelemetry:
    def __init__(self, result: VectorResult):
        self.result = result
        self.interval = result.interval
        self.slo = result.slo
        self._series_cache = None
        self._groups_cache = None

    def _ivl_samples(self, ivl: int) -> np.ndarray:
        """Samples completing in interval ``ivl`` — grouped once by a
        STABLE argsort (within-group order preserved), so each group is
        bit-for-bit the boolean-mask slice it replaces, without the
        O(intervals x samples) rescan."""
        if self._groups_cache is None:
            r = self.result
            order = np.argsort(r.sample_ivl, kind="stable")
            sorted_ivl = r.sample_ivl[order]
            sorted_xs = r.samples[order]
            starts = np.searchsorted(sorted_ivl, np.arange(len(r.n_ivl) + 1))
            self._groups_cache = (sorted_xs, starts)
        sorted_xs, starts = self._groups_cache
        return sorted_xs[starts[ivl]:starts[ivl + 1]]

    # ---- summaries ---------------------------------------------------------
    def overall(self) -> Summary:
        r = self.result
        if r.n == 0 and r.samples.size == 0:
            return Summary.empty()
        return Summary(r.n, r.mean, r.p50, r.p95, r.p99)

    def client(self, cid: int) -> Summary:
        return Summary.empty()

    def clients(self) -> list:
        return []

    def slo_frac(self) -> float:
        """Overall SLO-violation fraction.  Admission-shed requests are
        violations by definition; the served fraction comes from the
        bounded samples, weighted by the true served count."""
        r = self.result
        base = slo_violation_frac(r.samples, self.slo)
        shed = float(r.shed_ivl.sum()) if r.shed_ivl is not None else 0.0
        if shed <= 0.0 or self.slo is None:
            return base
        if r.n == 0:
            return 1.0
        f = 0.0 if base != base else base          # NaN -> no samples kept
        return (f * r.n + shed) / (r.n + shed)

    # ---- interval series ---------------------------------------------------
    def series(self, cid=None) -> dict:
        if cid is not None:
            return {}
        if self._series_cache is not None:
            return self._series_cache
        r = self.result
        out: dict[int, Summary] = {}
        for ivl in range(len(r.n_ivl)):
            n = int(round(float(r.n_ivl[ivl])))
            xs = self._ivl_samples(ivl)
            if n == 0 and xs.size == 0:
                continue
            if xs.size:
                p50, p95, p99 = quantiles_partition(xs, (50.0, 95.0, 99.0))
                out[ivl] = Summary(n, float(xs.mean()), float(p50),
                                   float(p95), float(p99))
            else:
                out[ivl] = Summary(n, *(float("nan"),) * 4)
        self._series_cache = out
        return out

    def window(self, metric: str, lo: int = 0, hi=None, cid=None) -> list:
        return [getattr(s, metric) for t, s in self.series(cid).items()
                if t >= lo and (hi is None or t < hi)]

    def frames(self) -> list[IntervalFrame]:
        r = self.result
        series = self.series()
        sids = r.server_ids
        frames = []
        for ivl in range(len(r.n_ivl)):
            s = series.get(ivl) or Summary.empty()
            xs = self._ivl_samples(ivl)
            shed_i = (float(r.shed_ivl[ivl]) if r.shed_ivl is not None
                      else 0.0)
            viol = slo_violation_frac(xs, self.slo)
            if shed_i > 0.0 and self.slo is not None:
                # fold sheds in, weighted by the interval's true served
                # count (a 100%-shed interval reports 1.0, not NaN/0)
                f = 0.0 if viol != viol else viol
                viol = (f * s.n + shed_i) / (s.n + shed_i)
            frames.append(IntervalFrame(
                t=ivl, n=s.n, qps=s.n / self.interval, mean=s.mean,
                p50=s.p50, p95=s.p95, p99=s.p99,
                slo_violation_frac=viol, n_shed=int(round(shed_i)),
                util={sid: float(r.util_ivl[ivl, j])
                      for j, sid in enumerate(sids)},
                qdepth={sid: int(round(float(r.qdepth_ivl[ivl, j])))
                        for j, sid in enumerate(sids)},
                occupancy={sid: float(r.occ_ivl[ivl, j])
                           for j, sid in enumerate(sids)},
                tokens_per_sec={} if r.tokens_ivl is None else
                {sid: float(r.tokens_ivl[ivl, j])
                 for j, sid in enumerate(sids)}))
        return frames

    def to_rows(self) -> list[dict]:
        rows = []
        for f in self.frames():
            mean_util = (sum(f.util.values()) / len(f.util)
                         if f.util else float("nan"))
            mean_occ = (sum(f.occupancy.values()) / len(f.occupancy)
                        if f.occupancy else float("nan"))
            rows.append({"t": f.t, "n": f.n, "qps": f.qps,
                         "mean_ms": f.mean * 1e3, "p50_ms": f.p50 * 1e3,
                         "p95_ms": f.p95 * 1e3, "p99_ms": f.p99 * 1e3,
                         "slo_violation_frac": f.slo_violation_frac,
                         "mean_util": mean_util,
                         "mean_occupancy": mean_occ,
                         "tokens_per_sec": sum(f.tokens_per_sec.values()),
                         "total_qdepth": sum(f.qdepth.values())
                                         if f.qdepth else 0})
        return rows
