"""The vector execution engine: fixed-step queueing dynamics for every
grid cell at once.

One ``lax.scan`` (or NumPy slot loop) advances the whole grid's state
``(backlog, queue length, load EMA)`` with axes ``[cell, server]``
through ``n_slots`` fixed steps of width ``dt``:

* per-slot Poisson arrival counts and CLT-aggregated service work are
  pre-drawn per cell from its own seeded ``Generator`` (so a cell's
  numbers do not depend on which grid it runs in);
* connection-routed work lands on its replayed server; request-routed
  work (jsq/p2c) is water-filled onto the least-backlogged accepting
  servers — the fluid limit of join-shortest-queue;
* waiting follows the unfinished-work law: an arrival that must queue
  waits ``backlog / (c * speed)``; the probability it queues blends the
  Erlang-C delay probability at the smoothed offered load with a
  backlog-memory term (exact for c=1 by PASTA);
* batched cells advance the roofline step law per slot: occupancy
  ``b = clip(L, 1, max_batch)``, decode throughput ``b / step_time(b)``
  tokens/sec, prefill seconds served with priority — the same
  ``BatchedService`` cost model the event engine executes op by op.

Latency percentiles come from per-request samples (slot drawn from the
realized arrival weights, own service drawn from the exact law, wait
from the slot's state), censored at the horizon and at server-failure
instants exactly like the event engine's recorder, and extracted for
the whole grid chunk in ONE fused quantile pass.

On the jax backend the scan body dispatches through
``repro.kernels.ops``: ``impl="pallas"`` runs each slot advance as one
``pl.pallas_call`` over ``[cell, server]`` tiles (interpret mode off
TPU), ``impl="ref"`` the plain-jnp step, ``"auto"`` picks per
``jax.default_backend()`` with the ``REPRO_FORCE_IMPL`` env override.
Cells are grouped into geometric (T, S) shape buckets (one jit trace
per bucket, not per exact shape) and the cell axis is laid across the
local devices via ``shard_map``.  All three choices are
bit-preserving: every reduction in the step math runs over the server
axis, so ref / pallas-interpret / sharded execution produce identical
rows for identical seeds.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.vector.compile import VectorProgram, compile_experiment

_BIG = 1e18
_EPS = 1e-12
#: offered load above which the stationary wait is diffusion-bounded
_NEAR_CRITICAL = 0.9


def has_jax() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except ImportError:
        # ONLY an absent jax means "fall back to numpy" — a broken
        # install must raise, not silently switch backends
        return False


@dataclass
class VectorConfig:
    dt: float = 0.005               # slot width (seconds)
    samples: int = 32768            # latency-sample budget per cell
    backend: str = "auto"           # auto | jax | numpy
    impl: str = "auto"              # auto | pallas | ref (jax backend;
                                    # REPRO_FORCE_IMPL overrides "auto")
    jit: bool = True                # wrap the jax scan in jax.jit
    devices: int = 0                # cell-axis sharding: 0 = every local
                                    # device (auto), N >= 1 pins the mesh
                                    # size (1 still runs the shard layer)
    bucket: bool = True             # geometric (T, S) shape-bucketing
    max_slot_elems: int = 64_000_000   # chunk cells when T*C*S exceeds this
    jit_cache_size: int = 8         # compiled-runner LRU entries (eviction
                                    # only costs a recompile, never bits)
    pipeline: bool = True           # double-buffer chunks: the device scan
                                    # of chunk k+1 overlaps host finishing
                                    # (quantiles, cache writes) of chunk k
    soft: bool = False              # differentiable mode: smoothed
                                    # water-filling / Erlang-C / censoring
                                    # and the soft quantile head (jax
                                    # backend only; forces impl="ref")
    tau: float = 0.05               # soft-mode temperature (relative)
    band_frac: float = 5e-4         # soft quantile-head bandwidth, as a
                                    # fraction of the effective count

    def resolve_backend(self) -> str:
        if self.backend == "auto":
            return "jax" if has_jax() else "numpy"
        if self.backend == "jax" and not has_jax():
            raise RuntimeError("backend='jax' requested but jax is not "
                               "importable (use 'numpy' or 'auto')")
        return self.backend

    def resolve_impl(self) -> str:
        """Resolved scan-step impl for the jax backend.  Soft mode pins
        the jnp reference path: the Pallas kernels implement only the
        hard step math."""
        if self.soft:
            return "ref"
        from repro.kernels.ops import resolve_impl
        return resolve_impl(self.impl)

    def resolve_devices(self) -> int:
        import jax
        avail = len(jax.local_devices())
        if self.devices <= 0:
            return avail
        return max(1, min(self.devices, avail))


# ---------------------------------------------------------------------------
# Per-cell result
# ---------------------------------------------------------------------------
@dataclass
class VectorResult:
    """Extracted results for one (point, rep) cell."""
    n: int
    mean: float
    p50: float
    p95: float
    p99: float
    dropped: int
    interval: float
    slo: Optional[float]
    server_ids: list
    samples: np.ndarray             # kept latency samples (uniform over
                                    # completed requests)
    sample_ivl: np.ndarray          # completion interval per kept sample
    n_ivl: np.ndarray               # [n_ivls] completions per interval
    util_ivl: np.ndarray            # [n_ivls, S] utilization
    occ_ivl: np.ndarray             # [n_ivls, S] occupancy
    qdepth_ivl: np.ndarray          # [n_ivls, S] queue depth at boundary
    tokens_ivl: Optional[np.ndarray] = None   # [n_ivls, S] tokens/sec
    shed_ivl: Optional[np.ndarray] = None     # [n_ivls] admission-shed
                                              # requests (fluid expectation)


# ---------------------------------------------------------------------------
# The scan step (shared math, numpy or jax namespace)
# ---------------------------------------------------------------------------
def _waterfill(xp, U_eff, total):
    """Distribute ``total`` [C] of work over the least-loaded lanes of
    ``U_eff`` [C, S] (masked lanes carry ``_BIG``): fill to a common
    level.  -> per-lane fill amounts [C, S].

    Sort-free formulation (Pallas kernel bodies cannot sort): lane k
    proposes the level reached if exactly the lanes at-or-below it
    share the work, ``L_k = (total + sum_{U_i <= U_k} U_i) /
    |{U_i <= U_k}|``.  Every proposal upper-bounds the true level
    (``sum_A (L_k - U_i) = total = sum_i (L* - U_i)^+ >=
    sum_A (L* - U_i)``), and the true active set attains it — so the
    level is exactly ``min_k L_k``, no bracket test needed.  O(S^2)
    broadcasts over the server axis only, so cell-axis tiling and
    sharding cannot change bits."""
    mine = U_eff[..., :, None]                    # proposing lane k
    other = U_eff[..., None, :]                   # every lane i
    le = other <= mine
    cnt = xp.sum(xp.where(le, 1.0, 0.0), axis=-1)
    wsum = xp.sum(xp.where(le, other, 0.0), axis=-1)
    level = (total[..., None] + wsum) / xp.maximum(cnt, 1.0)
    L = xp.min(level, axis=-1, keepdims=True)
    return xp.clip(L - U_eff, 0.0, None)


def _lgamma(c: np.ndarray) -> np.ndarray:
    """lgamma(c + 1) for small-integer capacity arrays via a lookup
    table (np.vectorize(math.lgamma) over a [slots, cells] array costs
    more than the scan itself)."""
    hi = int(np.max(c)) + 1 if c.size else 1
    table = np.array([math.lgamma(k + 1.0) for k in range(hi + 1)])
    return table[np.clip(c.astype(np.int64), 0, hi)]


def _erlang_c(c, lgamma_c, rho, cmax: int):
    """Erlang-C delay probability (P(arrival must queue) in M/M/c),
    vectorized with per-server integer capacity ``c`` <= cmax.
    Precomputed in numpy from the deterministic per-slot offered load —
    it never enters the scan."""
    rho = np.clip(rho, 1e-9, 0.999)
    a = c * rho
    top = np.exp(c * np.log(a) - lgamma_c)
    term = np.ones_like(a)
    ssum = np.zeros_like(a)
    for k in range(cmax):
        ssum = ssum + np.where(k < c, term, 0.0)
        term = term * a / (k + 1.0)
    denom = (1.0 - rho) * ssum + top
    return top / np.maximum(denom, _EPS)


def _episode_age(rho: np.ndarray, t_idx: np.ndarray, dt: float,
                 band: float = _NEAR_CRITICAL) -> np.ndarray:
    """Seconds since each lane's offered load last sat below ``band``
    — the age of the current near-critical episode (>= dt).  Lanes hot
    from t=0 age from the run start."""
    idx = t_idx.reshape((-1,) + (1,) * (rho.ndim - 1)).astype(float)
    last_low = np.maximum.accumulate(np.where(rho < band, idx, -1.0),
                                     axis=0)
    return np.maximum(idx - last_low, 1.0) * dt


def _make_waterfill(xp, consts):
    """The step's water-fill operator: hard level-fill, or the
    temperature-controlled relaxation when the consts carry a soft-mode
    ``tau``.  The choice is structural (dict key presence), so it is
    trace-time static and never branches on a traced value."""
    tau = consts.get("tau")
    if tau is None:
        def wfill(U_eff, total):
            return _waterfill(xp, U_eff, total)
    else:
        from repro.vector.soft import soft_waterfill

        def wfill(U_eff, total):
            return soft_waterfill(xp, U_eff, total, tau)
    return wfill


def _scalar_step(xp, consts):
    c = consts["c"]
    fail_slot = consts["fail_slot"]
    dt = consts["dt"]
    wfill = _make_waterfill(xp, consts)

    def step(carry, xs):
        U, Q, drops = carry
        t, Nc, Wc, Nf, Wf, act, acc, spd = xs
        # failure instant: the resident queue and in-flight work vanish
        is_fail = (t == fail_slot)
        drops = drops + xp.sum(xp.where(is_fail, Q, 0.0), axis=-1)
        U = xp.where(is_fail, 0.0, U)
        Q = xp.where(is_fail, 0.0, Q)
        # request-routed work: water-fill the accepting servers
        n_acc = xp.sum(acc, axis=-1)
        ok = n_acc > 0
        drops = drops + xp.where(ok, 0.0, Nf)
        Wf = xp.where(ok, Wf, 0.0)
        Nf = xp.where(ok, Nf, 0.0)
        U_eff = xp.where(acc > 0, U, _BIG)
        w_free = wfill(U_eff, Wf)
        share = w_free / xp.maximum(
            xp.sum(w_free, axis=-1, keepdims=True), _EPS)
        n_free = Nf[..., None] * share
        W_arr = Wc + w_free
        N_arr = Nc + n_free
        # backlog wait an arrival inherits (transients and overload; the
        # stationary within-slot term is added analytically outside);
        # request-routed arrivals land at the water-fill level: they
        # inherit the LEAST backlog any accepting server offers
        wait_U = U / xp.maximum(c * spd, _EPS)
        wait_free = xp.min(xp.where(acc > 0, wait_U, _BIG), axis=-1)
        # serve
        cw = c * spd * act * dt
        drained = xp.minimum(U + W_arr, cw)
        wpr = (U + W_arr) / xp.maximum(Q + N_arr, _EPS)   # work per request
        n_served = xp.minimum(Q + N_arr, drained / xp.maximum(wpr, _EPS))
        U = U + W_arr - drained
        Q = Q + N_arr - n_served
        return (U, Q, drops), (wait_U, wait_free, n_served, drained, Q)
    return step


def _batched_step(xp, consts):
    B = consts["c"]                      # batch slots
    fail_slot = consts["fail_slot"]; dt = consts["dt"]
    tm = consts["tm"]; tc = consts["tc"]
    new_mean = consts["new_mean"]
    wfill = _make_waterfill(xp, consts)

    def step(carry, xs):
        P, T, L, drops = carry           # prefill s, tokens, requests
        t, Nc, Wpc, Wtc, Nf, Wpf, Wtf, act, acc, spd = xs
        is_fail = (t == fail_slot)
        drops = drops + xp.sum(xp.where(is_fail, L, 0.0), axis=-1)
        P = xp.where(is_fail, 0.0, P)
        T = xp.where(is_fail, 0.0, T)
        L = xp.where(is_fail, 0.0, L)
        # free arrivals: water-fill by queue length (jsq over load())
        n_acc = xp.sum(acc, axis=-1)
        ok = n_acc > 0
        drops = drops + xp.where(ok, 0.0, Nf)
        Nf = xp.where(ok, Nf, 0.0)
        L_eff = xp.where(acc > 0, L, _BIG)
        n_free = wfill(L_eff, Nf)
        share = n_free / xp.maximum(
            xp.sum(n_free, axis=-1, keepdims=True), _EPS)
        Wp_arr = Wpc + Wpf[..., None] * share
        Wt_arr = Wtc + Wtf[..., None] * share
        N_arr = Nc + n_free
        # roofline step law at the slot's occupancy
        b = xp.clip(L, 1.0, B)
        st = xp.maximum(tc * b, tm)
        tok_rate = b / st
        avail = act * spd * dt
        p_served = xp.minimum(P + Wp_arr, avail)
        rem = avail - p_served
        tok_served = xp.minimum(T + Wt_arr, rem * tok_rate)
        dec_used = tok_served / xp.maximum(tok_rate, _EPS)
        busy_used = p_served + dec_used
        n_served = xp.minimum(L + N_arr, tok_served / new_mean)
        P = P + Wp_arr - p_served
        T = T + Wt_arr - tok_served
        L = L + N_arr - n_served
        # admission wait: drain-time share ahead of a new arrival
        D = (P + T * st / xp.maximum(b, 1.0)) / xp.maximum(spd, _EPS)
        wait_adm = D * xp.clip((L - B) / xp.maximum(L, 1.0), 0.0, 1.0)
        b_hat = xp.clip(L + 1.0, 1.0, B)
        st_hat = xp.maximum(tc * b_hat, tm)
        return (P, T, L, drops), (wait_adm, st_hat, N_arr, n_served,
                                  busy_used, L, tok_served)
    return step


# ---------------------------------------------------------------------------
# Scan drivers
# ---------------------------------------------------------------------------
def _scan_numpy(step, carry, xs_seq, n_slots: int):
    outs = None
    for t in range(n_slots):
        xs = tuple(x[t] for x in xs_seq)
        carry, ys = step(carry, xs)
        if outs is None:
            outs = tuple(np.empty((n_slots,) + np.shape(y), dtype=float)
                         for y in ys)
        for buf, y in zip(outs, ys):
            buf[t] = y
    return carry, outs


#: (step_builder, jit, impl, shard, padded shapes) -> compiled runner.
#: consts enter as traced pytree arguments, so one entry serves every
#: grid with the same signature; shape-bucketing keeps the key set
#: small, and the LRU cap bounds the resident compile footprint across
#: long sessions (eviction only costs a recompile, never bits).
_JIT_CACHE: OrderedDict = OrderedDict()
_JIT_CACHE_CAP = 8


def _jax_runner(step_builder, jit: bool, impl: str, shard: int,
                shape_key: tuple, cap: int = _JIT_CACHE_CAP):
    key = (step_builder, jit, impl, shard, shape_key)
    fn = _JIT_CACHE.get(key)
    if fn is not None:
        _JIT_CACHE.move_to_end(key)
        return fn
    import jax
    import jax.numpy as jnp

    family = "batched" if step_builder is _batched_step else "scalar"
    if impl == "ref":
        def make_step(consts):
            return step_builder(jnp, consts)
    else:
        from repro.kernels import ops as kernel_ops

        def make_step(consts):
            def step(carry, xs):
                return kernel_ops.vector_slot_advance(
                    family, consts, carry, xs, impl=impl)
            return step

    def run(consts, carry, xs):
        return jax.lax.scan(make_step(consts), carry, xs)

    if shard:
        run = _shard_cells(run, family, shard)
    if jit:
        # donate the carry: the scan consumes it and the caller only
        # reads the returned one, so XLA may reuse the buffers in
        # place.  CPU jax cannot donate (it would only warn), so the
        # hint is gated on the backend.
        donate = (1,) if jax.default_backend() != "cpu" else ()
        fn = jax.jit(run, donate_argnums=donate)
    else:
        fn = run
    _JIT_CACHE[key] = fn
    while len(_JIT_CACHE) > max(1, cap):
        _JIT_CACHE.popitem(last=False)
    return fn


def _shard_cells(run, family: str, n_dev: int):
    """Lay the cell axis across ``n_dev`` local devices via
    ``shard_map``.  Every reduction in the step math runs over the
    server axis, so the sharded program is bit-identical to the
    single-device one (a test pins this)."""
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec

    cell = PartitionSpec("cells")          # [C, ...] leading cell axis
    seq = PartitionSpec(None, "cells")     # [T, C, ...] scan sequences
    none = PartitionSpec()
    if family == "scalar":
        const_spec = {"c": cell, "fail_slot": cell, "dt": none}
        n_carry, n_xs, n_ys = 3, 8, 5
    else:
        const_spec = {"c": cell, "fail_slot": cell, "dt": none,
                      "tm": cell, "tc": cell, "new_mean": cell}
        n_carry, n_xs, n_ys = 4, 10, 7
    in_specs = (const_spec, (cell,) * n_carry,
                (none,) + (seq,) * (n_xs - 1))
    out_specs = ((cell,) * n_carry, (seq,) * n_ys)
    mesh = Mesh(np.array(jax.devices()[:n_dev]), ("cells",))
    return shard_map(run, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


#: cell-padding fills that keep padded (dead) cells NaN-free: no
#: failure slot, unit roofline times; everything else zero
_CELL_PAD_FILL = {"fail_slot": -1, "tm": 1.0, "tc": 1.0, "new_mean": 1.0}


def _pad_cell_axis(a: np.ndarray, pad: int, axis: int, fill=0.0):
    width = [(0, 0)] * a.ndim
    width[axis] = (0, pad)
    return np.pad(a, width, constant_values=fill)


def _scan_jax_launch(step_builder, consts, carry, xs_seq,
                     cfg: VectorConfig):
    """Dispatch the chunk's scan and return immediately (jax dispatch is
    async: the device computes while the host moves on).  Pair with
    ``_scan_jax_finish``, which blocks on the transfer."""
    import jax.numpy as jnp

    impl = cfg.resolve_impl()
    n_dev = cfg.resolve_devices()
    # soft consts carry the extra "tau" leaf the shard specs don't
    # declare; soft grids are small, so they skip the shard layer
    use_shard = (n_dev > 1 or cfg.devices >= 1) and not cfg.soft
    if impl == "pallas":
        from repro.kernels.vector_step import CELL_TILE as tile
    else:
        tile = 1
    # pad the cell axis so each device shard is kernel-tile aligned;
    # padded cells are inert and sliced away after the scan
    C = carry[-1].shape[0]
    unit = tile * (n_dev if use_shard else 1)
    pad = (-C) % unit
    if pad:
        consts = {k: (_pad_cell_axis(v, pad, 0,
                                     _CELL_PAD_FILL.get(k, 0.0))
                      if isinstance(v, np.ndarray) else v)
                  for k, v in consts.items()}
        carry = tuple(_pad_cell_axis(c, pad, 0) for c in carry)
        xs_seq = (xs_seq[0],) + tuple(_pad_cell_axis(x, pad, 1)
                                      for x in xs_seq[1:])

    consts_j = {k: (jnp.asarray(v, jnp.float32)
                    if isinstance(v, np.ndarray) else
                    jnp.float32(v))
                for k, v in consts.items()}
    # fail_slot compares against integer slot indices
    consts_j["fail_slot"] = jnp.asarray(consts["fail_slot"], jnp.int32)
    carry_j = tuple(jnp.asarray(c, jnp.float32) for c in carry)
    xs_j = tuple(jnp.asarray(x, jnp.int32 if i == 0 else jnp.float32)
                 for i, x in enumerate(xs_seq))
    shape_key = (xs_j[0].shape[0],) + carry_j[0].shape
    runner = _jax_runner(step_builder, cfg.jit, impl,
                         n_dev if use_shard else 0, shape_key,
                         cap=cfg.jit_cache_size)
    return runner(consts_j, carry_j, xs_j), C


def _scan_jax_finish(raw):
    """Block on a launched chunk and widen host-side to f64."""
    import jax
    (out_carry, outs), C = raw
    # ONE device->host sync for the whole chunk: the previous per-array
    # np.asarray form issued ~10 blocking transfers per chunk, which is
    # what left the warm jax path behind the NumPy fallback on small
    # grids.  The f64 widening stays host-side so rows keep their bits.
    out_carry, outs = jax.device_get((out_carry, outs))
    return (tuple(np.asarray(c, np.float64)[:C] for c in out_carry),
            tuple(np.asarray(o, np.float64)[:, :C] for o in outs))


def _scan_jax(step_builder, consts, carry, xs_seq, cfg: VectorConfig):
    return _scan_jax_finish(
        _scan_jax_launch(step_builder, consts, carry, xs_seq, cfg))


# ---------------------------------------------------------------------------
# Grid execution
# ---------------------------------------------------------------------------
def _cell_rng(seed: int, stream: int) -> np.random.Generator:
    """The cell's private RNG: seeded by the sweep-derived (seed,
    stream), domain-separated from every scalar-path stream."""
    return np.random.default_rng((0x7EC7, int(seed), int(stream)))


def _draw_cell(prog: VectorProgram, rng: np.random.Generator) -> dict:
    """Pre-scan draws for one cell, in a FIXED order (the same numbers
    whether the cell runs alone or inside any grid)."""
    dt = prog.dt
    Nc = rng.poisson(prog.rate_conn * dt).astype(float)
    Nf = rng.poisson(prog.rate_free * dt).astype(float)
    if not prog.batched:
        # the scalar backlog is a pure fluid: expected work per slot.
        # Stochastic queueing below saturation is carried entirely by
        # the analytic stationary term (Erlang-C x exponential) — work
        # or count noise here would double-count it — so the fluid
        # captures exactly what the stationary law cannot: transient
        # buildup and overload growth.  Poisson counts still drive the
        # sampling weights and the completion counts.
        m = prog.work_mean                            # [S]
        return {"Nc": Nc, "Wc": prog.rate_conn * dt * m, "Nf": Nf,
                "Wf": prog.rate_free * dt * float(m.mean())}
    zc = rng.standard_normal(Nc.shape)
    zf = rng.standard_normal(Nf.shape)
    zc2 = rng.standard_normal(Nc.shape)
    zf2 = rng.standard_normal(Nf.shape)
    pm, pv = prog.prefill_mean, prog.prefill_var
    nm, nv = prog.new_mean, prog.new_var
    Wpc = np.maximum(Nc * pm + np.sqrt(Nc * pv) * zc, 0.05 * Nc * pm)
    Wtc = np.maximum(Nc * nm + np.sqrt(Nc * nv) * zc2, 0.05 * Nc * nm)
    Wpf = np.maximum(Nf * pm + np.sqrt(Nf * pv) * zf, 0.05 * Nf * pm)
    Wtf = np.maximum(Nf * nm + np.sqrt(Nf * nv) * zf2, 0.05 * Nf * nm)
    return {"Nc": Nc, "Wpc": Wpc, "Wtc": Wtc, "Nf": Nf,
            "Wpf": Wpf, "Wtf": Wtf}


def _pad(a: np.ndarray, T: int, S: int) -> np.ndarray:
    """Zero-pad a per-cell [T_i(, S_i)] array to the group shape."""
    if a.ndim == 1:
        out = np.zeros(T)
        out[:a.shape[0]] = a
        return out
    out = np.zeros((T, S))
    out[:a.shape[0], :a.shape[1]] = a
    return out


#: geometric bucket resolution: sizes per octave (<= 1/quantum relative
#: padding waste; tiny dims stay exact)
_BUCKET_QUANTUM = 8


def _bucket_dim(n: int, quantum: int = _BUCKET_QUANTUM) -> int:
    """Round ``n`` up to the next geometric bucket so heterogeneous
    grids collapse onto a few stable pad shapes (one jit trace per
    bucket, not per exact shape)."""
    n = int(n)
    if n <= quantum:
        return n
    step = max(1, (1 << ((n - 1).bit_length() - 1)) // quantum)
    return -(-n // step) * step


def _plan_groups(programs: Sequence[VectorProgram],
                 cfg: VectorConfig) -> list:
    """Group cell indices by (family, padded (T, S) shape).

    With ``cfg.bucket`` each cell's own (n_slots, n_servers) rounds up
    to its geometric bucket; without, each family pads to its max (the
    pre-bucketing behavior).  Either way padding is masking, never
    truncation: a cell's draws use its true shape and extraction
    slices it back out, so rows are bit-identical across groupings (a
    test pins bucketed == unbucketed)."""
    groups: dict = {}
    for i, p in enumerate(programs):
        shape = (_bucket_dim(p.n_slots), _bucket_dim(p.n_servers)) \
            if cfg.bucket else None
        groups.setdefault((p.batched, shape), []).append(i)
    out = []
    for (batched, shape), idxs in sorted(
            groups.items(), key=lambda kv: (kv[0][0], kv[0][1] or ())):
        if shape is None:
            shape = (max(programs[i].n_slots for i in idxs),
                     max(programs[i].n_servers for i in idxs))
        out.append((batched, shape, idxs))
    return out


def run_cells(programs: Sequence[VectorProgram],
              seeds: Sequence[tuple],
              config: Optional[VectorConfig] = None,
              cache=None) -> list[VectorResult]:
    """Execute one cell per (program, (seed, stream)) pair — the whole
    grid as one batched array program per (family, shape bucket),
    chunked to bound scan memory.

    With a ``ResultCache``, cached cells are filtered out BEFORE
    ``_plan_groups``: only cold cells enter the batched scan, so a
    re-run of a 117-cell grid with 3 edited points launches 3 cells.
    Each cell's draws come from its own seeded Generator, so which
    cells happen to be cold can never change any cell's bits.

    Chunks are double-buffered when ``cfg.pipeline``: the device scan
    of chunk k+1 is dispatched (async) before chunk k's host finishing
    (device fetch, sampling, quantiles, cache writes) runs, overlapping
    the two.  ``pipeline=False`` restores strictly serial
    launch-then-finish; both orders produce identical rows because a
    cell's numbers depend only on its own program, seed, and config.
    """
    cfg = config or VectorConfig()
    backend = cfg.resolve_backend()
    if cfg.soft and backend != "jax":
        raise RuntimeError("VectorConfig.soft=True needs the jax "
                           "backend: the soft quantile head runs "
                           "through jnp (use backend='jax' or 'auto')")
    results: list[Optional[VectorResult]] = [None] * len(programs)
    keys: list[Optional[str]] = [None] * len(programs)
    if cache is not None:
        cold = []
        for i, (p, s) in enumerate(zip(programs, seeds)):
            keys[i] = cache.cell_key(p, s, cfg)
            hit = cache.get_cell(keys[i]) if keys[i] is not None else None
            if hit is not None:
                results[i] = hit
            else:
                cold.append(i)
    else:
        cold = list(range(len(programs)))
    if not cold:
        return results  # type: ignore[return-value]

    cold_progs = [programs[i] for i in cold]
    chunks = []                     # (batched, shape, indices into cold)
    for batched, shape, idxs in _plan_groups(cold_progs, cfg):
        # chunk cells so T*C*S stays within the memory budget
        per_cell = max(shape[0] * shape[1], 1)
        chunk = max(1, cfg.max_slot_elems // per_cell)
        for lo in range(0, len(idxs), chunk):
            chunks.append((batched, shape, idxs[lo:lo + chunk]))

    def finish(state, part):
        for j, res in zip(part, _finish_family(state)):
            i = cold[j]
            results[i] = res
            if cache is not None and keys[i] is not None:
                cache.put_cell(keys[i], res)

    pending = None
    for batched, shape, part in chunks:
        state = _launch_family([cold_progs[j] for j in part],
                               [seeds[cold[j]] for j in part],
                               batched, backend, cfg, shape)
        if not cfg.pipeline:
            finish(state, part)
            continue
        if pending is not None:
            finish(*pending)
        pending = (state, part)
    if pending is not None:
        finish(*pending)
    return results  # type: ignore[return-value]


def _launch_family(progs: list, seeds: list, batched: bool, backend: str,
                   cfg: VectorConfig, shape: tuple) -> dict:
    """Draw, assemble, and DISPATCH one (family, shape) chunk.

    On the jax backend the scan is launched asynchronously and this
    returns before it completes; the host-side analytic aux (Erlang-C,
    pooled laws, stretch) is computed after dispatch so it overlaps the
    device scan.  ``_finish_family`` consumes the returned state."""
    C = len(progs)
    T, S = shape
    dt = progs[0].dt
    rngs = [_cell_rng(s, st) for s, st in seeds]
    draws = [_draw_cell(p, r) for p, r in zip(progs, rngs)]

    def stack(key: str) -> np.ndarray:
        return np.stack([_pad(d[key], T, S) for d in draws], axis=1)

    def stackp(attr: str) -> np.ndarray:
        return np.stack([_pad(getattr(p, attr), T, S) for p in progs],
                        axis=1)

    act = stackp("active")
    acc = stackp("accepting")
    spd = stackp("speed")
    c = np.stack([np.pad(p.workers, (0, S - p.n_servers)) for p in progs])
    fail = np.stack([np.pad(p.fail_slot, (0, S - p.n_servers),
                            constant_values=-1) for p in progs])
    t_idx = np.arange(T, dtype=np.int64)

    if not batched:
        consts = {"c": c, "fail_slot": fail, "dt": dt}
        xs = (t_idx, stack("Nc"), stack("Wc"), stack("Nf"), stack("Wf"),
              act, acc, spd)
        carry = tuple(np.zeros((C, S)) for _ in range(2)) + (np.zeros(C),)
        builder = _scalar_step
    else:
        tm = np.array([p.service.t_memory for p in progs])[:, None]
        tc = np.array([p.service.t_compute_per_seq for p in progs])[:, None]
        nm = np.array([p.new_mean for p in progs])[:, None]
        consts = {"c": c, "fail_slot": fail, "dt": dt, "tm": tm, "tc": tc,
                  "new_mean": nm}
        xs = (t_idx, stack("Nc"), stack("Wpc"), stack("Wtc"), stack("Nf"),
              stack("Wpf"), stack("Wtf"), act, acc, spd)
        carry = tuple(np.zeros((C, S)) for _ in range(3)) + (np.zeros(C),)
        builder = _batched_step
    if cfg.soft:
        consts["tau"] = float(cfg.tau)

    state = {"progs": progs, "rngs": rngs, "draws": draws,
             "batched": batched, "backend": backend, "cfg": cfg, "C": C}
    if backend == "jax":
        state["raw"] = _scan_jax_launch(builder, consts, carry, xs, cfg)
    else:
        step = builder(np, dict(consts))
        state["host"] = _scan_numpy(step, carry, xs, T)

    # ---- host-side analytic aux (overlaps the dispatched scan) ---------
    aux: dict = {}
    if not batched:
        m_w = np.stack([np.pad(p.work_mean, (0, S - p.n_servers),
                               constant_values=1.0) for p in progs])
        v_w = np.stack([np.pad(p.work_var, (0, S - p.n_servers))
                        for p in progs])
        # ---- analytic stationary wait (outside the scan) ----------------
        # deterministic per-slot offered load, with request-routed rate
        # spread capacity-proportionally over the accepting servers
        rate_c = np.stack([_pad(p.rate_conn, T, S) for p in progs], axis=1)
        rate_f = np.stack([_pad(p.rate_free, T, S) for p in progs], axis=1)
        cap_share = acc * (c * spd)
        share = cap_share / np.maximum(
            cap_share.sum(axis=-1, keepdims=True), _EPS)
        lam_w = (rate_c + rate_f[..., None] * share) * m_w[None]
        rho_det = np.where(act > 0,
                           lam_w / np.maximum(c * spd, _EPS), 0.0)
        lgamma_c = _lgamma(c)
        cmax = int(c.max()) if c.size else 1
        if cfg.soft:
            from repro.vector import soft as _soft
            aux["pC"] = _soft.soft_erlang_c(np, c[None].astype(float),
                                            rho_det, cmax, cfg.tau)
            headroom = 1.0 - _soft.smooth_rho(np, rho_det, cfg.tau)
        else:
            aux["pC"] = _erlang_c(c[None], lgamma_c[None], rho_det, cmax)
            headroom = 1.0 - np.clip(rho_det, 0.0, 0.999)
        # conditional wait given queueing: residual service work over
        # the free capacity (exact Pollaczek-Khinchine mean for c=1),
        # bounded near/above criticality by the diffusion growth law
        # E[U(t)] ~ sigma * sqrt(2 t / pi) — a finite run at rho -> 1
        # only builds the queue the random walk had time to build
        e2 = v_w + m_w * m_w
        resid = e2 / np.maximum(2.0 * m_w, _EPS)
        w_stat = resid[None] / np.maximum(c[None] * spd * headroom, _EPS)
        lam_srv = rho_det * c[None] * spd / np.maximum(m_w[None], _EPS)
        # the diffusion clock runs from the start of the CURRENT
        # near-critical episode, not the run: cyclic loads (diurnal)
        # cross criticality many times, and each crossing only has its
        # own age of random walk behind it
        t_since = _episode_age(rho_det, t_idx, dt)
        growth = np.sqrt(2.0 / math.pi * lam_srv * e2[None] * t_since) \
            / np.maximum(c[None] * spd, _EPS)
        # the diffusion bound only exists near/above criticality —
        # below the band the stationary law stands alone
        aux["w_cond"] = np.where(rho_det < _NEAR_CRITICAL, w_stat,
                                 np.minimum(w_stat, growth))
        # ---- pooled law for request-routed arrivals ---------------------
        # jsq/p2c pool the fleet: an arrival queues only when EVERY
        # accepting server is busy — Erlang-C over the pooled capacity,
        # not independent per-server queues
        m_bar = np.array([float(p.work_mean.mean()) for p in progs])
        e2_bar = np.array([float((p.work_var + p.work_mean ** 2).mean())
                           for p in progs])
        resid_bar = e2_bar / np.maximum(2.0 * m_bar, _EPS)
        cap_pool = (acc * c[None] * spd).sum(axis=-1)          # [T, C]
        work_rate = (rate_c * m_w[None]).sum(axis=-1) \
            + rate_f * m_bar[None]
        rho_pool = np.where(cap_pool > 0,
                            work_rate / np.maximum(cap_pool, _EPS), 0.0)
        c_pool = np.minimum(np.maximum((acc * c[None]).sum(axis=-1), 1.0),
                            64.0)
        if cfg.soft:
            aux["pC_free"] = _soft.soft_erlang_c(np, c_pool, rho_pool,
                                                 int(c_pool.max()),
                                                 cfg.tau)
            headroom_f = 1.0 - _soft.smooth_rho(np, rho_pool, cfg.tau)
        else:
            aux["pC_free"] = _erlang_c(c_pool, _lgamma(c_pool), rho_pool,
                                       int(c_pool.max()))
            headroom_f = 1.0 - np.clip(rho_pool, 0.0, 0.999)
        w_stat_f = resid_bar[None] / np.maximum(cap_pool * headroom_f,
                                                _EPS)
        lam_pool = rho_pool * cap_pool / np.maximum(m_bar[None], _EPS)
        t_since_f = _episode_age(rho_pool, t_idx, dt)
        growth_f = np.sqrt(2.0 / math.pi * lam_pool * e2_bar[None]
                           * t_since_f) / np.maximum(cap_pool, _EPS)
        aux["w_cond_free"] = np.where(rho_pool < _NEAR_CRITICAL, w_stat_f,
                                      np.minimum(w_stat_f, growth_f))
        aux["free_ok"] = (acc.sum(axis=-1) > 0).astype(float)
        aux["spd_free"] = np.where(
            acc.sum(axis=-1) > 0,
            (acc * c[None] * spd).sum(axis=-1)
            / np.maximum((acc * c[None]).sum(axis=-1), _EPS), 1.0)
    else:
        # a resident's wall-clock pace per own token stretches by the
        # prefill ops interleaved with decode (the engine serializes one
        # op at a time) — deterministic expected prefill time-share
        rate_c = np.stack([_pad(p.rate_conn, T, S) for p in progs], axis=1)
        rate_f = np.stack([_pad(p.rate_free, T, S) for p in progs], axis=1)
        share_even = acc / np.maximum(acc.sum(axis=-1, keepdims=True),
                                      _EPS)
        pf_mean = np.array([p.prefill_mean for p in progs])
        pf_share = np.clip((rate_c + rate_f[..., None] * share_even)
                           * pf_mean[None, :, None]
                           / np.maximum(spd, _EPS), 0.0, 0.8)
        aux["stretch"] = 1.0 / (1.0 - pf_share)
    state["aux"] = aux
    return state


def _finish_family(state: dict) -> list[VectorResult]:
    """Fetch a launched chunk's scan outputs and extract every cell's
    results (sampling, censoring, fused-grid percentiles)."""
    progs, rngs, draws = state["progs"], state["rngs"], state["draws"]
    batched, backend, cfg = (state["batched"], state["backend"],
                             state["cfg"])
    C, aux = state["C"], state["aux"]
    if backend == "jax":
        carry, outs = _scan_jax_finish(state["raw"])
    else:
        carry, outs = state["host"]

    cells = [_sample_cell(progs[i], rngs[i], i, batched, carry, outs, aux,
                          draws[i], cfg)
             for i in range(C)]
    if cfg.soft:
        quants = _grid_quantiles([cell["lat_all"] for cell in cells], cfg,
                                 backend,
                                 weights=[cell["w_all"] for cell in cells])
    else:
        quants = _grid_quantiles([cell["lat"] for cell in cells], cfg,
                                 backend)
    return [_finish_cell(progs[i], batched, cells[i], quants[i])
            for i in range(C)]


def _run_family(progs: list, seeds: list, batched: bool, backend: str,
                cfg: VectorConfig, shape: tuple) -> list[VectorResult]:
    return _finish_family(_launch_family(progs, seeds, batched, backend,
                                         cfg, shape))


# ---------------------------------------------------------------------------
# Per-cell extraction: sampling, censoring, fused-grid percentiles
# ---------------------------------------------------------------------------
def _sample_cell(prog: VectorProgram, rng: np.random.Generator, i: int,
                 batched: bool, carry, outs, aux: dict, draws: dict,
                 cfg: VectorConfig) -> dict:
    """Draw this cell's request sample from the slot series (uniform over
    realized arrivals, event-engine censoring) — everything per-cell
    EXCEPT the percentiles, which `_grid_quantiles` computes for the
    whole chunk in one fused launch."""
    T, S = prog.n_slots, prog.n_servers
    dt = prog.dt
    if not batched:
        wait_U = outs[0][:T, i, :S]
        wait_free = outs[1][:T, i]
        n_served = outs[2][:T, i, :S]
        drained = outs[3][:T, i, :S]
        Qs = outs[4][:T, i, :S]
        pC = aux["pC"][:T, i, :S]
        w_cond = aux["w_cond"][:T, i, :S]
        pC_f = aux["pC_free"][:T, i]
        w_cond_f = aux["w_cond_free"][:T, i]
        free_ok = aux["free_ok"][:T, i]
        spd_f = aux["spd_free"][:T, i]
    else:
        wait_adm, st_hat, N_arr, n_served, drained, Qs, tok_served = \
            (o[:T, i, :S] for o in outs)
    drops = float(carry[-1][i])

    centers = (np.arange(T) + 0.5) * dt
    speed = prog.speed

    # ---- request sampling (uniform over realized arrivals) -----------------
    # scalar cells keep connection-routed and request-routed arrivals in
    # separate weight blocks: conn samples see their server's stationary
    # law, free samples the POOLED fleet law (jsq pools the servers)
    if not batched:
        w = np.concatenate([draws["Nc"].ravel(), draws["Nf"] * free_ok])
    else:
        w = N_arr.ravel()
    total = w.sum()
    K = int(min(cfg.samples, math.ceil(total))) if total > 0 else 0
    if K > 0:
        cum = np.cumsum(w)
        u = rng.random(K) * cum[-1]
        flat = np.searchsorted(cum, u, side="right")
        flat = np.minimum(flat, w.size - 1)
        if not batched:
            is_free = flat >= T * S
            ts = np.where(is_free, flat - T * S, flat // S)
            ss = np.where(is_free, 0, flat % S)
            demand = prog.profile.sample_batch(rng, K)
            if prog.noise_sigma.any():
                sig = np.where(is_free, float(prog.noise_sigma.mean()),
                               prog.noise_sigma[ss])
                demand = demand * np.exp(sig * rng.standard_normal(K))
            spd_i = np.where(is_free, spd_f[ts], speed[ts, ss])
            svc = demand / np.maximum(spd_i, _EPS)
            # wait = inherited backlog (always, PASTA) + the stationary
            # within-slot queue: Bernoulli(Erlang-C) x Exp(conditional).
            # Soft mode reuses the SAME uniform/exponential draws and
            # only smooths the indicator (reparameterization), so the
            # two modes sample the same underlying requests.
            pC_i = np.where(is_free, pC_f[ts], pC[ts, ss])
            u_q = rng.random(K)
            e_q = rng.standard_exponential(K)
            if cfg.soft:
                from repro.vector.soft import stable_sigmoid
                queued = stable_sigmoid(np, (pC_i - u_q) / cfg.tau)
            else:
                queued = u_q < pC_i
            station = queued * e_q \
                * np.where(is_free, w_cond_f[ts], w_cond[ts, ss])
            lat = np.where(is_free, wait_free[ts], wait_U[ts, ss]) \
                + station + svc
            # request-routed arrivals never target a dead server; conn
            # arrivals caught by their server's failure are lost
            fail_t = np.where(is_free | (prog.fail_slot[ss] < 0), np.inf,
                              prog.fail_slot[ss] * dt)
        else:
            ts, ss = np.divmod(flat, S)
            spd_i = speed[ts, ss]
            ptoks, ntoks = prog.lengths.sample_batch(rng, K)
            pf = prog.service.prefill_time_array(ptoks)
            stretch = aux["stretch"][:T, i, :S][ts, ss]
            lat = wait_adm[ts, ss] + \
                (pf + ntoks * st_hat[ts, ss] * stretch) \
                / np.maximum(spd_i, _EPS)
            fail_t = np.where(prog.fail_slot[ss] >= 0,
                              prog.fail_slot[ss] * dt, np.inf)
        completion = centers[ts] + lat
        # censor like the event engine's recorder: completions past the
        # horizon are never recorded, and a request caught on a failing
        # server (arrived in its fail slot, or completing after the fail
        # instant) is lost.  Soft mode additionally keeps the FULL
        # sample with smooth keep-weights for the soft quantile head
        # (the stored samples stay hard-censored for telemetry).
        if cfg.soft:
            from repro.vector.soft import censor_weight
            lat_all = lat
            w_all = censor_weight(np, centers[ts], completion,
                                  prog.duration, fail_t,
                                  80.0 * dt * cfg.tau)
        keep = (completion <= prog.duration) & (centers[ts] < fail_t) \
            & (completion <= fail_t)
        lat = lat[keep]
        completion = completion[keep]
    else:
        lat = np.empty(0)
        completion = np.empty(0)
        lat_all = np.empty(0)
        w_all = np.empty(0)

    out = {"lat": lat, "completion": completion, "n_served": n_served,
           "drained": drained, "Qs": Qs, "drops": drops,
           "tok_served": tok_served if batched else None}
    if cfg.soft:
        out["lat_all"] = lat_all
        out["w_all"] = w_all
    return out


def _grid_quantiles(lats: list, cfg: VectorConfig, backend: str,
                    weights: Optional[list] = None):
    """p50/p95/p99 for every cell of a chunk -> [C, 3] (NaN rows when a
    cell has no samples).

    numpy backend: hoisted-plan partition per row, f64.  jax backend:
    ONE fused launch over a [C, K] +inf-padded f32 matrix — the jnp
    sort oracle (impl="ref") and the Pallas radix-select kernel select
    the same order statistics bit-for-bit, so the impl knob never
    changes a row.  Means are NOT computed here: the row mean stays
    host-side f64 so it cannot depend on the pad width K.

    ``weights`` (soft mode) switches to the differentiable head: the
    full per-cell sample with smooth censor keep-weights, one
    ``soft_quantiles`` launch for the chunk (zero-weight padding).
    """
    C = len(lats)
    counts = np.array([lat.size for lat in lats], np.int64)
    K = int(counts.max()) if C else 0
    if weights is not None:
        from repro.vector.soft import soft_quantiles
        if K == 0:
            return np.full((C, 3), float("nan"))
        import jax.numpy as jnp
        mat = np.full((C, K), np.inf, np.float32)
        wmat = np.zeros((C, K), np.float32)
        for i, (lat, w) in enumerate(zip(lats, weights)):
            mat[i, :lat.size] = lat
            wmat[i, :w.size] = w
        out = soft_quantiles(jnp.asarray(mat), jnp.asarray(wmat),
                             band_frac=cfg.band_frac)
        return np.asarray(out, np.float64)
    if backend != "jax":
        from repro.core.stats import quantiles_partition_batched
        mat = np.zeros((C, max(K, 1)))
        for i, lat in enumerate(lats):
            mat[i, :lat.size] = lat
        return quantiles_partition_batched(mat, counts, (50.0, 95.0, 99.0))
    if K == 0:
        return np.full((C, 3), float("nan"))
    import jax.numpy as jnp

    from repro.kernels import ops as kernel_ops
    mat = np.full((C, K), np.inf, np.float32)
    for i, lat in enumerate(lats):
        mat[i, :lat.size] = lat
    # eager launch (no jit): the kernel pads K internally to the lane
    # tile, so per-(C, K) retraces would defeat the bucketing anyway
    out = kernel_ops.vector_quantiles(jnp.asarray(mat),
                                      jnp.asarray(counts, jnp.int32),
                                      impl=cfg.resolve_impl())
    return np.asarray(out, np.float64)


def _finish_cell(prog: VectorProgram, batched: bool, cell: dict,
                 q3) -> VectorResult:
    T, S = prog.n_slots, prog.n_servers
    dt = prog.dt
    speed = prog.speed
    lat = cell["lat"]
    completion = cell["completion"]
    n_served = cell["n_served"]
    drained = cell["drained"]
    Qs = cell["Qs"]
    tok_served = cell["tok_served"]
    drops = cell["drops"]

    n = int(round(float(n_served.sum())))
    if lat.size:
        p50, p95, p99 = (float(v) for v in q3)
        mean = float(lat.mean())
    else:
        p50 = p95 = p99 = mean = float("nan")

    # ---- interval series ---------------------------------------------------
    spi = max(1, int(round(prog.interval / dt)))     # slots per interval
    n_ivls = int(math.ceil(T / spi))
    pad_to = n_ivls * spi
    def ivl_sum(a):                                   # [T, S] -> [n_ivls, S]
        buf = np.zeros((pad_to, a.shape[1]))
        buf[:T] = a
        return buf.reshape(n_ivls, spi, a.shape[1]).sum(axis=1)

    n_ivl = ivl_sum(n_served).sum(axis=1)
    busy_seconds = (drained / np.maximum(speed, _EPS)) if not batched \
        else drained
    util_cap = prog.workers[None, :] * prog.interval if not batched \
        else np.full((1, S), prog.interval)
    util_ivl = np.minimum(ivl_sum(busy_seconds) / np.maximum(util_cap,
                                                             _EPS), 1.0)
    # queue depth / occupancy at interval boundaries (last slot of each)
    ends = np.minimum(np.arange(1, n_ivls + 1) * spi - 1, T - 1)
    qdepth_ivl = Qs[ends]
    if batched:
        occ_ivl = np.minimum(Qs[ends] / np.maximum(prog.workers[None, :],
                                                   1.0), 1.0)
        tokens_ivl = ivl_sum(tok_served) / prog.interval
    else:
        occ_ivl = util_ivl
        tokens_ivl = None
    sample_ivl = np.minimum(completion / prog.interval,
                            n_ivls - 1 + 1e-9).astype(np.int64) \
        if completion.size else np.empty(0, np.int64)

    # admission shedding (fluid expectation): per-interval shed counts
    # ride the same reshape-sum as the served series, and sheds count
    # into ``dropped`` so they are never silently missing from totals
    if prog.shed_rate is not None:
        shed_slot = np.zeros(pad_to)
        shed_slot[:T] = prog.shed_rate * dt
        shed_ivl = shed_slot.reshape(n_ivls, spi).sum(axis=1)
        shed_total = float(shed_ivl.sum())
    else:
        shed_ivl = None
        shed_total = 0.0

    return VectorResult(
        n=n, mean=mean, p50=float(p50), p95=float(p95), p99=float(p99),
        dropped=int(round(drops + shed_total)) + prog.refused_clients,
        interval=prog.interval, slo=prog.slo, server_ids=prog.server_ids,
        samples=lat, sample_ivl=sample_ivl, n_ivl=n_ivl,
        util_ivl=util_ivl, occ_ivl=occ_ivl, qdepth_ivl=qdepth_ivl,
        tokens_ivl=tokens_ivl, shed_ivl=shed_ivl)


# ---------------------------------------------------------------------------
# Runtime adapter (single cell — scenario CLI / run_task parity)
# ---------------------------------------------------------------------------
class VectorRuntime:
    """``Runtime``-shaped adapter over one (experiment, rep) cell.

    Produces exactly the numbers the grid path produces for the same
    (seed, stream): per-cell RNG derivation makes a cell's results
    independent of the grid it runs in.
    """

    recorder = None                     # no raw-sample recorder: sampled

    def __init__(self, experiment, rep: int = 0,
                 config: Optional[VectorConfig] = None, cache=None):
        from repro.vector.telemetry import VectorTelemetry
        self.experiment = experiment
        self.config = config or VectorConfig()
        self.cache = cache
        self.program = compile_experiment(experiment, dt=self.config.dt)
        self.seed = (experiment.seed, rep)
        self.unsupported = self.program.unsupported
        self.telemetry: Optional[VectorTelemetry] = None
        self.result: Optional[VectorResult] = None

    @property
    def dropped(self) -> int:
        return self.result.dropped if self.result is not None else 0

    @property
    def shed(self) -> int:
        r = self.result
        if r is None or r.shed_ivl is None:
            return 0
        return int(round(float(r.shed_ivl.sum())))

    @property
    def control_log(self) -> list:
        return self.program.control_actions

    def run(self):
        from repro.vector.telemetry import VectorTelemetry
        self.result = run_cells([self.program], [self.seed],
                                self.config, cache=self.cache)[0]
        self.telemetry = VectorTelemetry(self.result)
        return self.telemetry
