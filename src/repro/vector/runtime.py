"""The vector execution engine: fixed-step queueing dynamics for every
grid cell at once.

One ``lax.scan`` (or NumPy slot loop) advances the whole grid's state
``(backlog, queue length, load EMA)`` with axes ``[cell, server]``
through ``n_slots`` fixed steps of width ``dt``:

* per-slot Poisson arrival counts and CLT-aggregated service work are
  pre-drawn per cell from its own seeded ``Generator`` (so a cell's
  numbers do not depend on which grid it runs in);
* connection-routed work lands on its replayed server; request-routed
  work (jsq/p2c) is water-filled onto the least-backlogged accepting
  servers — the fluid limit of join-shortest-queue;
* waiting follows the unfinished-work law: an arrival that must queue
  waits ``backlog / (c * speed)``; the probability it queues blends the
  Erlang-C delay probability at the smoothed offered load with a
  backlog-memory term (exact for c=1 by PASTA);
* batched cells advance the roofline step law per slot: occupancy
  ``b = clip(L, 1, max_batch)``, decode throughput ``b / step_time(b)``
  tokens/sec, prefill seconds served with priority — the same
  ``BatchedService`` cost model the event engine executes op by op.

Latency percentiles come from per-request samples (slot drawn from the
realized arrival weights, own service drawn from the exact law, wait
from the slot's state), censored at the horizon and at server-failure
instants exactly like the event engine's recorder, and extracted in
one ``np.partition`` pass per cell.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.vector.compile import VectorProgram, compile_experiment

_BIG = 1e18
_EPS = 1e-12
#: offered load above which the stationary wait is diffusion-bounded
_NEAR_CRITICAL = 0.9


def has_jax() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except ImportError:
        # ONLY an absent jax means "fall back to numpy" — a broken
        # install must raise, not silently switch backends
        return False


@dataclass
class VectorConfig:
    dt: float = 0.005               # slot width (seconds)
    samples: int = 32768            # latency-sample budget per cell
    backend: str = "auto"           # auto | jax | numpy
    jit: bool = True                # wrap the jax scan in jax.jit
    max_slot_elems: int = 64_000_000   # chunk cells when T*C*S exceeds this

    def resolve_backend(self) -> str:
        if self.backend == "auto":
            return "jax" if has_jax() else "numpy"
        if self.backend == "jax" and not has_jax():
            raise RuntimeError("backend='jax' requested but jax is not "
                               "importable (use 'numpy' or 'auto')")
        return self.backend


# ---------------------------------------------------------------------------
# Per-cell result
# ---------------------------------------------------------------------------
@dataclass
class VectorResult:
    """Extracted results for one (point, rep) cell."""
    n: int
    mean: float
    p50: float
    p95: float
    p99: float
    dropped: int
    interval: float
    slo: Optional[float]
    server_ids: list
    samples: np.ndarray             # kept latency samples (uniform over
                                    # completed requests)
    sample_ivl: np.ndarray          # completion interval per kept sample
    n_ivl: np.ndarray               # [n_ivls] completions per interval
    util_ivl: np.ndarray            # [n_ivls, S] utilization
    occ_ivl: np.ndarray             # [n_ivls, S] occupancy
    qdepth_ivl: np.ndarray          # [n_ivls, S] queue depth at boundary
    tokens_ivl: Optional[np.ndarray] = None   # [n_ivls, S] tokens/sec


# ---------------------------------------------------------------------------
# The scan step (shared math, numpy or jax namespace)
# ---------------------------------------------------------------------------
def _waterfill(xp, U_eff, total):
    """Distribute ``total`` [C] of work over the least-loaded lanes of
    ``U_eff`` [C, S] (masked lanes carry ``_BIG``): fill to a common
    level.  -> per-lane fill amounts [C, S]."""
    S = U_eff.shape[-1]
    sortU = xp.sort(U_eff, axis=-1)
    prefix = xp.cumsum(sortU, axis=-1)
    js = xp.arange(1, S + 1)
    level = (total[..., None] + prefix) / js
    # valid j: level within [sortU[j-1], sortU[j]] (last j open above)
    upper = xp.concatenate([sortU[..., 1:],
                            xp.full(sortU[..., :1].shape, _BIG)], axis=-1)
    valid = (level >= sortU - 1e-9) & (level <= upper + 1e-9)
    idx = xp.argmax(valid, axis=-1)
    L = xp.take_along_axis(level, idx[..., None], axis=-1)
    return xp.clip(L - U_eff, 0.0, None)


def _lgamma(c: np.ndarray) -> np.ndarray:
    """lgamma(c + 1) for small-integer capacity arrays via a lookup
    table (np.vectorize(math.lgamma) over a [slots, cells] array costs
    more than the scan itself)."""
    hi = int(np.max(c)) + 1 if c.size else 1
    table = np.array([math.lgamma(k + 1.0) for k in range(hi + 1)])
    return table[np.clip(c.astype(np.int64), 0, hi)]


def _erlang_c(c, lgamma_c, rho, cmax: int):
    """Erlang-C delay probability (P(arrival must queue) in M/M/c),
    vectorized with per-server integer capacity ``c`` <= cmax.
    Precomputed in numpy from the deterministic per-slot offered load —
    it never enters the scan."""
    rho = np.clip(rho, 1e-9, 0.999)
    a = c * rho
    top = np.exp(c * np.log(a) - lgamma_c)
    term = np.ones_like(a)
    ssum = np.zeros_like(a)
    for k in range(cmax):
        ssum = ssum + np.where(k < c, term, 0.0)
        term = term * a / (k + 1.0)
    denom = (1.0 - rho) * ssum + top
    return top / np.maximum(denom, _EPS)


def _episode_age(rho: np.ndarray, t_idx: np.ndarray, dt: float,
                 band: float = _NEAR_CRITICAL) -> np.ndarray:
    """Seconds since each lane's offered load last sat below ``band``
    — the age of the current near-critical episode (>= dt).  Lanes hot
    from t=0 age from the run start."""
    idx = t_idx.reshape((-1,) + (1,) * (rho.ndim - 1)).astype(float)
    last_low = np.maximum.accumulate(np.where(rho < band, idx, -1.0),
                                     axis=0)
    return np.maximum(idx - last_low, 1.0) * dt


def _scalar_step(xp, consts):
    c = consts["c"]
    fail_slot = consts["fail_slot"]
    dt = consts["dt"]

    def step(carry, xs):
        U, Q, drops = carry
        t, Nc, Wc, Nf, Wf, act, acc, spd = xs
        # failure instant: the resident queue and in-flight work vanish
        is_fail = (t == fail_slot)
        drops = drops + xp.sum(xp.where(is_fail, Q, 0.0), axis=-1)
        U = xp.where(is_fail, 0.0, U)
        Q = xp.where(is_fail, 0.0, Q)
        # request-routed work: water-fill the accepting servers
        n_acc = xp.sum(acc, axis=-1)
        ok = n_acc > 0
        drops = drops + xp.where(ok, 0.0, Nf)
        Wf = xp.where(ok, Wf, 0.0)
        Nf = xp.where(ok, Nf, 0.0)
        U_eff = xp.where(acc > 0, U, _BIG)
        w_free = _waterfill(xp, U_eff, Wf)
        share = w_free / xp.maximum(
            xp.sum(w_free, axis=-1, keepdims=True), _EPS)
        n_free = Nf[..., None] * share
        W_arr = Wc + w_free
        N_arr = Nc + n_free
        # backlog wait an arrival inherits (transients and overload; the
        # stationary within-slot term is added analytically outside);
        # request-routed arrivals land at the water-fill level: they
        # inherit the LEAST backlog any accepting server offers
        wait_U = U / xp.maximum(c * spd, _EPS)
        wait_free = xp.min(xp.where(acc > 0, wait_U, _BIG), axis=-1)
        # serve
        cw = c * spd * act * dt
        drained = xp.minimum(U + W_arr, cw)
        wpr = (U + W_arr) / xp.maximum(Q + N_arr, _EPS)   # work per request
        n_served = xp.minimum(Q + N_arr, drained / xp.maximum(wpr, _EPS))
        U = U + W_arr - drained
        Q = Q + N_arr - n_served
        return (U, Q, drops), (wait_U, wait_free, n_served, drained, Q)
    return step


def _batched_step(xp, consts):
    B = consts["c"]                      # batch slots
    fail_slot = consts["fail_slot"]; dt = consts["dt"]
    tm = consts["tm"]; tc = consts["tc"]
    new_mean = consts["new_mean"]

    def step(carry, xs):
        P, T, L, drops = carry           # prefill s, tokens, requests
        t, Nc, Wpc, Wtc, Nf, Wpf, Wtf, act, acc, spd = xs
        is_fail = (t == fail_slot)
        drops = drops + xp.sum(xp.where(is_fail, L, 0.0), axis=-1)
        P = xp.where(is_fail, 0.0, P)
        T = xp.where(is_fail, 0.0, T)
        L = xp.where(is_fail, 0.0, L)
        # free arrivals: water-fill by queue length (jsq over load())
        n_acc = xp.sum(acc, axis=-1)
        ok = n_acc > 0
        drops = drops + xp.where(ok, 0.0, Nf)
        Nf = xp.where(ok, Nf, 0.0)
        L_eff = xp.where(acc > 0, L, _BIG)
        n_free = _waterfill(xp, L_eff, Nf)
        share = n_free / xp.maximum(
            xp.sum(n_free, axis=-1, keepdims=True), _EPS)
        Wp_arr = Wpc + Wpf[..., None] * share
        Wt_arr = Wtc + Wtf[..., None] * share
        N_arr = Nc + n_free
        # roofline step law at the slot's occupancy
        b = xp.clip(L, 1.0, B)
        st = xp.maximum(tc * b, tm)
        tok_rate = b / st
        avail = act * spd * dt
        p_served = xp.minimum(P + Wp_arr, avail)
        rem = avail - p_served
        tok_served = xp.minimum(T + Wt_arr, rem * tok_rate)
        dec_used = tok_served / xp.maximum(tok_rate, _EPS)
        busy_used = p_served + dec_used
        n_served = xp.minimum(L + N_arr, tok_served / new_mean)
        P = P + Wp_arr - p_served
        T = T + Wt_arr - tok_served
        L = L + N_arr - n_served
        # admission wait: drain-time share ahead of a new arrival
        D = (P + T * st / xp.maximum(b, 1.0)) / xp.maximum(spd, _EPS)
        wait_adm = D * xp.clip((L - B) / xp.maximum(L, 1.0), 0.0, 1.0)
        b_hat = xp.clip(L + 1.0, 1.0, B)
        st_hat = xp.maximum(tc * b_hat, tm)
        return (P, T, L, drops), (wait_adm, st_hat, N_arr, n_served,
                                  busy_used, L, tok_served)
    return step


# ---------------------------------------------------------------------------
# Scan drivers
# ---------------------------------------------------------------------------
def _scan_numpy(step, carry, xs_seq, n_slots: int):
    outs = None
    for t in range(n_slots):
        xs = tuple(x[t] for x in xs_seq)
        carry, ys = step(carry, xs)
        if outs is None:
            outs = tuple(np.empty((n_slots,) + np.shape(y), dtype=float)
                         for y in ys)
        for buf, y in zip(outs, ys):
            buf[t] = y
    return carry, outs


#: (step_builder, jit_flag) -> compiled runner; consts enter as traced
#: pytree arguments, so one trace serves every grid of the same shape
#: signature — repeated sweeps and same-shape chunks pay the jit
#: compile once per process, not once per call
_JIT_CACHE: dict = {}


def _jax_runner(step_builder, jit: bool):
    key = (step_builder, jit)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        import jax
        import jax.numpy as jnp

        def run(consts, carry, xs):
            return jax.lax.scan(step_builder(jnp, consts), carry, xs)

        fn = _JIT_CACHE[key] = jax.jit(run) if jit else run
    return fn


def _scan_jax(step_builder, consts, carry, xs_seq, jit: bool):
    import jax.numpy as jnp

    consts_j = {k: (jnp.asarray(v, jnp.float32)
                    if isinstance(v, np.ndarray) else v)
                for k, v in consts.items()}
    # fail_slot compares against integer slot indices
    consts_j["fail_slot"] = jnp.asarray(consts["fail_slot"], jnp.int32)
    carry_j = tuple(jnp.asarray(c, jnp.float32) for c in carry)
    xs_j = tuple(jnp.asarray(x, jnp.int32 if i == 0 else jnp.float32)
                 for i, x in enumerate(xs_seq))
    out_carry, outs = _jax_runner(step_builder, jit)(consts_j, carry_j,
                                                     xs_j)
    return (tuple(np.asarray(c, np.float64) for c in out_carry),
            tuple(np.asarray(o, np.float64) for o in outs))


# ---------------------------------------------------------------------------
# Grid execution
# ---------------------------------------------------------------------------
def _cell_rng(seed: int, stream: int) -> np.random.Generator:
    """The cell's private RNG: seeded by the sweep-derived (seed,
    stream), domain-separated from every scalar-path stream."""
    return np.random.default_rng((0x7EC7, int(seed), int(stream)))


def _draw_cell(prog: VectorProgram, rng: np.random.Generator) -> dict:
    """Pre-scan draws for one cell, in a FIXED order (the same numbers
    whether the cell runs alone or inside any grid)."""
    dt = prog.dt
    Nc = rng.poisson(prog.rate_conn * dt).astype(float)
    Nf = rng.poisson(prog.rate_free * dt).astype(float)
    if not prog.batched:
        # the scalar backlog is a pure fluid: expected work per slot.
        # Stochastic queueing below saturation is carried entirely by
        # the analytic stationary term (Erlang-C x exponential) — work
        # or count noise here would double-count it — so the fluid
        # captures exactly what the stationary law cannot: transient
        # buildup and overload growth.  Poisson counts still drive the
        # sampling weights and the completion counts.
        m = prog.work_mean                            # [S]
        return {"Nc": Nc, "Wc": prog.rate_conn * dt * m, "Nf": Nf,
                "Wf": prog.rate_free * dt * float(m.mean())}
    zc = rng.standard_normal(Nc.shape)
    zf = rng.standard_normal(Nf.shape)
    zc2 = rng.standard_normal(Nc.shape)
    zf2 = rng.standard_normal(Nf.shape)
    pm, pv = prog.prefill_mean, prog.prefill_var
    nm, nv = prog.new_mean, prog.new_var
    Wpc = np.maximum(Nc * pm + np.sqrt(Nc * pv) * zc, 0.05 * Nc * pm)
    Wtc = np.maximum(Nc * nm + np.sqrt(Nc * nv) * zc2, 0.05 * Nc * nm)
    Wpf = np.maximum(Nf * pm + np.sqrt(Nf * pv) * zf, 0.05 * Nf * pm)
    Wtf = np.maximum(Nf * nm + np.sqrt(Nf * nv) * zf2, 0.05 * Nf * nm)
    return {"Nc": Nc, "Wpc": Wpc, "Wtc": Wtc, "Nf": Nf,
            "Wpf": Wpf, "Wtf": Wtf}


def _pad(a: np.ndarray, T: int, S: int) -> np.ndarray:
    """Zero-pad a per-cell [T_i(, S_i)] array to the group shape."""
    if a.ndim == 1:
        out = np.zeros(T)
        out[:a.shape[0]] = a
        return out
    out = np.zeros((T, S))
    out[:a.shape[0], :a.shape[1]] = a
    return out


def run_cells(programs: Sequence[VectorProgram],
              seeds: Sequence[tuple],
              config: Optional[VectorConfig] = None) -> list[VectorResult]:
    """Execute one cell per (program, (seed, stream)) pair — the whole
    grid as one batched array program per family (scalar / batched),
    chunked to bound scan memory."""
    cfg = config or VectorConfig()
    backend = cfg.resolve_backend()
    results: list[Optional[VectorResult]] = [None] * len(programs)
    for batched in (False, True):
        idxs = [i for i, p in enumerate(programs) if p.batched == batched]
        if not idxs:
            continue
        # chunk cells so T*C*S stays within the memory budget
        T = max(programs[i].n_slots for i in idxs)
        S = max(programs[i].n_servers for i in idxs)
        per_cell = max(T * S, 1)
        chunk = max(1, cfg.max_slot_elems // per_cell)
        for lo in range(0, len(idxs), chunk):
            part = idxs[lo:lo + chunk]
            for i, res in zip(part, _run_family(
                    [programs[i] for i in part],
                    [seeds[i] for i in part], batched, backend, cfg)):
                results[i] = res
    return results  # type: ignore[return-value]


def _run_family(progs: list, seeds: list, batched: bool, backend: str,
                cfg: VectorConfig) -> list[VectorResult]:
    C = len(progs)
    T = max(p.n_slots for p in progs)
    S = max(p.n_servers for p in progs)
    dt = progs[0].dt
    rngs = [_cell_rng(s, st) for s, st in seeds]
    draws = [_draw_cell(p, r) for p, r in zip(progs, rngs)]

    def stack(key: str) -> np.ndarray:
        return np.stack([_pad(d[key], T, S) for d in draws], axis=1)

    def stackp(attr: str) -> np.ndarray:
        return np.stack([_pad(getattr(p, attr), T, S) for p in progs],
                        axis=1)

    act = stackp("active")
    acc = stackp("accepting")
    spd = stackp("speed")
    c = np.stack([np.pad(p.workers, (0, S - p.n_servers)) for p in progs])
    fail = np.stack([np.pad(p.fail_slot, (0, S - p.n_servers),
                            constant_values=-1) for p in progs])
    t_idx = np.arange(T, dtype=np.int64)

    aux = {}
    if not batched:
        m_w = np.stack([np.pad(p.work_mean, (0, S - p.n_servers),
                               constant_values=1.0) for p in progs])
        v_w = np.stack([np.pad(p.work_var, (0, S - p.n_servers))
                        for p in progs])
        consts = {"c": c, "fail_slot": fail, "dt": dt}
        xs = (t_idx, stack("Nc"), stack("Wc"), stack("Nf"), stack("Wf"),
              act, acc, spd)
        carry = tuple(np.zeros((C, S)) for _ in range(2)) + (np.zeros(C),)
        builder = _scalar_step
        # ---- analytic stationary wait (outside the scan) ----------------
        # deterministic per-slot offered load, with request-routed rate
        # spread capacity-proportionally over the accepting servers
        rate_c = np.stack([_pad(p.rate_conn, T, S) for p in progs], axis=1)
        rate_f = np.stack([_pad(p.rate_free, T, S) for p in progs], axis=1)
        cap_share = acc * (c * spd)
        share = cap_share / np.maximum(
            cap_share.sum(axis=-1, keepdims=True), _EPS)
        lam_w = (rate_c + rate_f[..., None] * share) * m_w[None]
        rho_det = np.where(act > 0,
                           lam_w / np.maximum(c * spd, _EPS), 0.0)
        lgamma_c = _lgamma(c)
        cmax = int(c.max()) if c.size else 1
        aux["pC"] = _erlang_c(c[None], lgamma_c[None], rho_det, cmax)
        # conditional wait given queueing: residual service work over
        # the free capacity (exact Pollaczek-Khinchine mean for c=1),
        # bounded near/above criticality by the diffusion growth law
        # E[U(t)] ~ sigma * sqrt(2 t / pi) — a finite run at rho -> 1
        # only builds the queue the random walk had time to build
        e2 = v_w + m_w * m_w
        resid = e2 / np.maximum(2.0 * m_w, _EPS)
        w_stat = resid[None] / np.maximum(
            c[None] * spd * (1.0 - np.clip(rho_det, 0.0, 0.999)), _EPS)
        lam_srv = rho_det * c[None] * spd / np.maximum(m_w[None], _EPS)
        # the diffusion clock runs from the start of the CURRENT
        # near-critical episode, not the run: cyclic loads (diurnal)
        # cross criticality many times, and each crossing only has its
        # own age of random walk behind it
        t_since = _episode_age(rho_det, t_idx, dt)
        growth = np.sqrt(2.0 / math.pi * lam_srv * e2[None] * t_since) \
            / np.maximum(c[None] * spd, _EPS)
        # the diffusion bound only exists near/above criticality —
        # below the band the stationary law stands alone
        aux["w_cond"] = np.where(rho_det < _NEAR_CRITICAL, w_stat,
                                 np.minimum(w_stat, growth))
        # ---- pooled law for request-routed arrivals ---------------------
        # jsq/p2c pool the fleet: an arrival queues only when EVERY
        # accepting server is busy — Erlang-C over the pooled capacity,
        # not independent per-server queues
        m_bar = np.array([float(p.work_mean.mean()) for p in progs])
        e2_bar = np.array([float((p.work_var + p.work_mean ** 2).mean())
                           for p in progs])
        resid_bar = e2_bar / np.maximum(2.0 * m_bar, _EPS)
        cap_pool = (acc * c[None] * spd).sum(axis=-1)          # [T, C]
        work_rate = (rate_c * m_w[None]).sum(axis=-1) \
            + rate_f * m_bar[None]
        rho_pool = np.where(cap_pool > 0,
                            work_rate / np.maximum(cap_pool, _EPS), 0.0)
        c_pool = np.minimum(np.maximum((acc * c[None]).sum(axis=-1), 1.0),
                            64.0)
        aux["pC_free"] = _erlang_c(c_pool, _lgamma(c_pool), rho_pool,
                                   int(c_pool.max()))
        w_stat_f = resid_bar[None] / np.maximum(
            cap_pool * (1.0 - np.clip(rho_pool, 0.0, 0.999)), _EPS)
        lam_pool = rho_pool * cap_pool / np.maximum(m_bar[None], _EPS)
        t_since_f = _episode_age(rho_pool, t_idx, dt)
        growth_f = np.sqrt(2.0 / math.pi * lam_pool * e2_bar[None]
                           * t_since_f) / np.maximum(cap_pool, _EPS)
        aux["w_cond_free"] = np.where(rho_pool < _NEAR_CRITICAL, w_stat_f,
                                      np.minimum(w_stat_f, growth_f))
        aux["free_ok"] = (acc.sum(axis=-1) > 0).astype(float)
        aux["spd_free"] = np.where(
            acc.sum(axis=-1) > 0,
            (acc * c[None] * spd).sum(axis=-1)
            / np.maximum((acc * c[None]).sum(axis=-1), _EPS), 1.0)
    else:
        tm = np.array([p.service.t_memory for p in progs])[:, None]
        tc = np.array([p.service.t_compute_per_seq for p in progs])[:, None]
        nm = np.array([p.new_mean for p in progs])[:, None]
        consts = {"c": c, "fail_slot": fail, "dt": dt, "tm": tm, "tc": tc,
                  "new_mean": nm}
        # a resident's wall-clock pace per own token stretches by the
        # prefill ops interleaved with decode (the engine serializes one
        # op at a time) — deterministic expected prefill time-share
        rate_c = np.stack([_pad(p.rate_conn, T, S) for p in progs], axis=1)
        rate_f = np.stack([_pad(p.rate_free, T, S) for p in progs], axis=1)
        share_even = acc / np.maximum(acc.sum(axis=-1, keepdims=True),
                                      _EPS)
        pf_mean = np.array([p.prefill_mean for p in progs])
        pf_share = np.clip((rate_c + rate_f[..., None] * share_even)
                           * pf_mean[None, :, None]
                           / np.maximum(spd, _EPS), 0.0, 0.8)
        aux["stretch"] = 1.0 / (1.0 - pf_share)
        xs = (t_idx, stack("Nc"), stack("Wpc"), stack("Wtc"), stack("Nf"),
              stack("Wpf"), stack("Wtf"), act, acc, spd)
        carry = tuple(np.zeros((C, S)) for _ in range(3)) + (np.zeros(C),)
        builder = _batched_step

    if backend == "jax":
        carry, outs = _scan_jax(builder, consts, carry, xs, cfg.jit)
    else:
        step = builder(np, dict(consts))
        carry, outs = _scan_numpy(step, carry, xs, T)

    return [_extract(progs[i], rngs[i], i, batched, carry, outs, aux,
                     draws[i], cfg)
            for i in range(C)]


# ---------------------------------------------------------------------------
# Per-cell extraction: sampling, censoring, one-partition percentiles
# ---------------------------------------------------------------------------
def _extract(prog: VectorProgram, rng: np.random.Generator, i: int,
             batched: bool, carry, outs, aux: dict, draws: dict,
             cfg: VectorConfig) -> VectorResult:
    from repro.core.stats import quantiles_partition

    T, S = prog.n_slots, prog.n_servers
    dt = prog.dt
    if not batched:
        wait_U = outs[0][:T, i, :S]
        wait_free = outs[1][:T, i]
        n_served = outs[2][:T, i, :S]
        drained = outs[3][:T, i, :S]
        Qs = outs[4][:T, i, :S]
        pC = aux["pC"][:T, i, :S]
        w_cond = aux["w_cond"][:T, i, :S]
        pC_f = aux["pC_free"][:T, i]
        w_cond_f = aux["w_cond_free"][:T, i]
        free_ok = aux["free_ok"][:T, i]
        spd_f = aux["spd_free"][:T, i]
    else:
        wait_adm, st_hat, N_arr, n_served, drained, Qs, tok_served = \
            (o[:T, i, :S] for o in outs)
    drops = float(carry[-1][i])

    centers = (np.arange(T) + 0.5) * dt
    speed = prog.speed

    # ---- request sampling (uniform over realized arrivals) -----------------
    # scalar cells keep connection-routed and request-routed arrivals in
    # separate weight blocks: conn samples see their server's stationary
    # law, free samples the POOLED fleet law (jsq pools the servers)
    if not batched:
        w = np.concatenate([draws["Nc"].ravel(), draws["Nf"] * free_ok])
    else:
        w = N_arr.ravel()
    total = w.sum()
    K = int(min(cfg.samples, math.ceil(total))) if total > 0 else 0
    if K > 0:
        cum = np.cumsum(w)
        u = rng.random(K) * cum[-1]
        flat = np.searchsorted(cum, u, side="right")
        flat = np.minimum(flat, w.size - 1)
        if not batched:
            is_free = flat >= T * S
            ts = np.where(is_free, flat - T * S, flat // S)
            ss = np.where(is_free, 0, flat % S)
            demand = prog.profile.sample_batch(rng, K)
            if prog.noise_sigma.any():
                sig = np.where(is_free, float(prog.noise_sigma.mean()),
                               prog.noise_sigma[ss])
                demand = demand * np.exp(sig * rng.standard_normal(K))
            spd_i = np.where(is_free, spd_f[ts], speed[ts, ss])
            svc = demand / np.maximum(spd_i, _EPS)
            # wait = inherited backlog (always, PASTA) + the stationary
            # within-slot queue: Bernoulli(Erlang-C) x Exp(conditional)
            queued = rng.random(K) < np.where(is_free, pC_f[ts],
                                              pC[ts, ss])
            station = queued * rng.standard_exponential(K) \
                * np.where(is_free, w_cond_f[ts], w_cond[ts, ss])
            lat = np.where(is_free, wait_free[ts], wait_U[ts, ss]) \
                + station + svc
            # request-routed arrivals never target a dead server; conn
            # arrivals caught by their server's failure are lost
            fail_t = np.where(is_free | (prog.fail_slot[ss] < 0), np.inf,
                              prog.fail_slot[ss] * dt)
        else:
            ts, ss = np.divmod(flat, S)
            spd_i = speed[ts, ss]
            ptoks, ntoks = prog.lengths.sample_batch(rng, K)
            pf = prog.service.prefill_time_array(ptoks)
            stretch = aux["stretch"][:T, i, :S][ts, ss]
            lat = wait_adm[ts, ss] + \
                (pf + ntoks * st_hat[ts, ss] * stretch) \
                / np.maximum(spd_i, _EPS)
            fail_t = np.where(prog.fail_slot[ss] >= 0,
                              prog.fail_slot[ss] * dt, np.inf)
        completion = centers[ts] + lat
        # censor like the event engine's recorder: completions past the
        # horizon are never recorded, and a request caught on a failing
        # server (arrived in its fail slot, or completing after the fail
        # instant) is lost
        keep = (completion <= prog.duration) & (centers[ts] < fail_t) \
            & (completion <= fail_t)
        lat = lat[keep]
        completion = completion[keep]
    else:
        lat = np.empty(0)
        completion = np.empty(0)

    n = int(round(float(n_served.sum())))
    if lat.size:
        p50, p95, p99 = quantiles_partition(lat, (50.0, 95.0, 99.0))
        mean = float(lat.mean())
    else:
        p50 = p95 = p99 = mean = float("nan")

    # ---- interval series ---------------------------------------------------
    spi = max(1, int(round(prog.interval / dt)))     # slots per interval
    n_ivls = int(math.ceil(T / spi))
    pad_to = n_ivls * spi
    def ivl_sum(a):                                   # [T, S] -> [n_ivls, S]
        buf = np.zeros((pad_to, a.shape[1]))
        buf[:T] = a
        return buf.reshape(n_ivls, spi, a.shape[1]).sum(axis=1)

    n_ivl = ivl_sum(n_served).sum(axis=1)
    busy_seconds = (drained / np.maximum(speed, _EPS)) if not batched \
        else drained
    util_cap = prog.workers[None, :] * prog.interval if not batched \
        else np.full((1, S), prog.interval)
    util_ivl = np.minimum(ivl_sum(busy_seconds) / np.maximum(util_cap,
                                                             _EPS), 1.0)
    # queue depth / occupancy at interval boundaries (last slot of each)
    ends = np.minimum(np.arange(1, n_ivls + 1) * spi - 1, T - 1)
    qdepth_ivl = Qs[ends]
    if batched:
        occ_ivl = np.minimum(Qs[ends] / np.maximum(prog.workers[None, :],
                                                   1.0), 1.0)
        tokens_ivl = ivl_sum(tok_served) / prog.interval
    else:
        occ_ivl = util_ivl
        tokens_ivl = None
    sample_ivl = np.minimum(completion / prog.interval,
                            n_ivls - 1 + 1e-9).astype(np.int64) \
        if completion.size else np.empty(0, np.int64)

    return VectorResult(
        n=n, mean=mean, p50=float(p50), p95=float(p95), p99=float(p99),
        dropped=int(round(drops)) + prog.refused_clients,
        interval=prog.interval, slo=prog.slo, server_ids=prog.server_ids,
        samples=lat, sample_ivl=sample_ivl, n_ivl=n_ivl,
        util_ivl=util_ivl, occ_ivl=occ_ivl, qdepth_ivl=qdepth_ivl,
        tokens_ivl=tokens_ivl)


# ---------------------------------------------------------------------------
# Runtime adapter (single cell — scenario CLI / run_task parity)
# ---------------------------------------------------------------------------
class VectorRuntime:
    """``Runtime``-shaped adapter over one (experiment, rep) cell.

    Produces exactly the numbers the grid path produces for the same
    (seed, stream): per-cell RNG derivation makes a cell's results
    independent of the grid it runs in.
    """

    recorder = None                     # no raw-sample recorder: sampled

    def __init__(self, experiment, rep: int = 0,
                 config: Optional[VectorConfig] = None):
        from repro.vector.telemetry import VectorTelemetry
        self.experiment = experiment
        self.config = config or VectorConfig()
        self.program = compile_experiment(experiment, dt=self.config.dt)
        self.seed = (experiment.seed, rep)
        self.unsupported = self.program.unsupported
        self.telemetry: Optional[VectorTelemetry] = None
        self.result: Optional[VectorResult] = None

    @property
    def dropped(self) -> int:
        return self.result.dropped if self.result is not None else 0

    def run(self):
        from repro.vector.telemetry import VectorTelemetry
        self.result = run_cells([self.program], [self.seed],
                                self.config)[0]
        self.telemetry = VectorTelemetry(self.result)
        return self.telemetry
