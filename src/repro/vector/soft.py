"""Smoothed (differentiable) counterparts of the vector runtime's hard
primitives — the ``VectorConfig.soft=True`` mode.

Every hard decision in the vector dynamics is a kink or a step that
kills gradients: the water-filling ``argmin`` over server backlogs, the
``rho < 0.999`` clip inside Erlang-C, the queue/no-queue Bernoulli
indicator, the horizon/failure censoring mask, and the order-statistic
quantile extraction.  This module replaces each with a
temperature-controlled relaxation that (a) recovers the hard operator
as ``tau -> 0`` and (b) keeps a usable gradient near the places
capacity planning actually cares about (rho ~= 1, the p99 rank).

Design rules shared by every primitive here:

* one temperature knob ``tau`` (dimensionless); primitives that compare
  quantities with physical units rescale it by a magnitude estimate of
  their operands, so ``tau=0.05`` means "5% of the operand scale"
  everywhere;
* masked lanes (``_BIG`` backlogs, ``+inf`` quantile padding) must fall
  out EXACTLY — the sigmoids saturate to literal 0.0/1.0 there, so soft
  mode never leaks mass through a dead server or a pad slot;
* the quantile surrogate anchors on ``repro.kernels.ref``'s
  ``quantile_ranks`` / ``quantile_lerp`` — the exact kernel's rank
  plan, not a reimplementation — so soft and hard heads interpolate
  between the SAME order statistics (a test pins the identity).

The scan-step relaxations are ``xp``-generic like the hard step math;
the quantile head is jnp-only (it exists to be differentiated).
"""
from __future__ import annotations

import math

import numpy as np

_EPS = 1e-12
_BIG = 1e18
#: utilization ceiling shared with the hard Erlang-C clip
RHO_MAX = 0.999


def stable_sigmoid(xp, x):
    """Overflow-safe logistic; saturates to exact 0.0/1.0 so masked
    (``_BIG``) operands drop out bit-exactly."""
    z = xp.exp(-xp.abs(x))
    return xp.where(x >= 0, 1.0 / (1.0 + z), z / (1.0 + z))


def softplus(xp, x):
    """Overflow-safe ``log(1 + exp(x))`` (= x for large x, 0 for very
    negative x)."""
    return xp.maximum(x, 0.0) + xp.log1p(xp.exp(-xp.abs(x)))


def smooth_min(xp, a, b, tau):
    """Soft ``min(a, b)``: ``-tau * logsumexp(-[a, b]/tau)``, written in
    the overflow-safe two-operand form.  Always <= min(a, b); recovers
    it as ``tau -> 0``."""
    m = xp.minimum(a, b)
    return m - tau * xp.log1p(xp.exp(-xp.abs(a - b) / tau))


def smooth_rho(xp, rho, tau, hi: float = RHO_MAX):
    """Utilization with the Erlang-C ceiling applied smoothly: the hard
    path's ``clip(rho, 1e-9, 0.999)`` flattens the loss surface to zero
    gradient the moment a candidate fleet saturates — exactly where the
    planner needs a slope pointing back toward feasibility.  The soft
    ceiling ``smooth_min(rho, hi)`` keeps ``1 - rho`` >= ``1 - hi`` (so
    every downstream ``1/(1-rho)`` stays finite) while ``d rho/d x``
    survives arbitrarily deep into overload."""
    return xp.maximum(smooth_min(xp, rho, hi, tau * hi), 1e-9)


def soft_waterfill(xp, U_eff, total, tau):
    """Temperature-controlled relaxation of ``_waterfill``: distribute
    ``total`` [C] over the least-loaded lanes of ``U_eff`` [C, S].

    The hard operator has two kinks: the active-set membership test
    (``U_i <= U_k``) and the final ``relu(L - U)``.  Both become
    sigmoids/softplus at a temperature scaled by the per-cell operand
    magnitude, and the level itself becomes a softmin over the lane
    proposals.  Fills are renormalized so the slot conserves work mass
    exactly at ANY temperature — the relaxation may misallocate between
    near-tied servers but can never create or destroy work.  Masked
    lanes (``_BIG``) saturate every sigmoid and contribute exact zeros.
    """
    fin = U_eff < (_BIG * 0.5)
    n_fin = xp.sum(xp.where(fin, 1.0, 0.0), axis=-1)
    u_sum = xp.sum(xp.where(fin, U_eff, 0.0), axis=-1)
    # operand magnitude: mean finite backlog + the incoming work itself
    scale = (u_sum + total) / xp.maximum(n_fin, 1.0) + _EPS
    t = tau * scale
    mine = U_eff[..., :, None]
    other = U_eff[..., None, :]
    le = stable_sigmoid(xp, (mine - other) / t[..., None, None])
    cnt = xp.sum(le, axis=-1)
    wsum = xp.sum(le * xp.where(fin, U_eff, 0.0)[..., None, :], axis=-1)
    level = (total[..., None] + wsum) / xp.maximum(cnt, 0.5)
    # softmin over lane proposals, anchored at the hard min for safety
    lmin = xp.min(level, axis=-1, keepdims=True)
    w_prop = xp.exp(-(level - lmin) / t[..., None])
    L = xp.sum(level * w_prop, axis=-1, keepdims=True) \
        / xp.maximum(xp.sum(w_prop, axis=-1, keepdims=True), _EPS)
    fill = softplus(xp, (L - U_eff) / t[..., None]) * t[..., None]
    # conserve the slot's work mass exactly at any temperature
    fsum = xp.sum(fill, axis=-1, keepdims=True)
    return fill * (total[..., None] / xp.maximum(fsum, _EPS))


def _np_lgamma1p(c: np.ndarray) -> np.ndarray:
    """lgamma(c + 1) elementwise; dedup first — capacity arrays hold a
    handful of distinct values over [T, C, S] elements."""
    flat = np.asarray(c, float).ravel()
    vals, inv = np.unique(flat, return_inverse=True)
    table = np.array([math.lgamma(v + 1.0) for v in vals])
    return table[inv].reshape(np.shape(c))


def soft_erlang_c(xp, c, rho, cmax: int, tau):
    """Erlang-C delay probability with CONTINUOUS capacity ``c`` and a
    smooth utilization ceiling — the differentiable twin of
    ``_erlang_c``.

    Two discrete structures go soft: the factorial becomes
    ``lgamma(c + 1)`` (exact at integers, smooth between), and the
    truncated-sum membership ``k < c`` becomes a sigmoid gate at
    ``c - k - 0.5`` so fractional capacity blends adjacent integer
    laws instead of jumping.  ``rho`` passes through ``smooth_rho`` so
    the delay probability saturates to ~1 smoothly as the fleet
    saturates instead of clipping flat.  At integer ``c`` and
    ``tau <= 0.05`` the gates are within 1e-4 of the hard sum, so the
    forward pass agrees with ``_erlang_c`` to the same order."""
    rho_s = smooth_rho(xp, rho, tau)
    a = c * rho_s
    if xp is np:
        lg = _np_lgamma1p(c)
    else:
        from jax import lax
        # c * 1.0 promotes integer inputs; float inputs keep their
        # dtype (f64 under enable_x64, where the FD grad checks run)
        lg = lax.lgamma(xp.asarray(c * 1.0))
    top = xp.exp(c * xp.log(xp.maximum(a, _EPS)) - lg)
    term = xp.ones_like(a)
    ssum = xp.zeros_like(a)
    for k in range(cmax):
        gate = stable_sigmoid(xp, (c - k - 0.5) / tau)
        ssum = ssum + gate * term
        term = term * a / (k + 1.0)
    denom = (1.0 - rho_s) * ssum + top
    return top / xp.maximum(denom, _EPS)


def censor_weight(xp, arrive_t, completion, horizon, fail_t, tau):
    """Smooth keep-weight for one sampled request — the relaxation of
    the recorder's hard censoring mask ``(completion <= horizon) &
    (arrive < fail) & (completion <= fail)``.  ``tau`` is in seconds
    (a few slot widths); ``fail_t = +inf`` saturates its sigmoids to
    exact 1.0, so unfailed servers censor only at the horizon."""
    w = stable_sigmoid(xp, (horizon - completion) / tau)
    w = w * stable_sigmoid(xp, (fail_t - arrive_t) / tau)
    return w * stable_sigmoid(xp, (fail_t - completion) / tau)


def soft_quantiles(lat, weights, qs=None, band_frac: float = 5e-4):
    """Differentiable weighted-quantile head: ``[C, K]`` latencies with
    per-sample keep-weights -> ``[C, len(qs)]``.

    Anchored on the exact kernel's rank plan: ``quantile_ranks`` gives
    the (pos, lo, hi) order statistics np.percentile would select at
    the effective (weighted) count, a Gaussian kernel over fractional
    ranks turns each anchor into a soft order statistic, and
    ``quantile_lerp`` blends the two anchors with the exact path's
    interpolation — so as the band shrinks the head converges to
    ``fused_quantiles`` on unit weights.  The kernel bandwidth is
    ``max(0.5, band_frac * n_eff)`` ranks: 0.5 keeps adjacent integer
    ranks resolvable (forward agreement), larger fractions widen the
    gradient support for planning.  Pad slots must carry weight 0.0
    (their value may be ``+inf``); rows with no effective samples
    return NaN like the hard head."""
    import jax.numpy as jnp

    from repro.kernels.ref import VECTOR_QS, quantile_lerp, quantile_ranks
    if qs is None:
        qs = VECTOR_QS
    order = jnp.argsort(lat, axis=-1)
    xs = jnp.take_along_axis(lat, order, axis=-1)
    ws = jnp.take_along_axis(weights, order, axis=-1)
    xs = jnp.where(ws > 0.0, xs, 0.0)         # never 0 * inf at the pads
    cum = jnp.cumsum(ws, axis=-1)
    n_eff = cum[..., -1]
    # each sample sits at the center of its own weight mass, 0-indexed:
    # unit weights give ranks 0..K-1 exactly
    r = cum - 0.5 * ws - 0.5
    pos, lo, hi = quantile_ranks(n_eff, qs)
    band = jnp.maximum(band_frac * n_eff, 0.5)[..., None, None]

    def soft_os(rank):                         # [C, Q] -> [C, Q]
        d = (r[..., None, :] - rank[..., :, None]) / band
        k = jnp.exp(-0.5 * d * d) * ws[..., None, :]
        num = jnp.sum(k * xs[..., None, :], axis=-1)
        return num / jnp.maximum(jnp.sum(k, axis=-1), _EPS)

    a = soft_os(lo.astype(jnp.float32))
    b = soft_os(hi.astype(jnp.float32))
    out = quantile_lerp(a, b, pos - lo.astype(jnp.float32))
    return jnp.where(n_eff[..., None] > 0.5, out, jnp.nan)
