"""Sweep specification: axes, points, repetitions, seeds, metrics.

A ``Sweep`` declares WHAT to run — a parameter grid over
``Experiment``/``Scenario`` builders, how many seeded repetitions per
point, and which metrics to extract — and leaves HOW to run it to
``repro.sweep.executor`` (serial or process-parallel, identical
results either way).

Seed derivation
---------------

Repetition seeding is where tail-latency benchmarks silently go wrong
("Tell-Tale Tail Latencies", "Sampling in Cloud Benchmarking"): ad-hoc
arithmetic like ``seed + 1000*(rep+1)`` collides across points (point
seed 0 / rep 1 replays point seed 1000 / rep 0), quietly correlating
supposedly independent repetitions.  The default ``"spawn"`` seeder
derives every (point, rep) seed from
``np.random.SeedSequence(base_seed, spawn_key=(point_index, rep))`` —
the SeedSequence spawn tree guarantees stream independence for every
(point, rep) pair, for any grid shape.

Named seeders (``Sweep.seeder``):

``"spawn"``
    ``(spawn_seed(base, point, rep), rep)`` — the collision-free
    default; the repetition index also threads into the client RNG
    streams so explicitly-seeded clients draw independent arrivals.
``"run-repeated"``
    ``(base + 1000*(rep+1), rep)`` — bit-compatible with the legacy
    ``run_repeated`` helper (which is now a shim over this).
``"fixed"``
    ``(base, 0)`` — the factory owns all per-rep variation (single-run
    figures, or factories that derive their own seeds from ``ctx.rep``).
``"rep"``
    ``(base + rep, 0)`` — the repetition index IS the seed (legacy
    figure scripts that loop ``for seed in range(13)``).

A custom ``(base_seed, point_index, rep) -> (seed, rng_stream)``
callable is also accepted (module-level, so it pickles to workers).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional, Sequence, Union

import numpy as np

#: metric names the executor resolves against ``telemetry.overall()``
SUMMARY_METRICS = ("n", "mean", "p50", "p95", "p99")
DEFAULT_METRICS = SUMMARY_METRICS
#: extra metric names with dedicated extractors
EXTRA_METRICS = ("dropped", "slo_frac", "shed", "timeouts", "retries")


# ---------------------------------------------------------------------------
# Seed derivation
# ---------------------------------------------------------------------------
def spawn_seed(base_seed: int, point_index: int, rep: int) -> int:
    """Collision-free (point, rep) seed via the SeedSequence spawn tree."""
    ss = np.random.SeedSequence(base_seed, spawn_key=(point_index, rep))
    return int(ss.generate_state(1, np.uint32)[0])


def _seed_spawn(base: int, index: int, rep: int) -> tuple:
    return spawn_seed(base, index, rep), rep


def _seed_run_repeated(base: int, index: int, rep: int) -> tuple:
    return base + 1000 * (rep + 1), rep


def _seed_fixed(base: int, index: int, rep: int) -> tuple:
    return base, 0


def _seed_rep(base: int, index: int, rep: int) -> tuple:
    return base + rep, 0


SEEDERS: dict[str, Callable[[int, int, int], tuple]] = {
    "spawn": _seed_spawn,
    "run-repeated": _seed_run_repeated,
    "fixed": _seed_fixed,
    "rep": _seed_rep,
}


# ---------------------------------------------------------------------------
# Points
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Axis:
    """One sweepable parameter: a name and its ordered values."""
    name: str
    values: tuple

    def __post_init__(self):
        object.__setattr__(self, "values", tuple(self.values))
        if not self.values:
            raise ValueError(f"axis {self.name!r} has no values")


@dataclass(frozen=True)
class PointCtx:
    """Everything a point factory may consume: the point's parameters,
    its position in the sweep, and the derived seed/RNG stream."""
    params: dict
    index: int          # point index in declaration order
    rep: int            # repetition index
    seed: int           # derived experiment seed (factories may override)
    stream: int         # repetition RNG stream (threads into client RNGs)


def _as_axes(axes) -> tuple:
    out = []
    for ax in axes:
        if isinstance(ax, Axis):
            out.append(ax)
        else:                       # (name, values) pair
            name, values = ax
            out.append(Axis(name, tuple(values)))
    return tuple(out)


# ---------------------------------------------------------------------------
# Sweep
# ---------------------------------------------------------------------------
@dataclass
class Sweep:
    """A declarative experiment grid.

    ``factory(ctx: PointCtx) -> Experiment | Scenario`` builds one run;
    use ``experiment_factory``/``scenario_factory`` for the common
    cases.  Factories must be module-level callables (or partials of
    them) to run on the process executor.

    Point forms (``mode``): ``"grid"`` takes the cartesian product of
    ``axes`` in declaration order (first axis outermost), ``"zip"``
    zips equal-length axes, ``"points"`` uses the explicit ``points``
    dicts.  ``fixed`` parameters merge into every point.

    ``mode="optimize"`` is the gradient-planner entry point: instead of
    enumerating points, the executor hands the spec to
    ``repro.plan.run_plan_sweep``, which optimizes the ``optimize``
    block's parameters through the smoothed vector surrogate and
    verifies the answer on the exact runtime.  ``fixed`` becomes the
    scenario overrides, ``reps``/``base_seed`` keep their meanings, and
    ``factory``/``axes`` are unused (pass ``factory=None``).

    ``runtime`` picks the execution backend: ``"sim"`` (virtual-time
    simulator), ``"engine"`` (wall-clock ``EngineRuntime`` driving
    stub engines on a virtual clock), or ``"vector"`` (the batched
    array backend: every (point, rep) cell of the sweep advances
    simultaneously as one jitted array program — statistically
    equivalent to ``sim``, ~20x the points/sec).  A point may override
    it via a ``"runtime"`` parameter — the backend itself is a
    sweepable axis (that is how ``fig_batching`` declares its
    sim-vs-engine knees, and how a vector sweep can carry a sim
    control arm in the same frame).
    """
    name: str
    factory: Callable[[PointCtx], object]
    axes: Sequence = ()
    mode: str = "grid"                  # grid | zip | points
    points: Sequence[dict] = ()
    fixed: dict = field(default_factory=dict)
    reps: int = 13                      # the paper's repetition count
    base_seed: int = 0
    seeder: Union[str, Callable[[int, int, int], tuple]] = "spawn"
    metrics: Sequence = DEFAULT_METRICS
    telemetry: bool = False             # capture per-interval series rows
    per_client: bool = False            # capture per-client summaries
    runtime: str = "sim"                # sim | engine (stub replicas)
    optimize: Optional[dict] = None     # mode="optimize": planner knobs
                                        # (see repro.plan.PlanSpec)

    def __post_init__(self):
        self.axes = _as_axes(self.axes)
        if self.mode not in ("grid", "zip", "points", "optimize"):
            raise ValueError(f"unknown sweep mode: {self.mode!r}")
        if self.mode == "optimize":
            if not self.optimize:
                raise ValueError("mode='optimize' needs an optimize "
                                 "block (at least an 'slo')")
            if self.axes or self.points:
                raise ValueError("mode='optimize' takes no axes/points "
                                 "— the planner owns the search")
            if self.reps < 1:
                raise ValueError("reps must be >= 1")
            return
        if self.optimize:
            raise ValueError(f"optimize block given but "
                             f"mode={self.mode!r} (use mode='optimize')")
        if self.mode == "points" and not self.points:
            raise ValueError("mode='points' needs a non-empty points list")
        if self.mode != "points" and self.points:
            raise ValueError(f"points given but mode={self.mode!r}: they "
                             f"would be silently ignored (use "
                             f"mode='points')")
        if self.mode != "points" and not self.axes:
            # a 1-point sweep (reps only) is legal: one empty point
            self.mode = "points"
            self.points = ({},)
        if self.mode == "zip":
            lens = {len(ax.values) for ax in self.axes}
            if len(lens) > 1:
                raise ValueError(f"zip axes differ in length: {sorted(lens)}")
        if self.reps < 1:
            raise ValueError("reps must be >= 1")
        if self.runtime not in ("sim", "engine", "vector"):
            raise ValueError(f"unknown runtime: {self.runtime!r}")
        if isinstance(self.seeder, str) and self.seeder not in SEEDERS:
            raise ValueError(f"unknown seeder {self.seeder!r}; "
                             f"named: {sorted(SEEDERS)}")

    # ------------------------------------------------------------- points
    def point_dicts(self) -> list[dict]:
        """The sweep's points, in deterministic declaration order."""
        if self.mode == "optimize":
            return []               # the planner owns the search space
        if self.mode == "points":
            pts = [dict(p) for p in self.points]
        elif self.mode == "zip":
            pts = [dict(zip((ax.name for ax in self.axes), combo))
                   for combo in zip(*(ax.values for ax in self.axes))]
        else:                          # grid: first axis outermost
            pts = [dict(zip((ax.name for ax in self.axes), combo))
                   for combo in itertools.product(
                       *(ax.values for ax in self.axes))]
        if self.fixed:
            pts = [{**self.fixed, **p} for p in pts]
        return pts

    def tasks(self) -> list[tuple]:
        """Flat (point_index, params, rep) work list, declaration order."""
        return [(i, params, rep)
                for i, params in enumerate(self.point_dicts())
                for rep in range(self.reps)]

    def seed_for(self, point_index: int, rep: int) -> tuple:
        """-> (experiment seed, repetition RNG stream) for one task."""
        fn = SEEDERS[self.seeder] if isinstance(self.seeder, str) \
            else self.seeder
        seed, stream = fn(self.base_seed, point_index, rep)
        return int(seed), int(stream)

    def describe(self) -> dict:
        """JSON-friendly spec metadata (recorded into the ResultFrame)."""
        return {
            "name": self.name,
            "mode": self.mode,
            "axes": {ax.name: list(ax.values) for ax in self.axes},
            "n_points": len(self.point_dicts()),
            "fixed": dict(self.fixed),
            "reps": self.reps,
            "base_seed": self.base_seed,
            "seeder": (self.seeder if isinstance(self.seeder, str)
                       else getattr(self.seeder, "__name__", "custom")),
            "metrics": [m if isinstance(m, str) else m[0]
                        for m in self.metrics],
            "runtime": self.runtime,
            "telemetry": self.telemetry,
            "per_client": self.per_client,
            **({"optimize": dict(self.optimize)} if self.optimize else {}),
        }


# ---------------------------------------------------------------------------
# Common factories
# ---------------------------------------------------------------------------
#: point-param keys the EXECUTOR consumes (never the point factory) —
#: custom factories building from ``ctx.params`` should go through
#: ``factory_params`` so a sweep stays free to add these axes
EXECUTOR_PARAMS = ("runtime",)


def factory_params(ctx: PointCtx) -> dict:
    """``ctx.params`` minus the executor-consumed keys — what a factory
    may forward verbatim to an ``Experiment``/scenario builder."""
    return {k: v for k, v in ctx.params.items()
            if k not in EXECUTOR_PARAMS}


def _experiment_point(base_exp, ctx: PointCtx):
    from dataclasses import replace
    return replace(base_exp, seed=ctx.seed, **factory_params(ctx))


def experiment_factory(base_exp) -> Callable[[PointCtx], object]:
    """Factory over a base ``Experiment``: point params map onto its
    dataclass fields via ``replace`` and the derived seed is applied
    (a ``"runtime"`` axis goes to the executor, not the dataclass)."""
    return partial(_experiment_point, base_exp)


def _scenario_point(name: str, ctx: PointCtx):
    from repro.scenarios import get
    return get(name, seed=ctx.seed, **factory_params(ctx))


def scenario_factory(name: str) -> Callable[[PointCtx], object]:
    """Factory over a canonical scenario: point params become builder
    keyword overrides (``qps``, ``n_servers``, ``duration``, ...) and
    the derived seed becomes the scenario seed.  A ``"runtime"`` param
    is consumed by the executor, not the builder."""
    return partial(_scenario_point, name)
