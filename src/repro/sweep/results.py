"""ResultFrame: the sweep artifact — typed rows, round-trip, compare.

One ``SweepRow`` per (point, repetition): the point's parameters, the
derived seed/stream, the extracted metrics, and (optionally) per-client
summaries and per-interval telemetry series.  ``ResultFrame`` holds the
rows plus the sweep's spec metadata and provides:

* ``aggregate(metric)`` — per-point mean and 95% CI across repetitions
  (the paper's error bars, via ``confidence95``);
* ``compare(other, metric)`` — Welch's t-test between two frames over
  the filter-matching rows (the paper's Table-4 equivalence
  methodology, reusable for any A/B sweep);
* ``to_json``/``from_json`` — exact round-trip (floats survive
  bit-for-bit through ``repr``-based JSON encoding, NaN included);
* ``to_csv`` — flat per-row or aggregated CSV, the benchmark artifact
  format the figure scripts and CI emit.
"""
from __future__ import annotations

import csv
import json
import math
import os
from dataclasses import dataclass, field
from typing import Optional

from repro.core.stats import confidence95, welch_ttest


@dataclass
class SweepRow:
    """One (point, repetition) outcome."""
    index: int                          # point index in declaration order
    params: dict
    rep: int
    seed: int                           # experiment seed actually used
    stream: int                         # repetition RNG stream
    metrics: dict = field(default_factory=dict)
    clients: Optional[dict] = None      # cid(str) -> summary dict
    series: Optional[list] = None       # per-interval rows (cid -1 = overall)
    error: Optional[str] = None         # failure capture: row kept, run lost

    @property
    def ok(self) -> bool:
        return self.error is None

    def to_dict(self) -> dict:
        d = {"index": self.index, "params": self.params, "rep": self.rep,
             "seed": self.seed, "stream": self.stream,
             "metrics": self.metrics}
        if self.clients is not None:
            d["clients"] = self.clients
        if self.series is not None:
            d["series"] = self.series
        if self.error is not None:
            d["error"] = self.error
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SweepRow":
        return cls(index=d["index"], params=d["params"], rep=d["rep"],
                   seed=d["seed"], stream=d["stream"],
                   metrics=d.get("metrics", {}),
                   clients=d.get("clients"), series=d.get("series"),
                   error=d.get("error"))


def series_window(series: list, metric: str, lo: int = 0,
                  hi: Optional[int] = None, cid: int = -1) -> list:
    """Per-interval ``metric`` values over ``[lo, hi)`` for one client
    (``-1`` = overall) from a row's captured telemetry series — the same
    windowing ``MetricsPipeline.window`` provides on a live run."""
    return [r[metric] for r in (series or ())
            if r["cid"] == cid and r["t"] >= lo
            and (hi is None or r["t"] < hi)]


@dataclass
class ResultFrame:
    """The result store for one executed sweep."""
    name: str
    spec: dict = field(default_factory=dict)   # Sweep.describe() metadata
    rows: list = field(default_factory=list)   # SweepRow, (index, rep) order

    # ---------------------------------------------------------- selection
    @property
    def ok_rows(self) -> list:
        return [r for r in self.rows if r.ok]

    @property
    def errors(self) -> list:
        return [r for r in self.rows if not r.ok]

    def raise_errors(self) -> "ResultFrame":
        """Raise if any row failed, carrying the captured error text —
        for consumers (the figure scripts) that need every point and
        would otherwise crash on an empty ``metrics`` dict with the real
        failure message sitting unread in ``row.error``."""
        if self.errors:
            detail = "; ".join(f"point={r.params} rep={r.rep}: {r.error}"
                               for r in self.errors[:5])
            more = len(self.errors) - 5
            if more > 0:
                detail += f" (+{more} more)"
            raise RuntimeError(f"sweep {self.name!r}: "
                               f"{len(self.errors)} failed rows — {detail}")
        return self

    def point_rows(self, index: int) -> list:
        return [r for r in self.rows if r.index == index]

    def values(self, metric: str, **filters) -> list:
        """Metric values (row order) over rows matching all ``filters``
        (matched against point params)."""
        return [r.metrics[metric] for r in self.ok_rows
                if all(r.params.get(k) == v for k, v in filters.items())]

    # --------------------------------------------------------- aggregation
    def points(self) -> list[tuple]:
        """Distinct (index, params) in declaration order."""
        seen: dict[int, dict] = {}
        for r in self.rows:
            seen.setdefault(r.index, r.params)
        return sorted(seen.items())

    def aggregate(self, metric: str) -> list[dict]:
        """Per-point mean + 95% CI half-width across repetitions.

        Failed repetitions are excluded from the aggregate (their count
        shows up as ``n_failed``); a fully-failed point aggregates to
        NaN rather than vanishing."""
        by_index: dict[int, list] = {}          # one pass, not O(points x rows)
        for r in self.rows:
            by_index.setdefault(r.index, []).append(r)
        out = []
        for index, params in self.points():
            rows = by_index.get(index, [])
            vals = [r.metrics[metric] for r in rows if r.ok]
            mean, ci = confidence95(vals)
            out.append({"index": index, "params": params, "metric": metric,
                        "mean": mean, "ci95": ci, "n_reps": len(vals),
                        "n_failed": sum(1 for r in rows if not r.ok),
                        "vals": vals})
        return out

    def compare(self, other: "ResultFrame", metric: str,
                **filters) -> "WelchCompare":
        """Welch's t-test of ``metric`` between this frame and another,
        POOLING every row that matches the param ``filters`` on each
        side — pin the filters to one grid point for a per-point test
        (unfiltered, between-point variance enters the pooled samples).
        Retained H0 (|t| < 2, p > 0.05) means the two sides are
        statistically indistinguishable, the paper's equivalence
        criterion."""
        a = self.values(metric, **filters)
        b = other.values(metric, **filters)
        w = welch_ttest(a, b)
        return WelchCompare(metric=metric, t_stat=w.t_stat,
                            p_value=w.p_value, n_a=len(a), n_b=len(b),
                            retained=bool(abs(w.t_stat) < 2
                                          and w.p_value > 0.05)
                            if not math.isnan(w.t_stat) else False)

    # --------------------------------------------------------- round-trip
    def to_dict(self) -> dict:
        return {"name": self.name, "spec": self.spec,
                "rows": [r.to_dict() for r in self.rows]}

    def to_json(self, path: Optional[str] = None, indent: int = 1) -> str:
        """Serialize (and optionally write) the frame.  Python's JSON
        encoder emits ``repr``-exact floats (and NaN/Infinity literals),
        so ``from_json(to_json(frame))`` reproduces every value
        bit-for-bit."""
        text = json.dumps(self.to_dict(), indent=indent)
        if path is not None:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            with open(path, "w") as f:
                f.write(text)
        return text

    @classmethod
    def from_json(cls, text_or_path: str) -> "ResultFrame":
        if "\n" not in text_or_path and os.path.exists(text_or_path):
            with open(text_or_path) as f:
                text = f.read()
        else:
            text = text_or_path
        d = json.loads(text)
        return cls(name=d["name"], spec=d.get("spec", {}),
                   rows=[SweepRow.from_dict(r) for r in d.get("rows", [])])

    # --------------------------------------------------------------- CSV
    def to_csv(self, path: str, aggregated: Optional[str] = None) -> str:
        """Write the frame as CSV.  Default: one row per (point, rep)
        with params and metrics flattened.  ``aggregated=<metric>``
        writes the per-point mean/ci95 table for that metric instead."""
        if aggregated is not None:
            rows = [{**a["params"], "metric": aggregated, "mean": a["mean"],
                     "ci95": a["ci95"], "n_reps": a["n_reps"],
                     "n_failed": a["n_failed"]}
                    for a in self.aggregate(aggregated)]
        else:
            rows = []
            for r in self.rows:
                rows.append({**r.params, "rep": r.rep, "seed": r.seed,
                             **r.metrics,
                             "error": r.error if r.error else ""})
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        cols: list = []
        for r in rows:
            for c in r:
                if c not in cols:
                    cols.append(c)
        # csv.writer, not ','.join: error rows carry free-form exception
        # text that needs real quoting
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(cols)
            for r in rows:
                w.writerow([r.get(c, "") for c in cols])
        return path


@dataclass(frozen=True)
class WelchCompare:
    metric: str
    t_stat: float
    p_value: float
    n_a: int
    n_b: int
    retained: bool
