"""ResultFrame: the sweep artifact — typed rows, round-trip, compare.

One ``SweepRow`` per (point, repetition): the point's parameters, the
derived seed/stream, the extracted metrics, and (optionally) per-client
summaries and per-interval telemetry series.  ``ResultFrame`` holds the
rows plus the sweep's spec metadata and provides:

* ``aggregate(metric)`` — per-point mean and 95% CI across repetitions
  (the paper's error bars, via ``confidence95``);
* ``compare(other, metric)`` — Welch's t-test between two frames over
  the filter-matching rows (the paper's Table-4 equivalence
  methodology, reusable for any A/B sweep);
* ``to_json``/``from_json`` — exact round-trip (floats survive
  bit-for-bit through ``repr``-based JSON encoding, NaN included);
* ``to_csv`` — flat per-row or aggregated CSV, the benchmark artifact
  format the figure scripts and CI emit.
"""
from __future__ import annotations

import csv
import json
import math
import os
from dataclasses import dataclass, field
from typing import Optional

from repro.core.stats import confidence95, welch_ttest


@dataclass
class SweepRow:
    """One (point, repetition) outcome."""
    index: int                          # point index in declaration order
    params: dict
    rep: int
    seed: int                           # experiment seed actually used
    stream: int                         # repetition RNG stream
    metrics: dict = field(default_factory=dict)
    clients: Optional[dict] = None      # cid(str) -> summary dict
    series: Optional[list] = None       # per-interval rows (cid -1 = overall)
    error: Optional[str] = None         # failure capture: row kept, run lost

    @property
    def ok(self) -> bool:
        return self.error is None

    def to_dict(self) -> dict:
        d = {"index": self.index, "params": self.params, "rep": self.rep,
             "seed": self.seed, "stream": self.stream,
             "metrics": self.metrics}
        if self.clients is not None:
            d["clients"] = self.clients
        if self.series is not None:
            d["series"] = self.series
        if self.error is not None:
            d["error"] = self.error
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SweepRow":
        return cls(index=d["index"], params=d["params"], rep=d["rep"],
                   seed=d["seed"], stream=d["stream"],
                   metrics=d.get("metrics", {}),
                   clients=d.get("clients"), series=d.get("series"),
                   error=d.get("error"))


def series_window(series: list, metric: str, lo: int = 0,
                  hi: Optional[int] = None, cid: int = -1) -> list:
    """Per-interval ``metric`` values over ``[lo, hi)`` for one client
    (``-1`` = overall) from a row's captured telemetry series — the same
    windowing ``MetricsPipeline.window`` provides on a live run."""
    return [r[metric] for r in (series or ())
            if r["cid"] == cid and r["t"] >= lo
            and (hi is None or r["t"] < hi)]


@dataclass
class ResultFrame:
    """The result store for one executed sweep."""
    name: str
    spec: dict = field(default_factory=dict)   # Sweep.describe() metadata
    rows: list = field(default_factory=list)   # SweepRow, (index, rep) order

    # ---------------------------------------------------------- selection
    @property
    def ok_rows(self) -> list:
        return [r for r in self.rows if r.ok]

    @property
    def errors(self) -> list:
        return [r for r in self.rows if not r.ok]

    def raise_errors(self) -> "ResultFrame":
        """Raise if any row failed, carrying the captured error text —
        for consumers (the figure scripts) that need every point and
        would otherwise crash on an empty ``metrics`` dict with the real
        failure message sitting unread in ``row.error``."""
        if self.errors:
            detail = "; ".join(f"point={r.params} rep={r.rep}: {r.error}"
                               for r in self.errors[:5])
            more = len(self.errors) - 5
            if more > 0:
                detail += f" (+{more} more)"
            raise RuntimeError(f"sweep {self.name!r}: "
                               f"{len(self.errors)} failed rows — {detail}")
        return self

    def point_rows(self, index: int) -> list:
        return [r for r in self.rows if r.index == index]

    def values(self, metric: str, **filters) -> list:
        """Metric values (row order) over rows matching all ``filters``
        (matched against point params)."""
        return [r.metrics[metric] for r in self.ok_rows
                if all(r.params.get(k) == v for k, v in filters.items())]

    # --------------------------------------------------------- aggregation
    def points(self) -> list[tuple]:
        """Distinct (index, params) in declaration order."""
        seen: dict[int, dict] = {}
        for r in self.rows:
            seen.setdefault(r.index, r.params)
        return sorted(seen.items())

    def aggregate(self, metric: str) -> list[dict]:
        """Per-point mean + 95% CI half-width across repetitions.

        Failed repetitions are excluded from the aggregate (their count
        shows up as ``n_failed``); a fully-failed point aggregates to
        NaN rather than vanishing."""
        by_index: dict[int, list] = {}          # one pass, not O(points x rows)
        for r in self.rows:
            by_index.setdefault(r.index, []).append(r)
        out = []
        for index, params in self.points():
            rows = by_index.get(index, [])
            vals = [r.metrics[metric] for r in rows if r.ok]
            mean, ci = confidence95(vals)
            out.append({"index": index, "params": params, "metric": metric,
                        "mean": mean, "ci95": ci, "n_reps": len(vals),
                        "n_failed": sum(1 for r in rows if not r.ok),
                        "vals": vals})
        return out

    def compare(self, other: "ResultFrame", metric: str,
                **filters) -> "WelchCompare":
        """Welch's t-test of ``metric`` between this frame and another,
        POOLING every row that matches the param ``filters`` on each
        side — pin the filters to one grid point for a per-point test
        (unfiltered, between-point variance enters the pooled samples).
        Retained H0 (|t| < 2, p > 0.05) means the two sides are
        statistically indistinguishable, the paper's equivalence
        criterion."""
        a = self.values(metric, **filters)
        b = other.values(metric, **filters)
        w = welch_ttest(a, b)
        return WelchCompare(metric=metric, t_stat=w.t_stat,
                            p_value=w.p_value, n_a=len(a), n_b=len(b),
                            retained=bool(abs(w.t_stat) < 2
                                          and w.p_value > 0.05)
                            if not math.isnan(w.t_stat) else False)

    # --------------------------------------------------------- round-trip
    def to_dict(self) -> dict:
        return {"name": self.name, "spec": self.spec,
                "rows": [r.to_dict() for r in self.rows]}

    def iter_json(self, indent: int = 1):
        """Yield the frame's JSON text in row-sized pieces.  The
        concatenation is byte-identical to
        ``json.dumps(self.to_dict(), indent=indent)`` (a test pins
        this), but only one row is materialized at a time — the
        soak-scale path."""
        pad1 = " " * indent
        yield "{\n"
        yield f'{pad1}"name": {json.dumps(self.name)},\n'
        yield f'{pad1}"spec": {_dumps_at(self.spec, indent, 1)},\n'
        if not self.rows:
            yield f'{pad1}"rows": []\n'
        else:
            yield f'{pad1}"rows": [\n'
            pad2 = " " * (2 * indent)
            last = len(self.rows) - 1
            for i, r in enumerate(self.rows):
                body = _dumps_at(r.to_dict(), indent, 2)
                yield f"{pad2}{body}" + (",\n" if i != last else "\n")
            yield f"{pad1}]\n"
        yield "}"

    def to_json(self, path: Optional[str] = None, indent: int = 1) -> str:
        """Serialize (and optionally write) the frame.  Python's JSON
        encoder emits ``repr``-exact floats (and NaN/Infinity literals),
        so ``from_json(to_json(frame))`` reproduces every value
        bit-for-bit.  With ``path`` the frame is STREAMED to the file
        row by row (no whole-frame string) and the path is returned;
        without, the text itself is returned."""
        if path is not None:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            with open(path, "w") as f:
                for piece in self.iter_json(indent):
                    f.write(piece)
            return path
        return "".join(self.iter_json(indent))

    @classmethod
    def from_json(cls, text_or_path: str) -> "ResultFrame":
        """Load a frame from JSON text or a file path.  File input is
        parsed incrementally (row by row, bounded buffer) — a
        soak-scale artifact never materializes as one string."""
        if "\n" not in text_or_path and os.path.exists(text_or_path):
            frame = cls(name="")
            with open(text_or_path) as f:
                for key, val in _iter_frame_stream(f):
                    if key == "row":
                        frame.rows.append(SweepRow.from_dict(val))
                    elif key == "name":
                        frame.name = val
                    elif key == "spec":
                        frame.spec = val
            return frame
        d = json.loads(text_or_path)
        return cls(name=d["name"], spec=d.get("spec", {}),
                   rows=[SweepRow.from_dict(r) for r in d.get("rows", [])])

    @classmethod
    def iter_json_rows(cls, path: str):
        """Yield ``SweepRow``s straight off a frame file, one at a time
        — stream consumers (drift detectors, row filters) never hold
        the whole frame."""
        with open(path) as f:
            for key, val in _iter_frame_stream(f):
                if key == "row":
                    yield SweepRow.from_dict(val)

    # --------------------------------------------------------------- CSV
    def to_csv(self, path: str, aggregated: Optional[str] = None) -> str:
        """Write the frame as CSV.  Default: one row per (point, rep)
        with params and metrics flattened.  ``aggregated=<metric>``
        writes the per-point mean/ci95 table for that metric instead."""
        if aggregated is not None:
            rows = [{**a["params"], "metric": aggregated, "mean": a["mean"],
                     "ci95": a["ci95"], "n_reps": a["n_reps"],
                     "n_failed": a["n_failed"]}
                    for a in self.aggregate(aggregated)]
        else:
            rows = []
            for r in self.rows:
                rows.append({**r.params, "rep": r.rep, "seed": r.seed,
                             **r.metrics,
                             "error": r.error if r.error else ""})
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        cols: list = []
        for r in rows:
            for c in r:
                if c not in cols:
                    cols.append(c)
        # csv.writer, not ','.join: error rows carry free-form exception
        # text that needs real quoting
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(cols)
            for r in rows:
                w.writerow([r.get(c, "") for c in cols])
        return path


# ---------------------------------------------------------------------------
# Streaming JSON plumbing
# ---------------------------------------------------------------------------
def _dumps_at(obj, indent: int, depth: int) -> str:
    """``json.dumps(obj, indent=indent)`` re-anchored at nesting
    ``depth`` — every newline gains the enclosing indentation, which is
    exactly how the stock encoder lays out a nested value."""
    s = json.dumps(obj, indent=indent)
    if "\n" in s:
        s = s.replace("\n", "\n" + " " * (indent * depth))
    return s


class _JsonStream:
    """Incremental JSON reader over a file object: a bounded growing
    buffer + ``JSONDecoder.raw_decode``, with the consumed prefix
    dropped after every refill so memory tracks the LARGEST single
    value, not the file."""

    _WS = " \t\n\r"

    def __init__(self, f, chunk: int = 1 << 16):
        self.f = f
        self.chunk = chunk
        self.buf = ""
        self.pos = 0
        self._dec = json.JSONDecoder()

    def _fill(self) -> bool:
        data = self.f.read(self.chunk)
        if not data:
            return False
        if self.pos:
            self.buf = self.buf[self.pos:]
            self.pos = 0
        self.buf += data
        return True

    def peek(self) -> str:
        """Next non-whitespace char ('' at EOF); does not consume."""
        while True:
            while self.pos < len(self.buf) and \
                    self.buf[self.pos] in self._WS:
                self.pos += 1
            if self.pos < len(self.buf):
                return self.buf[self.pos]
            if not self._fill():
                return ""

    def expect(self, ch: str) -> None:
        got = self.peek()
        if got != ch:
            raise ValueError(f"malformed frame JSON: expected {ch!r}, "
                             f"got {got!r}")
        self.pos += 1

    def value(self):
        """Decode one complete JSON value, refilling as needed."""
        self.peek()                       # position at the value start
        while True:
            try:
                obj, end = self._dec.raw_decode(self.buf, self.pos)
            except ValueError:
                if not self._fill():
                    raise
                continue
            if end == len(self.buf) and self._fill():
                # the value touched the buffer end: it might be a
                # truncated number — re-decode with more data
                continue
            self.pos = end
            return obj


def _iter_frame_stream(f):
    """Yield ``(key, value)`` per top-level frame entry, with the
    ``rows`` list exploded into one ``("row", dict)`` per element."""
    s = _JsonStream(f)
    s.expect("{")
    if s.peek() == "}":
        return
    while True:
        key = s.value()
        s.expect(":")
        if key == "rows" and s.peek() == "[":
            s.pos += 1
            if s.peek() == "]":
                s.pos += 1
            else:
                while True:
                    yield ("row", s.value())
                    if s.peek() == ",":
                        s.pos += 1
                        continue
                    s.expect("]")
                    break
        else:
            yield (key, s.value())
        if s.peek() == ",":
            s.pos += 1
            continue
        s.expect("}")
        return


@dataclass(frozen=True)
class WelchCompare:
    metric: str
    t_stat: float
    p_value: float
    n_a: int
    n_b: int
    retained: bool
