"""Sweep execution: serial and process-parallel behind one interface.

``run_sweep(sweep)`` expands the spec into (point, repetition) tasks,
executes each as an independent deterministic run, and assembles a
``ResultFrame`` whose rows are ordered by (point_index, rep) — NOT by
completion order — so the frame is bit-identical whether it ran
serially, on 2 workers, or on 8 workers, under any OS scheduling.

Every task is hermetic: it derives its own seeds from the spec (no
shared RNG state), builds its own ``Experiment``/runtime, and extracts
its metrics in-worker (simulators never cross process boundaries).  A
task that raises records an error row — the sweep completes and reports
the failure instead of dying with it.

Backends:

* ``"serial"`` — in-process loop (supports lambda factories/metrics);
* ``"process"`` — ``concurrent.futures.ProcessPoolExecutor``; the
  ``Sweep`` must pickle, i.e. factories and metric callables must be
  module-level functions (or ``functools.partial`` of them).

Tasks whose runtime is ``"vector"`` bypass both: they are batched into
ONE in-process array program (``run_vector_tasks``) — the grid is the
unit of execution there, and the resulting rows are bit-identical to
per-task runs under any executor/worker count by construction.
"""
from __future__ import annotations

import multiprocessing
import sys
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from typing import Callable, Optional

from repro.sweep.results import ResultFrame, SweepRow
from repro.sweep.spec import EXTRA_METRICS, PointCtx, SUMMARY_METRICS, Sweep


# ---------------------------------------------------------------------------
# One task = one (point, rep) run
# ---------------------------------------------------------------------------
def _build_runtime(sweep: Sweep, exp, ctx: PointCtx, vector_config=None):
    runtime = ctx.params.get("runtime", sweep.runtime)
    if runtime == "sim":
        from repro.core.runtime import SimulatorRuntime
        rt = SimulatorRuntime(exp, rep=ctx.stream)
        rt.run()
        return rt
    if runtime == "engine":
        from repro.core.runtime import EngineRuntime, VirtualClock
        from repro.scenarios.backends import build_stub_engines
        clock = VirtualClock()
        engines, factory = build_stub_engines(exp, clock, exp.seed)
        rt = EngineRuntime.from_experiment(exp, engines,
                                           engine_factory=factory,
                                           rep=ctx.stream, clock=clock,
                                           sleep=clock.sleep)
        rt.run()
        return rt
    if runtime == "vector":
        # single-cell fallback (the grid path in run_sweep batches all
        # vector tasks into one array program; per-cell RNG derivation
        # makes the two paths bit-identical)
        from repro.vector import VectorRuntime
        rt = VectorRuntime(exp, rep=ctx.stream, config=vector_config)
        rt.run()
        return rt
    raise ValueError(f"unknown runtime: {runtime!r}")


def _slo_frac(rt, slo) -> float:
    """Fraction of recorded latencies above the SLO (NaN without one)."""
    if slo is None:
        return float("nan")
    rec = rt.recorder
    if rec is None:                 # vector backend: sampled latencies
        return rt.telemetry.slo_frac()
    if rec.mode == "exact":
        from repro.core.stats import slo_violation_frac
        return slo_violation_frac(rec.all, slo, n_bad=rec.failed_total())
    # streaming mode: aggregate the per-interval violation fractions,
    # weighted by interval request counts — served AND disposed
    # (shed/timeout/failed count as violations; reservoir-approximate)
    num = den = 0.0
    for f in rt.telemetry.frames():
        w = f.n + f.n_shed + f.n_timeout + f.n_failed
        if w and f.slo_violation_frac == f.slo_violation_frac:
            num += f.slo_violation_frac * w
            den += w
    return num / den if den else float("nan")


def _extract_metrics(sweep: Sweep, rt, exp) -> dict:
    s = rt.telemetry.overall()
    out: dict = {}
    for m in sweep.metrics:
        if not isinstance(m, str):          # ("name", callable) pair
            name, fn = m
            out[name] = fn(rt)
        elif m in SUMMARY_METRICS:
            out[m] = getattr(s, m)
        elif m == "dropped":
            out[m] = rt.dropped
        elif m == "slo_frac":
            out[m] = _slo_frac(rt, exp.slo)
        elif m in ("shed", "timeouts", "retries"):
            # resilience counters; 0 on runtimes without the feature
            # (vector exposes shed only — fluid has no per-request
            # timeout/retry mechanics)
            out[m] = int(getattr(rt, m, 0))
        else:
            raise ValueError(f"unknown metric {m!r}; known: "
                             f"{SUMMARY_METRICS + EXTRA_METRICS} or a "
                             f"(name, callable) pair")
    return out


def _series_rows(rt, cid: Optional[int]) -> list:
    key = -1 if cid is None else cid
    return [{"cid": key, "t": t, "n": s.n, "mean": s.mean,
             "p50": s.p50, "p95": s.p95, "p99": s.p99}
            for t, s in rt.telemetry.series(cid).items()]


def run_task(sweep: Sweep, index: int, params: dict, rep: int,
             capture: bool = True) -> SweepRow:
    """Execute one (point, rep) task; exceptions become error rows
    (``capture=False`` lets them propagate for fail-fast callers)."""
    seed, stream = sweep.seed_for(index, rep)
    ctx = PointCtx(params=params, index=index, rep=rep, seed=seed,
                   stream=stream)
    try:
        obj = sweep.factory(ctx)
        exp = obj.compile() if hasattr(obj, "compile") else obj
        rt = _build_runtime(sweep, exp, ctx)
        metrics = _extract_metrics(sweep, rt, exp)
        clients = None
        if sweep.per_client:
            clients = {str(cid): vars(rt.telemetry.client(cid))
                       for cid in rt.telemetry.clients()}
        series = None
        if sweep.telemetry:
            series = _series_rows(rt, None)
            if sweep.per_client:
                for cid in rt.telemetry.clients():
                    series.extend(_series_rows(rt, cid))
        return SweepRow(index=index, params=params, rep=rep,
                        seed=getattr(exp, "seed", seed), stream=stream,
                        metrics=metrics, clients=clients, series=series)
    except Exception as e:  # repro: noqa[broad-except] — error-row contract
        if not capture:
            raise
        return SweepRow(index=index, params=params, rep=rep, seed=seed,
                        stream=stream, error=f"{type(e).__name__}: {e}")


# ---------------------------------------------------------------------------
# Vector grid path: every vector task of the sweep as ONE array program
# ---------------------------------------------------------------------------
class _VectorCellView:
    """Runtime-shaped view of one grid cell (what ``_extract_metrics``
    and the telemetry capture consume)."""

    recorder = None

    def __init__(self, telemetry, dropped: int, shed: int = 0):
        self.telemetry = telemetry
        self.dropped = dropped
        self.shed = shed


def run_vector_tasks(sweep: Sweep, vec_tasks: list,
                     fail_fast: bool = False, config=None,
                     cache=None) -> dict:
    """Execute ``[(k, index, params, rep), ...]`` on the vector backend
    as one batched grid (the whole point of the backend: the grid — not
    the cell — is the unit of execution).  Returns ``{k: SweepRow}``.
    Results are bit-identical to running each task alone through
    ``run_task`` because every cell derives its own RNG from
    (experiment seed, repetition stream)."""
    from repro.vector import (VectorConfig, VectorTelemetry,
                              compile_experiment, run_cells)
    cfg = config if config is not None else VectorConfig()
    rows: dict = {}
    progs, seeds, metas = [], [], []
    for k, i, params, rep in vec_tasks:
        seed, stream = sweep.seed_for(i, rep)
        ctx = PointCtx(params=params, index=i, rep=rep, seed=seed,
                       stream=stream)
        try:
            obj = sweep.factory(ctx)
            exp = obj.compile() if hasattr(obj, "compile") else obj
            progs.append(compile_experiment(exp, dt=cfg.dt))
        except Exception as e:  # repro: noqa[broad-except] — error-row contract
            if fail_fast:
                raise
            rows[k] = SweepRow(index=i, params=params, rep=rep, seed=seed,
                               stream=stream,
                               error=f"{type(e).__name__}: {e}")
            continue
        seeds.append((exp.seed, stream))
        metas.append((k, i, params, rep, exp, stream))
    try:
        results = run_cells(progs, seeds, cfg, cache=cache)
    except Exception as e:  # repro: noqa[broad-except] — a failing grid
        if fail_fast:       # the sim/engine tasks sharing the sweep
            raise
        for k, i, params, rep, exp, stream in metas:
            rows[k] = SweepRow(index=i, params=params, rep=rep,
                               seed=exp.seed, stream=stream,
                               error=f"vector grid: "
                                     f"{type(e).__name__}: {e}")
        return rows
    for (k, i, params, rep, exp, stream), res in zip(metas, results):
        try:
            shed = (int(round(float(res.shed_ivl.sum())))
                    if res.shed_ivl is not None else 0)
            view = _VectorCellView(VectorTelemetry(res), res.dropped,
                                   shed=shed)
            metrics = _extract_metrics(sweep, view, exp)
            clients = None
            if sweep.per_client:
                clients = {}            # per-client views: not tracked
            series = None
            if sweep.telemetry:
                series = _series_rows(view, None)
            rows[k] = SweepRow(index=i, params=params, rep=rep,
                               seed=exp.seed, stream=stream,
                               metrics=metrics, clients=clients,
                               series=series)
        except Exception as e:  # repro: noqa[broad-except] — error-row contract
            if fail_fast:
                raise
            rows[k] = SweepRow(index=i, params=params, rep=rep,
                               seed=exp.seed, stream=stream,
                               error=f"{type(e).__name__}: {e}")
    return rows


# ---------------------------------------------------------------------------
# Result cache (row level)
# ---------------------------------------------------------------------------
def _row_key(cache, sweep: Sweep, index: int, params: dict, rep: int,
             vector_config=None):
    """Content key for one (point, rep) row: the compiled experiment,
    the derived (seed, stream), the runtime, and everything the row
    extraction depends on.  ``None`` = not cacheable (lambda metric,
    factory failure, ...) — the task simply runs."""
    seed, stream = sweep.seed_for(index, rep)
    ctx = PointCtx(params=params, index=index, rep=rep, seed=seed,
                   stream=stream)
    try:
        obj = sweep.factory(ctx)
        exp = obj.compile() if hasattr(obj, "compile") else obj
    except Exception:  # repro: noqa[broad-except] — a failing factory
        # must fail identically on the real path (error row), so the
        # task is simply not cacheable
        return None
    runtime = params.get("runtime", sweep.runtime)
    sig = {"runtime": runtime, "metrics": list(sweep.metrics),
           "telemetry": sweep.telemetry, "per_client": sweep.per_client}
    if runtime == "vector":
        from repro.vector import VectorConfig
        try:
            sig["vector"] = cache.vector_sig(vector_config
                                             or VectorConfig())
        except Exception:  # repro: noqa[broad-except] — unresolvable
            # backend config: uncacheable, the real path raises its own
            return None
    return cache.key("row", exp, (int(seed), int(stream)), sig)


def _row_from_payload(index: int, params: dict, rep: int,
                      payload: dict) -> SweepRow:
    return SweepRow(index=index, params=params, rep=rep,
                    seed=payload["seed"], stream=payload["stream"],
                    metrics=payload["metrics"],
                    clients=payload.get("clients"),
                    series=payload.get("series"))


def _row_payload(row: SweepRow) -> dict:
    payload = {"seed": row.seed, "stream": row.stream,
               "metrics": row.metrics}
    if row.clients is not None:
        payload["clients"] = row.clients
    if row.series is not None:
        payload["series"] = row.series
    return payload


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------
def _log(msg: str) -> None:
    print(msg, file=sys.stderr, flush=True)


def mp_context():
    """Start-method for sweep workers.

    The platform default (``fork`` on Linux) is the fast path: workers
    inherit the parent's imports for free.  But forking after JAX/XLA
    has started its thread pools is a documented deadlock, so once
    ``jax`` is loaded in this process the workers come from a
    ``forkserver`` instead — forked from a clean helper that never
    inherited those threads (falling back to ``spawn`` where the
    forkserver is unavailable).  Sweep results are start-method
    independent either way; only startup cost differs."""
    if "jax" not in sys.modules:
        return multiprocessing.get_context()
    for method in ("forkserver", "spawn"):
        try:
            return multiprocessing.get_context(method)
        except ValueError:
            continue
    return multiprocessing.get_context()


def run_sweep(sweep: Sweep, executor: str = "serial",
              workers: Optional[int] = None,
              progress: Optional[Callable[[str], None]] = _log,
              fail_fast: bool = False,
              vector_config=None, cache=None) -> ResultFrame:
    """Execute a ``Sweep`` and return its ``ResultFrame``.

    ``executor="serial"`` runs in-process; ``"process"`` fans the tasks
    out over a ``ProcessPoolExecutor`` with ``workers`` processes.  Rows
    are assembled in (point, rep) declaration order either way, so the
    two backends produce identical frames.  ``progress`` (default:
    stderr) receives one line per completed task; pass ``None`` to
    silence it.  ``fail_fast=True`` re-raises a task's ORIGINAL
    exception at the first failure instead of recording an error row —
    for shims like ``run_repeated`` whose callers expect the historical
    propagation semantics.  ``vector_config`` (a ``VectorConfig``)
    tunes the vector grid path's impl / device / bucketing knobs; all
    of them are bit-preserving, so it cannot change rows.

    ``cache`` (a ``repro.cache.ResultCache``) is consulted per task
    BEFORE dispatch — under every executor — and completed ok rows are
    written back.  Hit rows land at their declaration slot exactly like
    computed ones, so caching can never reorder or change a frame; a
    task whose key cannot be computed simply runs.
    """
    if sweep.mode == "optimize":
        # gradient-planner entry point: the search is an optimizer loop
        # over the smoothed vector surrogate, not a task grid
        from repro.plan import run_plan_sweep
        return run_plan_sweep(sweep, progress=progress,
                              vector_config=vector_config, cache=cache)
    tasks = sweep.tasks()
    total = len(tasks)
    rows: list = [None] * total

    def note(done: int, row: SweepRow) -> None:
        if progress is None:
            return
        status = "ok" if row.ok else f"ERROR ({row.error})"
        progress(f"sweep[{sweep.name}] {done}/{total} "
                 f"point={row.params} rep={row.rep}: {status}")

    done = 0
    row_keys: list = [None] * total
    cached: set = set()
    if cache is not None:
        for k, (i, params, rep) in enumerate(tasks):
            row_keys[k] = _row_key(cache, sweep, i, params, rep,
                                   vector_config)
            if row_keys[k] is None:
                continue
            payload = cache.get_row(row_keys[k])
            if payload is not None:
                rows[k] = _row_from_payload(i, params, rep, payload)
                cached.add(k)
                done += 1
                note(done, rows[k])

    # vector tasks always run the in-process grid path, whatever the
    # executor: the batched array program IS the parallelism, and the
    # rows are bit-identical to per-task execution by construction —
    # worker counts and executor choice cannot change vector results
    vec_tasks = [(k, i, params, rep)
                 for k, (i, params, rep) in enumerate(tasks)
                 if rows[k] is None
                 and params.get("runtime", sweep.runtime) == "vector"]
    if vec_tasks:
        for k, row in run_vector_tasks(sweep, vec_tasks,
                                       fail_fast=fail_fast,
                                       config=vector_config,
                                       cache=cache).items():
            rows[k] = row
            done += 1
            note(done, row)
    tasks_left = [(k, i, params, rep)
                  for k, (i, params, rep) in enumerate(tasks)
                  if rows[k] is None]

    if executor == "serial":
        for k, i, params, rep in tasks_left:
            rows[k] = run_task(sweep, i, params, rep,
                               capture=not fail_fast)
            done += 1
            note(done, rows[k])
    elif executor == "process":
        with ProcessPoolExecutor(max_workers=workers,
                                 mp_context=mp_context()) as pool:
            futs = {pool.submit(run_task, sweep, i, params, rep,
                                not fail_fast): k
                    for k, i, params, rep in tasks_left}
            pending = set(futs)
            while pending:
                finished, pending = wait(pending,
                                         return_when=FIRST_COMPLETED)
                for fut in finished:
                    k = futs[fut]
                    i, params, rep = tasks[k]
                    try:
                        rows[k] = fut.result()
                    except Exception as e:  # repro: noqa[broad-except]
                        # worker died, or a fail-fast task re-raised
                        # its original exception
                        if fail_fast:
                            for p in pending:
                                p.cancel()
                            raise
                        # record the death, don't kill the sweep
                        seed, stream = sweep.seed_for(i, rep)
                        rows[k] = SweepRow(index=i, params=params, rep=rep,
                                           seed=seed, stream=stream,
                                           error=f"worker: "
                                                 f"{type(e).__name__}: {e}")
                    done += 1
                    note(done, rows[k])
    else:
        raise ValueError(f"unknown executor {executor!r} "
                         f"(serial | process)")
    if cache is not None:
        # write back every computed ok row (error rows are never
        # cached: a fixed bug must re-run, not replay its failure)
        for k, row in enumerate(rows):
            if k not in cached and row_keys[k] is not None and row.ok:
                cache.put_row(row_keys[k], _row_payload(row))
    return ResultFrame(name=sweep.name, spec={**sweep.describe(),
                                              "executor": executor},
                       rows=rows)
