"""Declarative experiment sweeps — the TailBench++ grid layer.

The paper's whole methodology is grids: every figure sweeps
app x QPS x server-count x policy over 13 seeded repetitions.  This
package makes that a first-class, declarative object instead of a
hand-rolled nested loop per benchmark script:

* ``repro.sweep.spec`` — the ``Sweep`` dataclass: axes over
  ``Experiment``/``Scenario`` parameters (grid, zip, and explicit
  list-of-points forms), repetition counts, metric selection, and
  per-(point, rep) deterministic seed derivation via a
  SeedSequence-style spawn (streams never collide, unlike the old
  ``seed + 1000*(rep+1)`` arithmetic);
* ``repro.sweep.executor`` — serial and ``ProcessPoolExecutor``
  backends behind one ``run_sweep()`` interface, bit-identical results
  regardless of worker count or scheduling order, with per-point
  failure capture (a crashing point records an error row instead of
  killing the sweep);
* ``repro.sweep.results`` — the ``ResultFrame`` artifact: typed rows
  (point params + metrics + optional telemetry series), exact
  ``to_json``/``from_json`` round-trip, CSV emission, and Welch-t-test
  compare helpers.

Run named or file-declared sweeps from the command line::

    PYTHONPATH=src python -m repro.sweep --list
    PYTHONPATH=src python -m repro.sweep steady --axis qps=300,600,900 \
        --axis n_servers=1,2 --reps 3 --executor process --workers 4
    PYTHONPATH=src python -m repro.sweep --file my_sweep.json
    PYTHONPATH=src python -m repro.sweep --smoke
"""
from __future__ import annotations

from repro.sweep.executor import run_sweep
from repro.sweep.results import ResultFrame, SweepRow, series_window
from repro.sweep.spec import (Axis, PointCtx, SEEDERS, Sweep,
                              experiment_factory, scenario_factory,
                              spawn_seed)

__all__ = [
    "Axis", "PointCtx", "ResultFrame", "SEEDERS", "Sweep", "SweepRow",
    "experiment_factory", "run_sweep", "scenario_factory", "series_window",
    "spawn_seed",
]
