"""Run declarative sweeps from the command line, on either executor.

    PYTHONPATH=src python -m repro.sweep --list
    PYTHONPATH=src python -m repro.sweep steady --axis qps=300,600,900 \
        --axis n_servers=1,2 --reps 3
    PYTHONPATH=src python -m repro.sweep steady --axis qps=300,600 \
        --executor process --workers 4 --telemetry
    PYTHONPATH=src python -m repro.sweep batched-serving \
        --axis max_batch=2,4,8 --axis runtime=sim,engine --reps 1
    PYTHONPATH=src python -m repro.sweep steady --axis qps=300,600,900 \
        --runtime vector --reps 13          # whole grid as one array program
    PYTHONPATH=src python -m repro.sweep --file my_sweep.json
    PYTHONPATH=src python -m repro.sweep --smoke --executor process

A named sweep is a canonical scenario (``repro.scenarios``) swept over
its builder keywords: every ``--axis name=v1,v2,...`` becomes one grid
axis (first axis outermost), ``--set name=value`` pins a constant, and
``runtime`` is itself sweepable (``sim`` vs stub-``engine`` backends).

``--file`` runs a JSON (or YAML, when PyYAML is importable) sweep
declaration::

    {"name": "knee-hunt", "scenario": "steady", "reps": 5,
     "axes": {"qps": [300, 600, 900], "n_servers": [1, 2]},
     "fixed": {"duration": 10.0}, "seed": 0, "seeder": "spawn",
     "metrics": ["n", "mean", "p50", "p95", "p99", "dropped"],
     "telemetry": false, "runtime": "sim"}

Artifacts: ``<out>/<name>.json`` (the exact-round-trip ``ResultFrame``)
and ``<out>/<name>.csv`` (flat per-repetition rows).  Exit status is
non-zero if any point recorded an error row — CI gates on completion.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.sweep.executor import run_sweep
from repro.sweep.spec import DEFAULT_METRICS, SEEDERS, Axis, Sweep, \
    scenario_factory

OUT_DEFAULT = os.path.join("artifacts", "sweeps")

SMOKE = {
    "name": "smoke",
    "scenario": "steady",
    "axes": {"qps": [200.0, 400.0], "n_servers": [1, 2]},
    "fixed": {"duration": 3.0},
    "reps": 2,
    "metrics": list(DEFAULT_METRICS) + ["dropped"],
}


def _scalar(text: str):
    """Parse an axis value: int, then float, then bare string."""
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            continue
    return text


def _parse_axis(text: str) -> Axis:
    if "=" not in text:
        raise SystemExit(f"--axis wants name=v1,v2,... (got {text!r})")
    name, vals = text.split("=", 1)
    return Axis(name, tuple(_scalar(v) for v in vals.split(",")))


def _load_file(path: str) -> dict:
    with open(path) as f:
        text = f.read()
    if path.endswith((".yaml", ".yml")):
        try:
            import yaml
        except ImportError as e:
            raise SystemExit(f"{path}: YAML sweeps need PyYAML ({e})")
        return yaml.safe_load(text)
    import json
    return json.loads(text)


def _sweep_from_decl(decl: dict) -> Sweep:
    scenario = decl.get("scenario")
    if not scenario:
        raise SystemExit("sweep declaration needs a 'scenario' name")
    axes = tuple(Axis(k, tuple(v)) for k, v in decl.get("axes", {}).items())
    points = tuple(decl.get("points", ()))
    metrics = tuple(decl.get("metrics", DEFAULT_METRICS))
    optimize = decl.get("optimize")
    if optimize is not None:
        # planner declaration: {"scenario": ..., "optimize": {"slo": ...,
        # "params": {"capacity": [4, 1, 24]}, ...}, "fixed": {...}}
        return Sweep(name=decl.get("name", scenario), factory=None,
                     mode="optimize",
                     optimize={"scenario": scenario, **optimize},
                     fixed=dict(decl.get("fixed", {})),
                     reps=int(decl.get("reps", 13)),
                     base_seed=int(decl.get("seed", 0)))
    return Sweep(name=decl.get("name", scenario),
                 factory=scenario_factory(scenario),
                 axes=axes,
                 mode=decl.get("mode", "points" if points else "grid"),
                 points=points,
                 fixed=dict(decl.get("fixed", {})),
                 reps=int(decl.get("reps", 13)),
                 base_seed=int(decl.get("seed", 0)),
                 seeder=decl.get("seeder", "spawn"),
                 metrics=metrics,
                 telemetry=bool(decl.get("telemetry", False)),
                 per_client=bool(decl.get("per_client", False)),
                 runtime=decl.get("runtime", "sim"))


def _print_plan(frame) -> None:
    plan = frame.spec["plan"]
    print(f"plan={frame.name} objective={plan['spec']['objective']} "
          f"target={plan['spec']['target']}")
    print(f"continuous optimum: {plan['params']}")
    v = plan.get("verified")
    if v is not None:
        print(f"verified fleet: n={plan['n_star']} "
              f"{v['metric']}={v['mean']:.4g} +- {v['ci95']:.4g} "
              f"({'feasible' if plan['feasible'] else 'INFEASIBLE'}; "
              f"{plan['cell_evals']} exact cells)")


def _print_aggregate(frame) -> None:
    if "plan" in frame.spec:
        _print_plan(frame)
        return
    metrics = [m for m in frame.spec.get("metrics", ())
               if m not in ("n",)]
    headline = "p99" if "p99" in metrics else (metrics[0] if metrics else None)
    print(f"sweep={frame.name} points={len(frame.points())} "
          f"rows={len(frame.rows)} errors={len(frame.errors)}")
    if headline is None:
        return
    print(f"{'point':<48} {'reps':>4} {headline + '_mean':>12} {'ci95':>12}")
    for a in frame.aggregate(headline):
        label = ",".join(f"{k}={v}" for k, v in a["params"].items()) or "-"
        print(f"{label:<48} {a['n_reps']:>4} {a['mean']:>12.6g} "
              f"{a['ci95']:>12.6g}")
    for r in frame.errors:
        print(f"  ERROR point={r.params} rep={r.rep}: {r.error}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sweep",
                                 description=__doc__,
                                 formatter_class=argparse
                                 .RawDescriptionHelpFormatter)
    ap.add_argument("scenario", nargs="?",
                    help="canonical scenario to sweep (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="list sweepable scenarios and named seeders")
    ap.add_argument("--file", default=None,
                    help="JSON/YAML sweep declaration to run")
    ap.add_argument("--smoke", action="store_true",
                    help="run the built-in CI smoke grid")
    ap.add_argument("--axis", action="append", default=[],
                    metavar="NAME=V1,V2,...", help="add one grid axis")
    ap.add_argument("--set", action="append", default=[], dest="fixed",
                    metavar="NAME=VALUE", help="pin a constant override")
    ap.add_argument("--zip", action="store_true",
                    help="zip the axes instead of taking their product")
    ap.add_argument("--reps", type=int, default=13)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--seeder", default="spawn", choices=sorted(SEEDERS))
    ap.add_argument("--metrics", default=None,
                    metavar="M1,M2,...", help="metric names to extract")
    ap.add_argument("--telemetry", action="store_true",
                    help="capture per-interval series per repetition")
    ap.add_argument("--per-client", action="store_true",
                    help="capture per-client summaries per repetition")
    ap.add_argument("--runtime", default="sim",
                    choices=["sim", "engine", "vector"],
                    help="default runtime backend (axis 'runtime' overrides; "
                         "'vector' batches the whole grid into one array "
                         "program)")
    ap.add_argument("--executor", default="serial",
                    choices=["serial", "process"])
    ap.add_argument("--workers", type=int, default=None)
    # vector grid-path knobs (all bit-preserving — see repro.vector)
    ap.add_argument("--vector-impl", default="auto",
                    choices=["auto", "ref", "pallas"],
                    help="vector grid: kernel impl (auto = Pallas on TPU, "
                         "jnp reference elsewhere)")
    ap.add_argument("--vector-backend", default="auto",
                    choices=["auto", "jax", "numpy"],
                    help="vector grid: array backend")
    ap.add_argument("--vector-devices", type=int, default=0,
                    help="vector grid: shard cells over N local devices "
                         "(0 = all)")
    ap.add_argument("--out", default=OUT_DEFAULT,
                    help=f"artifact directory (default {OUT_DEFAULT})")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-task progress lines")
    from repro.cache import add_cache_args, cache_from_args
    add_cache_args(ap)
    args = ap.parse_args(argv)

    if args.list:
        from repro import scenarios
        print("sweepable canonical scenarios:")
        for n in scenarios.names():
            builder = scenarios.SCENARIOS[n]
            doc = (builder.__doc__ or "").strip().splitlines()[0]
            print(f"  {n:<18} {doc}")
        print(f"named seeders: {', '.join(sorted(SEEDERS))}")
        return 0

    if args.smoke:
        decl = dict(SMOKE)
        sweep = _sweep_from_decl(decl)
    elif args.file:
        sweep = _sweep_from_decl(_load_file(args.file))
    elif args.scenario:
        axes = tuple(_parse_axis(a) for a in args.axis)
        fixed = {}
        for kv in args.fixed:
            if "=" not in kv:
                raise SystemExit(f"--set wants name=value (got {kv!r})")
            k, v = kv.split("=", 1)
            fixed[k] = _scalar(v)
        metrics = tuple(args.metrics.split(",")) if args.metrics \
            else tuple(DEFAULT_METRICS) + ("dropped",)
        sweep = Sweep(name=args.scenario,
                      factory=scenario_factory(args.scenario),
                      axes=axes, mode="zip" if args.zip else "grid",
                      fixed=fixed, reps=args.reps, base_seed=args.seed,
                      seeder=args.seeder, metrics=metrics,
                      telemetry=args.telemetry, per_client=args.per_client,
                      runtime=args.runtime)
    else:
        ap.print_usage()
        return 2

    def _progress(msg: str) -> None:
        print(msg, file=sys.stderr, flush=True)

    from repro.vector import VectorConfig
    vcfg = VectorConfig(backend=args.vector_backend, impl=args.vector_impl,
                        devices=args.vector_devices)
    cache = cache_from_args(args)
    frame = run_sweep(sweep, executor=args.executor, workers=args.workers,
                      progress=None if args.quiet else _progress,
                      vector_config=vcfg, cache=cache)
    json_path = os.path.join(args.out, f"{frame.name}.json")
    csv_path = os.path.join(args.out, f"{frame.name}.csv")
    frame.to_json(json_path)
    frame.to_csv(csv_path)
    _print_aggregate(frame)
    if cache is not None:
        print(f"cache[{cache.cache_dir}] {cache.stats}")
    print(f"wrote {json_path}")
    print(f"wrote {csv_path}")
    return 1 if frame.errors else 0


if __name__ == "__main__":
    sys.exit(main())
