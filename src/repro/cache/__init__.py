"""Content-addressed result cache for sweeps, vector grids, planning.

PRs 4–7 proved per-cell RNG derivation bit-stable across executors,
backends, jit, sharding, and bucketing — which makes every (point,
rep) cell content-addressable: the same frozen inputs always produce
the same bits.  This package turns that invariant into a performance
layer, the benchmarking analogue of an inference stack's KV/prefix
cache.  See ``repro.cache.fingerprint`` for the key anatomy and
``repro.cache.store`` for the hit/miss contract.

CLI integration (``repro.sweep``, ``repro.scenarios``, ``repro.plan``)
goes through :func:`add_cache_args` / :func:`cache_from_args`;
maintenance via ``python -m repro.cache``.
"""
from repro.cache.fingerprint import (CACHE_FORMAT, Unfingerprintable,
                                     code_salt, fingerprint)
from repro.cache.store import (DEFAULT_CACHE_DIR, CacheStats, ResultCache,
                               gc, scan, verify)

__all__ = [
    "CACHE_FORMAT",
    "CacheStats",
    "DEFAULT_CACHE_DIR",
    "ResultCache",
    "Unfingerprintable",
    "add_cache_args",
    "cache_from_args",
    "code_salt",
    "fingerprint",
    "gc",
    "scan",
    "verify",
]


def add_cache_args(ap) -> None:
    """Attach the shared ``--cache/--no-cache/--cache-dir`` flags."""
    g = ap.add_argument_group("result cache")
    g.add_argument("--cache", action="store_true",
                   help="reuse content-addressed cached results "
                        f"(default dir: {DEFAULT_CACHE_DIR})")
    g.add_argument("--no-cache", action="store_true",
                   help="force recomputation even if --cache-dir is set")
    g.add_argument("--cache-dir", default=None, metavar="DIR",
                   help="cache directory (implies --cache)")


def cache_from_args(args):
    """-> a ``ResultCache`` per the CLI flags, or ``None`` (disabled)."""
    if getattr(args, "no_cache", False):
        return None
    cache_dir = getattr(args, "cache_dir", None)
    if not getattr(args, "cache", False) and cache_dir is None:
        return None
    return ResultCache(cache_dir=cache_dir or DEFAULT_CACHE_DIR)
