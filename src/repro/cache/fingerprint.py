"""Canonical content fingerprints — what makes a cell addressable.

A cache hit must be *provably* the same computation, so the key is a
SHA-256 over a canonical walk of everything that decides a cell's
bits: the compiled program/experiment (dataclasses walked field by
field, ndarrays hashed dtype + shape + raw bytes, floats by ``repr``
so ``0.1`` and ``0.30000000000000004`` key differently exactly when
they compute differently), the derived (seed, stream) pair, the
bit-affecting runtime config, and a code-version salt.

The salt (``code_salt``) digests every measurement-path source file
under ``src/repro`` (the analysis linter is excluded — static tooling
cannot move a result bit) plus a format-version constant, so ANY code
change that could move bits invalidates the whole cache rather than
silently serving stale rows.  ``REPRO_CACHE_SALT`` overrides it (tests
use this to simulate stale entries).

Objects that cannot be canonically walked (lambdas, closures, open
handles) raise ``Unfingerprintable`` — callers treat that as "not
cacheable", never as an error.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from functools import lru_cache, partial

import numpy as np

#: bump to invalidate every existing cache entry on a format change
CACHE_FORMAT = 1

#: packages whose source participates in the code-version salt — the
#: measurement path.  ``analysis`` (static lint) is deliberately out.
_SALT_EXCLUDE = ("analysis",)


class Unfingerprintable(TypeError):
    """The object has no canonical content form (lambda, closure,
    handle, ...) — the computation is valid but not cacheable."""


def _update_callable(h, fn) -> None:
    mod = getattr(fn, "__module__", None)
    qual = getattr(fn, "__qualname__", getattr(fn, "__name__", None))
    if not mod or not qual or "<lambda>" in qual or "<locals>" in qual \
            or mod == "__main__":
        raise Unfingerprintable(f"callable {fn!r} has no stable "
                                f"module-level identity")
    h.update(f"fn:{mod}:{qual};".encode())


def _update(h, obj) -> None:
    """Stream one object's canonical form into the hash."""
    if obj is None:
        h.update(b"N;")
    elif isinstance(obj, bool):
        h.update(b"T;" if obj else b"F;")
    elif isinstance(obj, (int, np.integer)):
        h.update(f"i{int(obj)};".encode())
    elif isinstance(obj, (float, np.floating)):
        h.update(f"f{float(obj)!r};".encode())
    elif isinstance(obj, str):
        h.update(f"s{len(obj)}:".encode())
        h.update(obj.encode())
    elif isinstance(obj, bytes):
        h.update(f"b{len(obj)}:".encode())
        h.update(obj)
    elif isinstance(obj, np.ndarray):
        h.update(f"nd:{obj.dtype.str}:{obj.shape};".encode())
        h.update(np.ascontiguousarray(obj).tobytes())
    elif isinstance(obj, (list, tuple)):
        h.update(f"l{len(obj)}[".encode())
        for v in obj:
            _update(h, v)
        h.update(b"];")
    elif isinstance(obj, dict):
        h.update(f"d{len(obj)}{{".encode())
        for k in sorted(obj, key=lambda k: (type(k).__name__, repr(k))):
            _update(h, k)
            h.update(b"=")
            _update(h, obj[k])
        h.update(b"};")
    elif isinstance(obj, partial):
        h.update(b"partial(")
        _update(h, obj.func)
        _update(h, tuple(obj.args))
        _update(h, dict(obj.keywords))
        h.update(b");")
    elif dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        cls = type(obj)
        h.update(f"dc:{cls.__module__}.{cls.__qualname__}(".encode())
        for f in sorted(dataclasses.fields(obj), key=lambda f: f.name):
            h.update(f.name.encode())
            h.update(b"=")
            _update(h, getattr(obj, f.name))
        h.update(b");")
    elif callable(obj):
        _update_callable(h, obj)
    elif hasattr(obj, "__dict__"):
        # plain object: class identity + its public attribute dict (the
        # declared configuration; leading-underscore derived state is
        # excluded so memo fields never split keys)
        cls = type(obj)
        if cls.__module__ == "__main__":
            raise Unfingerprintable(f"{cls.__qualname__} defined in "
                                    f"__main__ has no stable identity")
        h.update(f"o:{cls.__module__}.{cls.__qualname__}(".encode())
        attrs = {k: v for k, v in vars(obj).items()
                 if not k.startswith("_")}
        _update(h, attrs)
        h.update(b");")
    else:
        raise Unfingerprintable(f"no canonical form for "
                                f"{type(obj).__name__}: {obj!r}")


def fingerprint(obj) -> str:
    """SHA-256 hex digest of ``obj``'s canonical content form."""
    h = hashlib.sha256()
    _update(h, obj)
    return h.hexdigest()


@lru_cache(maxsize=1)
def code_salt() -> str:
    """Digest of the measurement-path source tree (+ format version).

    Computed once per process; ``REPRO_CACHE_SALT`` overrides it for
    tests that need to simulate a stale cache."""
    env = os.environ.get("REPRO_CACHE_SALT")
    if env:
        return env
    h = hashlib.sha256()
    h.update(f"format:{CACHE_FORMAT};".encode())
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for dirpath, dirnames, filenames in sorted(os.walk(root)):
        dirnames.sort()
        rel_dir = os.path.relpath(dirpath, root)
        top = rel_dir.split(os.sep, 1)[0]
        if top in _SALT_EXCLUDE or "__pycache__" in rel_dir:
            continue
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            rel = os.path.join(rel_dir, fn)
            h.update(f"file:{rel};".encode())
            with open(os.path.join(dirpath, fn), "rb") as f:
                h.update(f.read())
    return h.hexdigest()[:16]
