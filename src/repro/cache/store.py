"""The content-addressed result store: disk + in-process LRU.

Two entry kinds share one store:

* ``"row"`` — a sweep task's extracted ``SweepRow`` content (metrics,
  optional clients/series) as exact-float JSON;
* ``"cell"`` — a vector-runtime ``VectorResult`` as an ``.npz``
  (arrays keep their exact float64 bits) with a JSON meta block for
  the scalars.

Every entry records the key it was stored under and the code-version
salt it was computed with.  ``get`` re-checks both on load: a
corrupted file, a key mismatch, or a stale salt is a silent MISS (the
caller recomputes), never an exception and never a wrong row — the
cache can only ever change how fast an answer arrives, not what it is.

Layout: ``<dir>/<salt>/<key[:2]>/<key>.{json,npz}``.  Keying the top
level by salt makes ``python -m repro.cache gc`` trivial (any non-
current salt directory is stale wholesale) and keeps entries from
different code versions physically apart.  Writes go through a temp
file + ``os.replace`` so concurrent readers never see a torn entry.
"""
from __future__ import annotations

import copy
import itertools
import json
import os
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.cache.fingerprint import Unfingerprintable, code_salt, fingerprint

#: default on-disk location (CLI ``--cache`` without ``--cache-dir``)
DEFAULT_CACHE_DIR = os.path.join("artifacts", "cache")

_EXT = {"row": ".json", "cell": ".npz"}
_TMP_COUNTER = itertools.count()


@dataclass
class CacheStats:
    """Counters for one ``ResultCache`` instance's lifetime."""
    lookups: int = 0
    hits: int = 0
    misses: int = 0
    stores: int = 0
    errors: int = 0                 # corrupt / stale entries seen on get
    uncacheable: int = 0            # objects with no canonical fingerprint

    def as_dict(self) -> dict:
        return dict(vars(self))

    def __str__(self) -> str:
        return (f"hits={self.hits} misses={self.misses} "
                f"stores={self.stores} errors={self.errors} "
                f"uncacheable={self.uncacheable}")


@dataclass
class ResultCache:
    """Content-addressed result cache: on-disk store + in-process LRU.

    ``cache_dir=None`` keeps entries in memory only (useful for
    within-run reuse, e.g. the planner ladder re-probing a fleet).
    ``memory_entries`` bounds the in-process LRU; eviction only costs a
    disk read (or a recompute), never correctness.
    """
    cache_dir: Optional[str] = DEFAULT_CACHE_DIR
    memory_entries: int = 128
    stats: CacheStats = field(default_factory=CacheStats)

    def __post_init__(self):
        self.salt = code_salt()
        self._mem: OrderedDict = OrderedDict()

    # ------------------------------------------------------------- keys
    def key(self, kind: str, *parts) -> Optional[str]:
        """Content key for ``parts`` (``None`` = not cacheable)."""
        try:
            return fingerprint((kind, self.salt) + parts)
        except Unfingerprintable:
            self.stats.uncacheable += 1
            return None

    def vector_sig(self, config) -> dict:
        """The bit-affecting slice of a ``VectorConfig``: everything
        that selects which numbers come out, including knobs proven
        bit-preserving (impl/devices/bucket) — distinct configurations
        key distinctly by design."""
        backend = config.resolve_backend()
        sig = {"dt": config.dt, "samples": config.samples,
               "backend": backend, "soft": bool(config.soft),
               "bucket": bool(config.bucket)}
        if backend == "jax":
            sig["impl"] = config.resolve_impl()
            sig["devices"] = config.resolve_devices()
        if config.soft:
            sig["tau"] = config.tau
            sig["band_frac"] = config.band_frac
        return sig

    def cell_key(self, program, seed, config) -> Optional[str]:
        """Key of one vector cell: compiled program + (seed, stream) +
        bit-affecting config + code salt."""
        try:
            sig = self.vector_sig(config)
        except Unfingerprintable:
            self.stats.uncacheable += 1
            return None
        return self.key("cell", program, tuple(int(s) for s in seed), sig)

    # ---------------------------------------------------------- generic
    def _path(self, key: str, kind: str) -> Optional[str]:
        if self.cache_dir is None:
            return None
        return os.path.join(self.cache_dir, self.salt, key[:2],
                            key + _EXT[kind])

    def _mem_put(self, key: str, value) -> None:
        self._mem[key] = value
        self._mem.move_to_end(key)
        while len(self._mem) > self.memory_entries:
            self._mem.popitem(last=False)

    def _write_atomic(self, path: str, writer) -> None:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}.{next(_TMP_COUNTER)}"
        try:
            with open(tmp, "wb") as f:
                writer(f)
            os.replace(tmp, path)
        except OSError:
            # a full/readonly disk must never fail the sweep — the
            # cache degrades to a recompute
            if os.path.exists(tmp):
                os.remove(tmp)

    # ------------------------------------------------------------- rows
    def get_row(self, key: str) -> Optional[dict]:
        """-> the stored row payload (deep copy), or ``None``."""
        self.stats.lookups += 1
        hit = self._mem.get(key)
        if hit is not None:
            self._mem.move_to_end(key)
            self.stats.hits += 1
            return copy.deepcopy(hit)
        path = self._path(key, "row")
        if path is not None and os.path.exists(path):
            try:
                with open(path) as f:
                    entry = json.load(f)
                if entry["key"] != key or entry["salt"] != self.salt \
                        or entry["kind"] != "row":
                    raise ValueError("fingerprint mismatch")
                payload = entry["payload"]
            except Exception:  # repro: noqa[broad-except] — a corrupt or
                # stale entry is a silent miss by contract, never a crash
                self.stats.errors += 1
            else:
                self._mem_put(key, payload)
                self.stats.hits += 1
                return copy.deepcopy(payload)
        self.stats.misses += 1
        return None

    def put_row(self, key: str, payload: dict) -> None:
        self._mem_put(key, copy.deepcopy(payload))
        self.stats.stores += 1
        path = self._path(key, "row")
        if path is None:
            return
        entry = {"key": key, "salt": self.salt, "kind": "row",
                 "payload": payload}
        text = json.dumps(entry)
        self._write_atomic(path, lambda f: f.write(text.encode()))

    # ------------------------------------------------------------ cells
    def get_cell(self, key: str):
        """-> the stored ``VectorResult``, or ``None``.  Arrays of a
        memory hit are shared (consumers read, never mutate)."""
        self.stats.lookups += 1
        hit = self._mem.get(key)
        if hit is not None:
            self._mem.move_to_end(key)
            self.stats.hits += 1
            return hit
        path = self._path(key, "cell")
        if path is not None and os.path.exists(path):
            try:
                res = _load_cell(path, key, self.salt)
            except Exception:  # repro: noqa[broad-except] — a corrupt or
                # stale entry is a silent miss by contract, never a crash
                self.stats.errors += 1
            else:
                self._mem_put(key, res)
                self.stats.hits += 1
                return res
        self.stats.misses += 1
        return None

    def put_cell(self, key: str, result) -> None:
        self._mem_put(key, result)
        self.stats.stores += 1
        path = self._path(key, "cell")
        if path is None:
            return
        self._write_atomic(path, lambda f: _save_cell(f, key, self.salt,
                                                      result))


# ---------------------------------------------------------------------------
# VectorResult (de)serialization — exact bits
# ---------------------------------------------------------------------------
_CELL_ARRAYS = ("samples", "sample_ivl", "n_ivl", "util_ivl", "occ_ivl",
                "qdepth_ivl")


def _save_cell(f, key: str, salt: str, result) -> None:
    meta = {"key": key, "salt": salt, "kind": "cell",
            "n": result.n, "mean": result.mean, "p50": result.p50,
            "p95": result.p95, "p99": result.p99,
            "dropped": result.dropped, "interval": result.interval,
            "slo": result.slo, "server_ids": list(result.server_ids),
            "has_tokens": result.tokens_ivl is not None,
            "has_shed": result.shed_ivl is not None}
    arrays = {name: np.asarray(getattr(result, name))
              for name in _CELL_ARRAYS}
    if result.tokens_ivl is not None:
        arrays["tokens_ivl"] = np.asarray(result.tokens_ivl)
    if result.shed_ivl is not None:
        arrays["shed_ivl"] = np.asarray(result.shed_ivl)
    np.savez(f, meta=np.array(json.dumps(meta)), **arrays)


def _load_cell(path: str, key: str, salt: str):
    from repro.vector import VectorResult
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["meta"][()]))
        if meta["key"] != key or meta["salt"] != salt \
                or meta["kind"] != "cell":
            raise ValueError("fingerprint mismatch")
        arrays = {name: z[name] for name in _CELL_ARRAYS}
        tokens = z["tokens_ivl"] if meta["has_tokens"] else None
        # older cache entries predate shed accounting: absent = None
        shed = z["shed_ivl"] if meta.get("has_shed") else None
    return VectorResult(
        n=int(meta["n"]), mean=float(meta["mean"]),
        p50=float(meta["p50"]), p95=float(meta["p95"]),
        p99=float(meta["p99"]), dropped=int(meta["dropped"]),
        interval=float(meta["interval"]),
        slo=None if meta["slo"] is None else float(meta["slo"]),
        server_ids=list(meta["server_ids"]), tokens_ivl=tokens,
        shed_ivl=shed, **arrays)


# ---------------------------------------------------------------------------
# Maintenance (``python -m repro.cache``)
# ---------------------------------------------------------------------------
def scan(cache_dir: str) -> dict:
    """Inventory of a cache directory: entries/bytes per salt."""
    out: dict = {"dir": cache_dir, "current_salt": code_salt(),
                 "salts": {}}
    if not os.path.isdir(cache_dir):
        return out
    for salt in sorted(os.listdir(cache_dir)):
        sdir = os.path.join(cache_dir, salt)
        if not os.path.isdir(sdir):
            continue
        info = {"rows": 0, "cells": 0, "other": 0, "bytes": 0}
        for dirpath, _dirnames, filenames in os.walk(sdir):
            for fn in filenames:
                p = os.path.join(dirpath, fn)
                info["bytes"] += os.path.getsize(p)
                if fn.endswith(".json"):
                    info["rows"] += 1
                elif fn.endswith(".npz"):
                    info["cells"] += 1
                else:
                    info["other"] += 1
        info["stale"] = salt != out["current_salt"]
        out["salts"][salt] = info
    return out


def verify(cache_dir: str, delete: bool = False) -> dict:
    """Load every current-salt entry and re-check its recorded key and
    salt; -> ``{"checked": n, "corrupt": [paths]}`` (entries removed
    when ``delete``)."""
    salt = code_salt()
    sdir = os.path.join(cache_dir, salt)
    checked, corrupt = 0, []
    if not os.path.isdir(sdir):
        return {"checked": 0, "corrupt": []}
    for dirpath, _dirnames, filenames in os.walk(sdir):
        for fn in sorted(filenames):
            path = os.path.join(dirpath, fn)
            key, ext = os.path.splitext(fn)
            checked += 1
            try:
                if ext == ".npz":
                    _load_cell(path, key, salt)
                elif ext == ".json":
                    with open(path) as f:
                        entry = json.load(f)
                    if entry["key"] != key or entry["salt"] != salt:
                        raise ValueError("fingerprint mismatch")
                else:
                    raise ValueError(f"unknown entry type {ext!r}")
            except Exception:  # repro: noqa[broad-except] — verify's whole
                # job is classifying arbitrary on-disk damage
                corrupt.append(path)
                if delete:
                    os.remove(path)
    return {"checked": checked, "corrupt": corrupt}


def gc(cache_dir: str, all_salts: bool = False) -> dict:
    """Remove stale-salt trees (every tree when ``all_salts``) and
    corrupt current-salt entries; -> removal counts."""
    import shutil
    cur = code_salt()
    removed_salts, removed_entries = [], 0
    if os.path.isdir(cache_dir):
        for salt in sorted(os.listdir(cache_dir)):
            sdir = os.path.join(cache_dir, salt)
            if not os.path.isdir(sdir):
                continue
            if all_salts or salt != cur:
                shutil.rmtree(sdir)
                removed_salts.append(salt)
    if not all_salts:
        removed_entries = len(verify(cache_dir, delete=True)["corrupt"])
    return {"removed_salts": removed_salts,
            "removed_corrupt_entries": removed_entries}
