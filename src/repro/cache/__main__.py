"""Cache maintenance CLI.

    python -m repro.cache stats  [--cache-dir DIR]
    python -m repro.cache verify [--cache-dir DIR] [--delete]
    python -m repro.cache gc     [--cache-dir DIR] [--all]

``stats`` inventories entries per code-version salt, ``verify``
re-checks every current-salt entry's recorded fingerprint (exit 1 on
corruption), ``gc`` removes stale-salt trees and corrupt entries.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.cache import DEFAULT_CACHE_DIR, gc, scan, verify


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.cache",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("command", choices=("stats", "verify", "gc"))
    ap.add_argument("--cache-dir", default=DEFAULT_CACHE_DIR, metavar="DIR")
    ap.add_argument("--delete", action="store_true",
                    help="verify: remove corrupt entries")
    ap.add_argument("--all", action="store_true",
                    help="gc: remove every salt tree, including current")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output")
    args = ap.parse_args(argv)

    if args.command == "stats":
        rep = scan(args.cache_dir)
        if args.json:
            print(json.dumps(rep, indent=1))
        else:
            print(f"cache dir: {rep['dir']}")
            print(f"current salt: {rep['current_salt']}")
            if not rep["salts"]:
                print("(empty)")
            for salt, info in rep["salts"].items():
                mark = "  (stale)" if info["stale"] else "  (current)"
                print(f"  {salt}{mark}: {info['rows']} rows, "
                      f"{info['cells']} cells, {info['bytes']} bytes")
        return 0

    if args.command == "verify":
        rep = verify(args.cache_dir, delete=args.delete)
        if args.json:
            print(json.dumps(rep, indent=1))
        else:
            print(f"checked {rep['checked']} entries, "
                  f"{len(rep['corrupt'])} corrupt"
                  + (" (deleted)" if args.delete and rep["corrupt"] else ""))
            for path in rep["corrupt"]:
                print(f"  corrupt: {path}")
        return 1 if rep["corrupt"] and not args.delete else 0

    rep = gc(args.cache_dir, all_salts=args.all)
    if args.json:
        print(json.dumps(rep, indent=1))
    else:
        print(f"removed {len(rep['removed_salts'])} stale salt tree(s), "
              f"{rep['removed_corrupt_entries']} corrupt entries")
    return 0


if __name__ == "__main__":
    sys.exit(main())
