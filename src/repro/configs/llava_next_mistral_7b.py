"""llava-next-mistral-7b [vlm]: Mistral-7B backbone + anyres patch-embed stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000, head_dim=128,
    rope_theta=1_000_000.0,
    embed_frontend="patch",
    sub_quadratic=False,
    notes="anyres tiling lives in the stubbed frontend; backbone sees "
          "precomputed patch embeddings (B, S_img, 1024).",
))
