"""Architecture config system.

Every assigned architecture is a frozen dataclass instance registered under
its ``--arch`` id.  A config fully determines the model (layer pattern,
attention flavor, MoE, …), its sharding profile, and the shape cells it
participates in.  ``smoke()`` returns a reduced same-family config for CPU
tests; the full config is only ever lowered abstractly (dry-run).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence

# ---------------------------------------------------------------------------
# Layer kinds composing a repeating pattern group (scanned unit).
# ---------------------------------------------------------------------------
ATTN = "attn"            # full causal attention
ATTN_SWA = "attn_swa"    # sliding-window causal attention
MAMBA = "mamba"          # mamba2 SSD block
ENC_ATTN = "enc_attn"    # bidirectional (encoder) attention


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0          # deepseek-style always-on experts
    expert_d_ff: Optional[int] = None    # if != d_ff (fine-grained experts)
    router_jitter: float = 0.0
    capacity_factor: float = 1.25        # dropless ignored; used for dispatch buffers

    @property
    def d_ff_expert(self) -> int:
        return self.expert_d_ff if self.expert_d_ff is not None else 0


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256                     # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                          # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None       # default d_model // num_heads
    # Repeating layer pattern; length must divide num_layers. None => [ATTN].
    pattern: Optional[Sequence[str]] = None
    # Which pattern positions carry an MoE FFN instead of a dense MLP.
    moe_positions: Optional[Sequence[int]] = None
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    # attention details
    sliding_window: Optional[int] = None  # window for ATTN_SWA layers
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0            # partial rotary (stablelm)
    qk_norm: bool = False                 # gemma3
    attn_logit_softcap: Optional[float] = None
    # block details
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    parallel_block: bool = False          # command-r: attn & mlp in parallel
    use_bias: bool = False
    tie_embeddings: bool = False
    act: str = "silu"                     # silu (swiglu) | gelu (plain mlp)
    glu: bool = True                      # gated MLP (SwiGLU) vs plain 2-layer
    # encoder-decoder (whisper)
    enc_dec: bool = False
    num_encoder_layers: int = 0
    # modality frontend stub: fraction of prefill sequence that arrives as
    # precomputed embeddings instead of token ids (vlm/audio).
    embed_frontend: Optional[str] = None  # None | "patch" | "frame"
    # shapes: which of the 4 standard cells run; long_500k auto-derived
    sub_quadratic: bool = False           # eligible for long_500k
    notes: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def resolved_pattern(self) -> Sequence[str]:
        return tuple(self.pattern) if self.pattern else (ATTN,)

    @property
    def n_groups(self) -> int:
        p = len(self.resolved_pattern)
        assert self.num_layers % p == 0, (self.name, self.num_layers, p)
        return self.num_layers // p

    @property
    def attn_free(self) -> bool:
        return all(k == MAMBA for k in self.resolved_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND."""
        from repro.models.registry import count_params
        return count_params(self)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        p = self.resolved_pattern
        small_ff = 128 if not self.glu else 128
        kv = min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 2
        moe = None
        moe_pos = None
        if self.moe is not None:
            moe = replace(
                self.moe,
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                expert_d_ff=64 if self.moe.expert_d_ff is not None else None,
            )
            moe_pos = self.moe_positions
        mamba = replace(self.mamba, d_state=16, head_dim=16, chunk=32) if self.mamba else None
        return replace(
            self,
            name=self.name + "-smoke",
            num_layers=2 * len(p),
            num_encoder_layers=2 if self.enc_dec else 0,
            d_model=64,
            num_heads=4,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=small_ff,
            vocab_size=256,
            sliding_window=16 if self.sliding_window else None,
            moe=moe,
            moe_positions=moe_pos,
            mamba=mamba,
        )


# ---------------------------------------------------------------------------
# Shape cells (assigned): every LM arch pairs with these four.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeCell("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeCell("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeCell("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeCell("long_500k", "decode", 524_288, 1)
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ArchConfig) -> Sequence[ShapeCell]:
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return tuple(out)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    assert cfg.name not in _REGISTRY, cfg.name
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    import repro.configs.all  # noqa: F401  (populate registry)
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).smoke()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs.all  # noqa: F401
    return sorted(_REGISTRY)
