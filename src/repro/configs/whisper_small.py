"""whisper-small [audio]: enc-dec; conv frontend is a stub supplying frame
embeddings (B, T, 128).  12 encoder + 12 decoder layers, plain GELU MLP,
LayerNorm, biases.  Adaptation: RoPE replaces learned/sinusoidal positions.

[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ATTN, ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-small",
    family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    norm="layernorm", use_bias=True, act="gelu", glu=False,
    enc_dec=True, num_encoder_layers=12,
    embed_frontend="frame",
    sub_quadratic=False,
    notes="shape cells: seq_len = stubbed frame length for encoder shapes; "
          "decode cells use decoder self-KV at seq_len + cross-KV at enc len.",
))
