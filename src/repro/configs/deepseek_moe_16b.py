"""deepseek-moe-16b [moe]: fine-grained 64 routed experts top-6 + 2 shared.

[arXiv:2401.06066; hf]
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=128,
    moe_positions=(0,),
    moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                  expert_d_ff=1408),
    sub_quadratic=False,
    notes="experts EP-sharded over model (64/16 = 4 experts per chip)",
))
