"""Import all architecture configs (populates the registry)."""
from repro.configs import (  # noqa: F401
    command_r_35b,
    deepseek_moe_16b,
    gemma3_12b,
    jamba_1_5_large_398b,
    llava_next_mistral_7b,
    mamba2_1_3b,
    mixtral_8x22b,
    phi3_mini_3_8b,
    stablelm_3b,
    whisper_small,
)
