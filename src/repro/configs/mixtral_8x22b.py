"""mixtral-8x22b [moe]: 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf]
"""
from repro.configs.base import ATTN_SWA, ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    pattern=(ATTN_SWA,),
    sliding_window=4096,
    moe_positions=(0,),
    moe=MoEConfig(num_experts=8, top_k=2),
    rope_theta=1_000_000.0,
    sub_quadratic=True,   # SWA bounds the KV working set
    notes="experts are d_ff-TP sharded (8 experts don't divide model=16)",
))
