"""jamba-1.5-large-398b [hybrid]: Mamba+attn 1:7 interleave, MoE 16e top-2
on every other layer.  Pattern group of 8: attn at index 4, mamba elsewhere;
MoE FFN at odd indices (matches 398B total / ~94B active).

[arXiv:2403.19887; hf]  Adaptation: mamba layers use the Mamba-2 SSD form
(TPU-idiomatic chunked scan) rather than Mamba-1's sequential selective scan.
"""
from repro.configs.base import ATTN, MAMBA, ArchConfig, MambaConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536, head_dim=128,
    pattern=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA),
    moe_positions=(1, 3, 5, 7),
    moe=MoEConfig(num_experts=16, top_k=2),
    mamba=MambaConfig(d_state=128, head_dim=128, expand=2, chunk=256),
    sub_quadratic=True,
))
