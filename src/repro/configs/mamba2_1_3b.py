"""mamba2-1.3b [ssm]: pure SSD stack (attn-free, no FFN), d_state=128.

[arXiv:2405.21060; unverified]
"""
from repro.configs.base import MAMBA, ArchConfig, MambaConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    pattern=(MAMBA,),
    mamba=MambaConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    sub_quadratic=True,
))
