"""stablelm-3b [dense]: LayerNorm + partial rotary (25%) GQA(kv=H)=MHA.

[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=6912, vocab_size=50304, head_dim=80,
    norm="layernorm", rope_fraction=0.25,
    sub_quadratic=False,
))
