"""gemma3-12b [dense]: 5:1 local(SWA-1024):global pattern, 262k vocab,
head_dim 256, qk-norm, tied embeddings, GeGLU.

[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ATTN, ATTN_SWA, ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
    d_ff=15360, vocab_size=262144, head_dim=256,
    pattern=(ATTN_SWA,) * 5 + (ATTN,),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    qk_norm=True, tie_embeddings=True, act="gelu",
    sub_quadratic=True,   # 5/6 layers SWA; global-layer KV shards over model
    notes="long_500k runs: local layers ring-buffer to 1024, global layers "
          "hold full KV sharded over (data, model).",
))
