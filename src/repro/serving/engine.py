"""Batched inference engine with continuous batching.

Slot-based: ``max_batch`` sequences decode together; free slots are refilled
by prefilling queued prompts (prompt lengths are bucket-padded to bound jit
recompiles).  Step-driven so the TailBench++ harness can drive it in real
time: each ``step()`` performs one prefill (if a request is waiting and a
slot is free) or one batched decode step, and returns completion events.

This is the "ModelBackend" service the paper's clients hit; per-request
latency decomposes into queue wait (admission) + service (prefill+decode).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN_SWA, MAMBA, ArchConfig
from repro.core.profiles import apply_service_noise
from repro.models import param as P
from repro.models import registry as R


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray             # (L,) int32
    max_new_tokens: int
    submitted_at: float = 0.0
    prefilled_at: Optional[float] = None
    tokens_out: list = field(default_factory=list)


@dataclass
class Completion:
    req_id: int
    tokens: list
    ttft: float                    # time to first token (from submit)
    latency: float                 # total sojourn


class StubEngine:
    """Engine-protocol stand-in: no model, just timed service slots.

    Serves each request after a profile-sampled service time on one of
    ``workers`` parallel slots — the wall-clock analogue of ``SimServer``.
    Lets ``EngineRuntime``, the scenario CLI and the parity tests exercise
    the real-time path without weights or a JIT compile.  With a clock
    that exposes ``advance_to`` (``repro.core.runtime.VirtualClock``),
    ``step()`` jumps virtual time to the next completion the way a real
    engine's blocking decode step consumes wall time.
    """

    def __init__(self, profile, *, workers: int = 1, speed: float = 1.0,
                 service_noise: float = 0.0, seed: int = 0,
                 clock: Callable[[], float] = time.monotonic):
        self.profile = profile
        self.max_batch = workers
        self.speed = speed
        # multiplicative log-normal execution noise, same semantics as
        # SimServer.service_noise (a scenario configuring it gets noisy
        # service on both backends, not just the simulator)
        self.service_noise = service_noise
        self.clock = clock
        self._rng = np.random.default_rng((9176, 0x57AB, seed))
        self.queue: deque[tuple] = deque()      # (req_id, submitted_at)
        self.active: dict[int, tuple] = {}      # req_id -> (finish, start, submit)
        self.total_served = 0
        self.busy_time = 0.0                    # accrued service seconds

    def submit(self, prompt, max_new_tokens: int, req_id: int) -> None:
        self.queue.append((req_id, self.clock()))

    def pending(self) -> int:
        return len(self.queue)

    def n_active(self) -> int:
        return len(self.active)

    def idle(self) -> bool:
        return not self.queue and not self.active

    def step(self) -> list[Completion]:
        now = self.clock()
        done = []
        for rid, (finish, start, submit) in list(self.active.items()):
            if finish <= now:
                del self.active[rid]
                done.append(Completion(rid, [], ttft=start - submit,
                                       latency=finish - submit))
                self.total_served += 1
        while self.queue and len(self.active) < self.max_batch:
            rid, submit = self.queue.popleft()
            dur = apply_service_noise(
                self.profile.sample(self._rng) / self.speed,
                self.service_noise, self._rng)
            self.busy_time += dur
            self.active[rid] = (now + dur, now, submit)
        if not done and self.active and hasattr(self.clock, "advance_to"):
            # mimic a blocking decode step: consume (virtual) time up to
            # the earliest in-flight completion
            self.clock.advance_to(min(f for f, _, _ in self.active.values()))
        return done


class BatchedStubEngine:
    """Engine-protocol stand-in with *real* continuous-batching dynamics.

    Where ``StubEngine`` times each request on an independent slot, this
    drives the shared ``BatchScheduler`` op sequencer against a
    ``BatchedService`` cost model — the same code the simulator's batched
    ``SimServer`` serve loop executes in virtual time.  Per-op costs are
    ``max(compute x batch, memory)`` for a decode step and
    prompt-proportional for a prefill, so throughput saturates with
    occupancy exactly like ``InferenceEngine`` — and exactly like the
    simulator predicts, by construction.

    With a clock exposing ``advance_to`` (``VirtualClock``), ``step()``
    consumes virtual time up to the in-flight op's end the way a real
    engine's blocking decode step consumes wall time.
    """

    serializes_ops = True        # one op at a time: util normalizes per
                                 # engine, not per batch slot

    def __init__(self, service, *, max_batch: int = 8, speed: float = 1.0,
                 service_noise: float = 0.0, seed: int = 0,
                 clock: Callable[[], float] = time.monotonic):
        from repro.core.profiles import BatchScheduler
        self.service = service
        self.max_batch = max_batch
        self.speed = speed
        # per-op multiplicative log-normal noise, mirroring the batched
        # SimServer._kick — without it a noisy scenario would silently
        # run noise-free on the engine backend only
        self.service_noise = service_noise
        self.clock = clock
        self._rng = np.random.default_rng((9176, 0xBA7C, seed))
        self.core = BatchScheduler(service, max_batch)
        self._submit_at: dict[int, float] = {}
        self._prefilled: dict[int, float] = {}
        self._op_end: Optional[float] = None
        # the engine's own timeline: ops chain back-to-back on it even
        # when step() polls late (e.g. a shared VirtualClock advanced by
        # a sibling replica) — otherwise every poll gap would be billed
        # as idle service time and the replica would lose throughput
        self._t = clock()
        self.total_served = 0
        self.busy_time = 0.0                    # accrued op seconds

    @property
    def tokens_done(self) -> int:
        return self.core.tokens_done

    def submit(self, prompt, max_new_tokens: int, req_id: int) -> None:
        self._submit_at[req_id] = self.clock()
        self.core.submit(req_id, len(prompt), max_new_tokens)

    def pending(self) -> int:
        return self.core.pending()

    def n_active(self) -> int:
        return self.core.occupancy()

    def idle(self) -> bool:
        return self._op_end is None and self.core.idle()

    def step(self) -> list[Completion]:
        now = self.clock()
        done: list[Completion] = []
        # replay the engine's background execution up to ``now``: finish
        # due ops and chain the next one at the op boundary (never at the
        # poll instant), admitting only requests already submitted by
        # that boundary — op timing is therefore identical to the
        # simulator's calendar-queue serve loop
        while True:
            if self._op_end is not None:
                if self._op_end > now:
                    break
                end = self._op_end
                self._op_end = None
                self._t = end
                if self.core.op[0] == "prefill":
                    self._prefilled[self.core.op[1].key] = end
                for rid in self.core.finish_op():
                    sub = self._submit_at.pop(rid)
                    first = self._prefilled.pop(rid, end)
                    done.append(Completion(rid, [], ttft=first - sub,
                                           latency=end - sub))
                    self.total_served += 1
            t_op = self._t
            if not self.core.active and self.core.waiting:
                # idle engine: the next op starts when its head arrived
                t_op = max(t_op, self._submit_at[self.core.waiting[0].key])
            dur = self.core.start_op(
                ready=lambda rid: self._submit_at[rid] <= t_op)
            if dur is None:
                break
            dur = apply_service_noise(dur / self.speed, self.service_noise,
                                      self._rng)
            self.busy_time += dur
            self._t = t_op
            self._op_end = t_op + dur
        if not done and self._op_end is not None \
                and hasattr(self.clock, "advance_to"):
            # mimic a blocking engine op: consume (virtual) time up to
            # its end so the runtime's poll loop makes progress
            self.clock.advance_to(self._op_end)
        return done


def make_warmed_engine(cfg: ArchConfig, params, *, max_batch: int = 4,
                       prompt_len: int = 16,
                       max_new_tokens: int = 4) -> "InferenceEngine":
    """Build an InferenceEngine sized for the harness's request shape and
    warm its prefill/decode compile caches, so measured latency is
    serving, not compilation.  Shared by the serving launcher and the
    scenario CLI's real-engine backend."""
    eng = InferenceEngine(cfg, params, max_batch=max_batch,
                          max_len=prompt_len + max_new_tokens + 32)
    eng.submit(np.arange(prompt_len) % cfg.vocab_size, 2, -1)
    eng.run_until_idle()
    return eng


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


class InferenceEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_len: int = 512, impl: str = "auto",
                 moe_impl: str = "dispatch", clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.clock = clock
        self._impl, self._moe_impl = impl, moe_impl
        # batched decode cache (leading dims: groups, batch)
        enc_len = 64 if cfg.enc_dec else None
        self.cache = P.init_tree(
            R.cache_specs(cfg, max_batch, max_len, enc_len=enc_len),
            jax.random.PRNGKey(0))  # repro: noqa[seed-convention] —
        # fixed key: cache init allocates zeroed buffers, never samples
        self.positions = jnp.zeros((max_batch,), jnp.int32)
        self.tokens = jnp.zeros((max_batch,), jnp.int32)
        self.active: list[Optional[Request]] = [None] * max_batch
        self.queue: list[Request] = []
        self._decode = jax.jit(self._decode_impl, donate_argnums=(0,))
        self._prefills: dict[int, Callable] = {}
        # mamba state / SWA ring caches need exact-length prefill (no pads)
        self._exact_prefill = any(k in (MAMBA, ATTN_SWA)
                                  for k in cfg.resolved_pattern)
        self.completed: list[Completion] = []
        self.decode_steps = 0
        self.prefill_count = 0

    # ------------------------------------------------------------------ api
    def submit(self, prompt: np.ndarray, max_new_tokens: int, req_id: int):
        req = Request(req_id, np.asarray(prompt, np.int32), max_new_tokens,
                      submitted_at=self.clock())
        self.queue.append(req)

    def pending(self) -> int:
        return len(self.queue)

    def n_active(self) -> int:
        return sum(r is not None for r in self.active)

    def idle(self) -> bool:
        return not self.queue and self.n_active() == 0

    def step(self) -> list[Completion]:
        """One scheduler iteration. Prefill-priority continuous batching."""
        done: list[Completion] = []
        if self.queue and None in self.active:
            self._admit(self.queue.pop(0), self.active.index(None))
        elif self.n_active():
            done = self._decode_once()
        return done

    def run_until_idle(self, max_steps: int = 100_000) -> list[Completion]:
        out = []
        for _ in range(max_steps):
            if self.idle():
                break
            out.extend(self.step())
        return out

    # ------------------------------------------------------------- internals
    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefills:
            def fn(params, tokens, lengths):
                return R.prefill(self.cfg, params, {"tokens": tokens},
                                 self.max_len, impl=self._impl,
                                 moe_impl=self._moe_impl, lengths=lengths)
            self._prefills[bucket] = jax.jit(fn)
        return self._prefills[bucket]

    def _admit(self, req: Request, slot: int):
        L = len(req.prompt)
        bucket = L if self._exact_prefill else min(_bucket(L), self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :L] = req.prompt           # right-pad; pads masked via positions
        logits, cache1, pos1 = self._prefill_fn(bucket)(
            self.params, jnp.asarray(toks), jnp.asarray([L], np.int32))
        first = int(jnp.argmax(logits[0]))
        req.tokens_out.append(first)
        req.prefilled_at = self.clock()
        self.cache = jax.tree_util.tree_map(
            lambda c, p: c.at[:, slot].set(p[:, 0].astype(c.dtype)), self.cache, cache1)
        self.positions = self.positions.at[slot].set(int(pos1[0]))
        self.tokens = self.tokens.at[slot].set(first)
        self.active[slot] = req
        self.prefill_count += 1
        self._maybe_finish(slot)

    def _decode_impl(self, cache, params, tokens, positions):
        logits, new_cache = R.decode_step(self.cfg, params, cache, tokens,
                                          positions, impl=self._impl,
                                          moe_impl=self._moe_impl)
        return jnp.argmax(logits, -1).astype(jnp.int32), new_cache

    def _decode_once(self) -> list[Completion]:
        next_tokens, self.cache = self._decode(self.cache, self.params,
                                               self.tokens, self.positions)
        self.positions = self.positions + 1
        self.tokens = next_tokens
        self.decode_steps += 1
        toks = np.asarray(next_tokens)
        done = []
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.tokens_out.append(int(toks[slot]))
            c = self._maybe_finish(slot)
            if c:
                done.append(c)
        return done

    def _maybe_finish(self, slot: int) -> Optional[Completion]:
        req = self.active[slot]
        if req and len(req.tokens_out) >= req.max_new_tokens:
            now = self.clock()
            c = Completion(req.req_id, req.tokens_out,
                           ttft=req.prefilled_at - req.submitted_at,
                           latency=now - req.submitted_at)
            self.completed.append(c)
            self.active[slot] = None
            return c
        return None
