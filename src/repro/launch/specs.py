"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation — everything the dry-run lowers against is abstract.
Frontend stubs follow the assignment: [vlm]/[audio] cells feed precomputed
patch/frame embeddings for part of the sequence.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import registry as R
from repro.models.param import abstract_tree

I32 = jnp.int32

# share of a [vlm] prefill sequence carried by image patch embeddings
VLM_IMG_FRACTION = 0.25
# whisper decoder length cap for *training/prefill* cells (its decoder is
# short; the encoder carries the cell's seq_len)
WHISPER_DEC_LEN = 448
# encoder context for whisper decode cells
WHISPER_ENC_LEN = 4096


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """Abstract batch for train/prefill cells."""
    b, s = cell.global_batch, cell.seq_len
    if cfg.enc_dec:
        out = {"frames": sds((b, s, 128), jnp.bfloat16),
               "tokens": sds((b, WHISPER_DEC_LEN), I32)}
        if cell.kind == "train":
            out["targets"] = sds((b, WHISPER_DEC_LEN), I32)
        return out
    if cfg.embed_frontend == "patch":
        s_img = int(s * VLM_IMG_FRACTION)
        out = {"patch_embeds": sds((b, s_img, 1024), jnp.bfloat16),
               "tokens": sds((b, s - s_img), I32)}
        if cell.kind == "train":
            out["targets"] = sds((b, s), I32)   # image positions masked (-1)
        return out
    out = {"tokens": sds((b, s), I32)}
    if cell.kind == "train":
        out["targets"] = sds((b, s), I32)
    return out


def decode_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """Abstract (cache, tokens, positions) for decode cells."""
    b, s = cell.global_batch, cell.seq_len
    enc_len = WHISPER_ENC_LEN if cfg.enc_dec else None
    cache = abstract_tree(R.cache_specs(cfg, b, s, enc_len=enc_len))
    return {"cache": cache,
            "tokens": sds((b,), I32),
            "positions": sds((b,), I32)}
