"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
CPU devices stand in for 2 pods of 256 v5e chips.  For each cell we lower
the real step function against abstract inputs (zero allocation), compile,
and record memory_analysis / cost_analysis / collective bytes for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch stablelm-3b --shape train_4k
  python -m repro.launch.dryrun --all                 # every cell, both meshes
  python -m repro.launch.dryrun --all --single-pod-only
"""
# The placeholder-device flag MUST precede any jax import.
import os  # noqa: E402
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse                     # noqa: E402
import json                         # noqa: E402
import re                           # noqa: E402
import time                         # noqa: E402
import traceback                    # noqa: E402

import jax                          # noqa: E402
import jax.numpy as jnp             # noqa: E402

from repro.configs.base import ALL_SHAPES, ArchConfig, ShapeCell, get_config, list_configs, shapes_for  # noqa: E402
from repro.distributed.sharding import mesh_context, named_sharding, strategy_rules, tree_shardings  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import registry as R  # noqa: E402
from repro.models.param import Axes, abstract_tree, axes_tree  # noqa: E402
from repro.training.optimizer import OptConfig, abstract_opt_state  # noqa: E402
from repro.training.train_step import make_train_step  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


# ---------------------------------------------------------------------------
# Collective accounting from optimized HLO
# ---------------------------------------------------------------------------
_DEF_RE = re.compile(r"%?([\w.\-]+)\s*=\s*([a-z0-9]+)\[([\d,]*)\]")
_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Sum result bytes per collective opcode (per-partition program)."""
    out: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _DEF_RE.match(stripped)
        if not m:
            continue
        rest = stripped[m.end():]
        for op in _COLL:
            # match opcode usage like "= bf16[...] all-gather(" incl. -start
            if re.search(rf"\s{op}(-start)?\(", rest):
                out[op] = out.get(op, 0.0) + _shape_bytes(m.group(2), m.group(3))
                counts[op] = counts.get(op, 0) + 1
                break
        # tuple-shaped collectives: "= (bf16[..], bf16[..]) all-reduce-start("
        if "(" == stripped.split("=")[-1].strip()[:1]:
            for op in _COLL:
                if re.search(rf"\)\s{op}(-start)?\(", stripped):
                    for dt, dims in re.findall(r"([a-z0-9]+)\[([\d,]*)\]",
                                               stripped.split(op)[0]):
                        out[op] = out.get(op, 0.0) + _shape_bytes(dt, dims)
                    counts[op] = counts.get(op, 0) + 1
                    break
    return {"bytes_by_op": out, "counts": counts,
            "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------
def _batch_axes(batch: dict) -> dict:
    ax = {}
    for k, v in batch.items():
        if k in ("tokens", "targets"):
            ax[k] = Axes(("batch", "seq")) if len(v.shape) == 2 else Axes(("batch",))
        elif k in ("patch_embeds", "frames"):
            ax[k] = Axes(("batch", "seq", None))
        elif k == "positions":
            ax[k] = Axes(("batch",))
        else:
            raise KeyError(k)
    return ax


def build_cell(cfg: ArchConfig, cell: ShapeCell, mesh, strategy: str):
    """-> (fn, abstract_args, in_shardings, out_shardings, donate)."""
    prules, arules = strategy_rules(strategy)
    aparams = R.abstract_params(cfg)
    p_sh = tree_shardings(R.param_axes(cfg), aparams, mesh, prules)

    if cell.kind == "train":
        opt_cfg = OptConfig()
        aopt = abstract_opt_state(aparams, opt_cfg)
        o_sh = {"m": tree_shardings(R.param_axes(cfg), aopt["m"], mesh, prules),
                "v": tree_shardings(R.param_axes(cfg), aopt["v"], mesh, prules),
                "step": named_sharding((), (), mesh)}
        batch = S.batch_specs(cfg, cell)
        b_sh = tree_shardings(_batch_axes(batch), batch, mesh, arules)
        from repro.util import opt_flags
        mb = 8 if "microbatch8" in opt_flags() else 1
        step = make_train_step(cfg, opt_cfg, impl="ref", microbatches=mb)
        return (step, (aparams, aopt, batch), (p_sh, o_sh, b_sh),
                (p_sh, o_sh, None), (0, 1))

    if cell.kind == "prefill":
        batch = S.batch_specs(cfg, cell)
        b_sh = tree_shardings(_batch_axes(batch), batch, mesh, arules)

        def step(params, batch):
            return R.prefill(cfg, params, batch, max_len=cell.seq_len, impl="ref")

        return step, (aparams, batch), (p_sh, b_sh), None, ()

    # decode
    d = S.decode_specs(cfg, cell)
    enc_len = S.WHISPER_ENC_LEN if cfg.enc_dec else None
    cache_axes = axes_tree(R.cache_specs(cfg, cell.global_batch, cell.seq_len,
                                         enc_len=enc_len))
    c_sh = tree_shardings(cache_axes, d["cache"], mesh, arules)
    t_sh = named_sharding((cell.global_batch,), ("batch",), mesh, arules)

    def step(params, cache, tokens, positions):
        return R.decode_step(cfg, params, cache, tokens, positions, impl="ref")

    return (step, (aparams, d["cache"], d["tokens"], d["positions"]),
            (p_sh, c_sh, t_sh, t_sh), None, (1,))


DEFAULT_STRATEGY = {"train": "sp", "prefill": "tp", "decode": "tp"}


def _lower_compile(cfg, cell, mesh, strategy):
    prules, arules = strategy_rules(strategy)
    t0 = time.time()
    fn, args, in_sh, out_sh, donate = build_cell(cfg, cell, mesh, strategy)
    with mesh_context(mesh, arules):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, t_lower, t_compile


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, strategy: str = "",
             with_cost: bool = True, opts: str = "", tag: str = "") -> dict:
    if opts:
        os.environ["REPRO_OPTS"] = opts
    cfg = get_config(arch)
    cell = {c.name: c for c in ALL_SHAPES}[shape_name]
    strategy = strategy or DEFAULT_STRATEGY[cell.kind]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    # 1) production lowering: scanned layers -> compile proof + memory
    compiled, t_lower, t_compile = _lower_compile(cfg, cell, mesh, strategy)
    mem = compiled.memory_analysis()
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "chips": int(n_chips),
        "multi_pod": multi_pod, "strategy": strategy,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {k: int(getattr(mem, k)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")
                   if hasattr(mem, k)},
        "params": R.count_params(cfg),
        "params_active": R.count_params(cfg, active=True),
    }
    del compiled

    # 2) cost lowering: scans unrolled -> true HLO FLOPs + collectives.
    # Unrolling the full depth is too slow to compile, but every layer group
    # is identical, so cost is linear in depth: measure at G=2 and G=4
    # unrolled and extrapolate — exact for boundary + G * per_group.
    if with_cost:
        os.environ["REPRO_COST_MODE"] = "1"
        try:
            t0 = time.time()
            plen = len(cfg.resolved_pattern)
            G = cfg.n_groups
            probes = {}
            for g in (2, min(4, max(G, 2))):
                if g in probes:
                    continue
                import dataclasses
                enc = (cfg.num_encoder_layers * g // G) if cfg.enc_dec else 0
                cfg_g = dataclasses.replace(cfg, num_layers=plen * g,
                                            num_encoder_layers=max(enc, 1) if cfg.enc_dec else 0)
                costc, _, _ = _lower_compile(cfg_g, cell, mesh, strategy)
                cost = costc.cost_analysis()
                if isinstance(cost, (list, tuple)):
                    cost = cost[0]
                coll = parse_collectives(costc.as_text())
                probes[g] = {"flops": float(cost.get("flops", 0.0)),
                             "bytes": float(cost.get("bytes accessed", 0.0)),
                             "coll": coll}
                del costc
            gs = sorted(probes)
            if len(gs) == 1:
                lo = hi = probes[gs[0]]
                g_lo = g_hi = gs[0]
            else:
                (g_lo, g_hi) = gs
                lo, hi = probes[g_lo], probes[g_hi]

            def extrap(vlo, vhi):
                if g_hi == g_lo:
                    return vhi * G / g_hi
                per_g = (vhi - vlo) / (g_hi - g_lo)
                return vhi + per_g * (G - g_hi)

            coll_ops = {}
            for op in set(lo["coll"]["bytes_by_op"]) | set(hi["coll"]["bytes_by_op"]):
                coll_ops[op] = extrap(lo["coll"]["bytes_by_op"].get(op, 0.0),
                                      hi["coll"]["bytes_by_op"].get(op, 0.0))
            result.update({
                "cost_compile_s": round(time.time() - t0, 1),
                "flops": extrap(lo["flops"], hi["flops"]),
                "bytes_accessed": extrap(lo["bytes"], hi["bytes"]),
                "collectives": {"bytes_by_op": coll_ops,
                                "total_bytes": sum(coll_ops.values()),
                                "counts": hi["coll"]["counts"],
                                "probe_groups": gs, "total_groups": G},
            })
        finally:
            os.environ["REPRO_COST_MODE"] = "0"

    result["opts"] = opts
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        name = f"{arch}_{shape_name}_{'multipod' if multi_pod else 'pod'}"
        if tag:
            name += f"_{tag}"
        with open(os.path.join(ARTIFACT_DIR, name + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    if opts:
        os.environ.pop("REPRO_OPTS", None)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--both", action="store_true")
    ap.add_argument("--strategy", default="")
    ap.add_argument("--no-cost", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in list_configs():
            cfg = get_config(arch)
            for cell in shapes_for(cfg):
                cells.append((arch, cell.name))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if (args.both or (args.all and not args.single_pod_only and not args.multipod)) \
        else ([True] if args.multipod else [False])

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            try:
                r = run_cell(arch, shape, mp, strategy=args.strategy,
                             with_cost=not args.no_cost)
                print(f"OK   {tag}: compile={r['compile_s']}s "
                      f"flops={r.get('flops', -1):.3e} "
                      f"coll={r.get('collectives', {}).get('total_bytes', -1):.3e}B "
                      f"temp={r['memory'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
            except Exception as e:
                failures += 1
                print(f"FAIL {tag}: {type(e).__name__}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
