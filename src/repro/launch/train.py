"""Training launcher: real steps on local devices, fault-tolerant.

  PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 50

--resume restores params/opt/data state from the latest checkpoint (the
restart path a cluster scheduler takes after preemption).  On a real TPU
fleet the same script runs under ``jax.distributed.initialize()`` with the
production mesh; on CPU it uses whatever devices exist.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs.base import get_config
from repro.distributed.sharding import mesh_context, strategy_rules, tree_shardings
from repro.launch.mesh import make_local_mesh
from repro.models import registry as R
from repro.training.data import DataConfig, Prefetcher, SyntheticLM
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    name = args.arch + ("-smoke" if args.smoke else "")
    cfg = get_config(name)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                        total_steps=args.steps)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, batch=args.batch,
                      seq_len=args.seq, seed=args.seed)

    params = R.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = init_opt_state(params, opt_cfg)
    data = SyntheticLM(dcfg)
    start_step = 0

    ckpt = store.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir and store.latest_step(args.ckpt_dir) is not None:
        tree, start_step, extra = store.restore({"params": params, "opt": opt},
                                                args.ckpt_dir)
        params, opt = tree["params"], tree["opt"]
        data = SyntheticLM.from_state(dcfg, extra["data"])
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=args.microbatches),
                      donate_argnums=(0, 1))
    pf = Prefetcher(data)
    t0 = time.time()
    tokens_done = 0
    try:
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in pf.next_batch().items()}
            params, opt, metrics = step_fn(params, opt, batch)
            tokens_done += args.batch * args.seq
            if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                print(f"step {step+1:5d} loss={float(metrics['loss']):.4f} "
                      f"acc={float(metrics['acc']):.3f} "
                      f"gnorm={float(metrics['grad_norm']):.2f} "
                      f"tok/s={tokens_done/dt:.0f}")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save({"params": params, "opt": opt}, step + 1,
                          extra={"data": data.state()})
    finally:
        pf.close()
        if ckpt:
            ckpt.wait()
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
