"""Render §Dry-run and §Roofline tables into EXPERIMENTS.md from artifacts.

  PYTHONPATH=src python -m repro.launch.render
"""
from __future__ import annotations

import json
import os

from repro.launch.dryrun import ARTIFACT_DIR
from repro.launch.roofline import analyze

EXP = os.path.join(os.path.dirname(__file__), "..", "..", "..", "EXPERIMENTS.md")


def _load(tag: str):
    out = []
    for f in sorted(os.listdir(ARTIFACT_DIR)):
        if f.endswith(f"_{tag}.json"):
            with open(os.path.join(ARTIFACT_DIR, f)) as fh:
                out.append(json.load(fh))
    return out


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | strategy | compile (s) | args GiB/chip | temp GiB/chip | fits 16G |",
            "|---|---|---|---|---|---|---|---|"]
    for r in _load("pod") + _load("multipod"):
        mem = r["memory"]
        args_g = mem.get("argument_size_in_bytes", 0) / 2**30
        temp_g = mem.get("temp_size_in_bytes", 0) / 2**30
        fits = "yes" if args_g + temp_g < 16 else "**no**"
        mesh = "x".join(str(x) for x in r["mesh"])
        rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | {r.get('strategy','')} "
                    f"| {r['compile_s']} | {args_g:.2f} | {temp_g:.2f} | {fits} |")
    return "\n".join(rows)


def roofline_table() -> str:
    rows = ["| arch | shape | compute (s) | memory (s) | collective (s) | dominant | useful | roofline |",
            "|---|---|---|---|---|---|---|---|"]
    for r in _load("pod"):
        if "flops" not in r:
            continue
        a = analyze(r)
        rows.append(f"| {a.arch} | {a.shape} | {a.compute_s:.3e} | {a.memory_s:.3e} "
                    f"| {a.collective_s:.3e} | {a.dominant} | {a.useful_ratio:.2f} "
                    f"| **{a.roofline_fraction:.3f}** |")
    return "\n".join(rows)


def main():
    with open(EXP) as f:
        text = f.read()
    text = _replace(text, "DRYRUN_TABLE", dryrun_table())
    text = _replace(text, "ROOFLINE_TABLE", roofline_table())
    with open(EXP, "w") as f:
        f.write(text)
    print("rendered", EXP)


def _replace(text: str, marker: str, table: str) -> str:
    start = f"<!-- {marker} -->"
    end = f"<!-- /{marker} -->"
    block = f"{start}\n{table}\n{end}"
    if end in text:
        import re
        return re.sub(rf"<!-- {marker} -->.*?<!-- /{marker} -->", block,
                      text, flags=re.S)
    return text.replace(start, block)


if __name__ == "__main__":
    main()
