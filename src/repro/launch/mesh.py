"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 v5e chips over
("data", "model"); multi-pod: 2x16x16 = 512 chips with a leading "pod"
axis (DCN-connected data parallelism across pods).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally (smoke tests): 1xN ("data","model")."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (~bidirectional per-direction)
