"""Roofline analysis over dry-run artifacts.

Three terms, in seconds, per (arch x shape) on the single-pod 16x16 mesh
(cost_analysis numbers are per-partition, i.e. per chip):

  compute    = HLO_FLOPs_per_chip / 197e12        (v5e bf16 peak)
  memory     = HLO_bytes_per_chip / 819e9         (HBM bandwidth)
  collective = wire_bytes_per_chip / 50e9         (ICI per-link)

wire bytes apply ring-collective factors to the parsed result-shape bytes:
all-gather/reduce-scatter move (n-1)/n x full tensor; all-reduce moves
2x(n-1)/n; all-to-all ~ full/n per link; collective-permute = full.

MODEL_FLOPS = 6*N*D (train), 2*N*D (prefill), 2*N_active*B (decode) — the
"useful" fraction HLO_FLOPs is judged against.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

_WIRE_FACTOR = {          # per-chip bytes-on-wire per full-tensor byte
    "all-gather": 1.0,        # (n-1)/n ≈ 1
    "reduce-scatter": 1.0,
    "all-reduce": 2.0,        # RS + AG
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclass
class Roofline:
    arch: str
    shape: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    arg_bytes: float = 0.0      # per-chip params+state: one mandatory read

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/masking/dispatch overhead."""
        return self.model_flops / self.hlo_flops if self.hlo_flops > 0 else 0.0

    @property
    def ideal_s(self) -> float:
        """Roofline floor: useful FLOPs at peak, or one full HBM read of
        params+state (whichever binds) — decode is legitimately memory-bound,
        so its roofline is the weight/KV-streaming time, not the MXU."""
        return max(self.model_flops / PEAK_FLOPS_BF16, self.arg_bytes / HBM_BW)

    @property
    def roofline_fraction(self) -> float:
        """ideal_s / achieved bound — the score the perf pass hillclimbs."""
        return self.ideal_s / self.bound_s if self.bound_s > 0 else 0.0


def _attn_flops_per_token(cfg, ctx: int, causal: bool) -> float:
    """Useful attention/SSD mixer FLOPs per token (QK^T + PV = 4*H*hd*ctx)."""
    total = 0.0
    pattern = cfg.resolved_pattern
    n_rep = cfg.num_layers // len(pattern)
    for kind in pattern:
        if kind == "mamba":
            m = cfg.mamba
            di = m.d_inner(cfg.d_model)
            # intra-chunk quadratic + state read/write
            total += (2 * m.chunk * di + 4 * di * m.d_state) * n_rep
            continue
        eff = ctx / 2 if causal else ctx
        if kind == "attn_swa" and cfg.sliding_window:
            eff = min(eff, cfg.sliding_window)
        total += 4 * cfg.num_heads * cfg.resolved_head_dim * eff * n_rep
    if cfg.enc_dec:  # encoder self-attention (bidirectional)
        total += 4 * cfg.num_heads * cfg.resolved_head_dim * ctx * cfg.num_encoder_layers
    return total


def model_flops_for(result: dict) -> float:
    """Per-chip useful FLOPs: 2N per token (6N train) + attention/SSD term."""
    from repro.configs.base import ALL_SHAPES, get_config
    cell = {c.name: c for c in ALL_SHAPES}[result["shape"]]
    cfg = get_config(result["arch"])
    chips = result["chips"]
    n_active = result.get("params_active") or result["params"]
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        attn = _attn_flops_per_token(cfg, cell.seq_len, causal=True) * tokens
        return (6.0 * n_active * tokens + 3.0 * attn) / chips
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        attn = _attn_flops_per_token(cfg, cell.seq_len, causal=True) * tokens
        return (2.0 * n_active * tokens + attn) / chips
    # decode: 1 new token per sequence against a ctx-long cache
    attn = _attn_flops_per_token(cfg, cell.seq_len, causal=False) * cell.global_batch
    return (2.0 * n_active * cell.global_batch + attn) / chips


def analyze(result: dict) -> Roofline:
    flops = result["flops"]
    hbytes = result["bytes_accessed"]
    wire = 0.0
    for op, b in result["collectives"]["bytes_by_op"].items():
        wire += b * _WIRE_FACTOR.get(op, 1.0)
    return Roofline(
        arch=result["arch"], shape=result["shape"],
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=hbytes / HBM_BW,
        collective_s=wire / ICI_BW,
        model_flops=model_flops_for(result),
        hlo_flops=flops,
        arg_bytes=float(result.get("memory", {}).get("argument_size_in_bytes", 0)),
    )


def load_results(multi_pod: bool = False) -> list[dict]:
    tag = "multipod" if multi_pod else "pod"
    out = []
    if not os.path.isdir(ARTIFACT_DIR):
        return out
    for f in sorted(os.listdir(ARTIFACT_DIR)):
        if f.endswith(f"_{tag}.json"):
            with open(os.path.join(ARTIFACT_DIR, f)) as fh:
                r = json.load(fh)
            if "flops" in r:
                out.append(r)
    return out


def table(multi_pod: bool = False) -> str:
    rows = ["arch,shape,compute_s,memory_s,collective_s,dominant,"
            "model_flops,hlo_flops,useful_ratio,roofline_fraction"]
    for r in load_results(multi_pod):
        a = analyze(r)
        rows.append(
            f"{a.arch},{a.shape},{a.compute_s:.4e},{a.memory_s:.4e},"
            f"{a.collective_s:.4e},{a.dominant},{a.model_flops:.3e},"
            f"{a.hlo_flops:.3e},{a.useful_ratio:.3f},{a.roofline_fraction:.3f}")
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# Serving-profile fallback (used by core.profiles.arch_profile when no
# dry-run artifact exists): per-decode-step seconds for a batch of 8,
# memory-bound estimate: 2 bytes/param active / HBM_BW per chip on 8 chips.
# ---------------------------------------------------------------------------
def decode_step_time_fallback(arch: str) -> float:
    from repro.configs.base import get_config
    from repro.models import registry as R
    cfg = get_config(arch)
    n_active = R.count_params(cfg, active=True)
    bytes_per_step = 2.0 * n_active
    return bytes_per_step / (8 * HBM_BW)     # 8-chip serving slice


def decode_step_time(arch: str, shape: str = "decode_32k") -> float:
    """Roofline-derived decode step time from artifacts, else fallback."""
    path = os.path.join(ARTIFACT_DIR, f"{arch}_{shape}_pod.json")
    if os.path.exists(path):
        with open(path) as f:
            r = json.load(f)
        if "flops" in r:
            return analyze(r).bound_s
    return decode_step_time_fallback(arch)


if __name__ == "__main__":
    print(table(multi_pod=False))
