"""Serving launcher: N engine replicas behind the TailBench++ harness.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b --smoke \
      --replicas 2 --qps 40 --duration 5 --policy jsq

Real wall-clock serving of a real JAX model driven by open-loop clients —
the end-to-end driver for this paper's kind (latency-critical serving).
Runs on the unified ``EngineRuntime`` backend, so ``--scenario`` can
replay any canonical dynamic scenario against real engines (client churn
and server join/drain/fail are honored; hedging/slowdown injections are
simulator-only and reported as skipped).
"""
from __future__ import annotations

import argparse

from repro.core.client import ClientConfig, ConstantQPS
from repro.core.runtime import EngineRuntime


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    # None = "not supplied": lets --scenario reject flags it would ignore
    ap.add_argument("--replicas", type=int, default=None)
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--qps", type=float, default=None)
    # None = "not supplied": a scenario keeps its canonical duration/policy
    # unless the user explicitly overrides them
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--policy", default=None,
                    choices=["round_robin", "jsq", "p2c", "least_connections"])
    ap.add_argument("--scenario", default=None,
                    help="drive a canonical scenario instead of constant-QPS "
                         "clients (see python -m repro.scenarios --list)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.scenarios.backends import (build_real_engines,
                                          run_experiment_on_real_engines)

    if args.scenario:
        ignored = [f for f, v in (("--replicas", args.replicas),
                                  ("--clients", args.clients),
                                  ("--qps", args.qps)) if v is not None]
        if ignored:
            ap.error(f"{', '.join(ignored)} cannot be combined with "
                     f"--scenario (the scenario defines fleet and clients)")
        from repro.scenarios import get as get_scenario
        overrides = {k: v for k, v in (("duration", args.duration),
                                       ("policy", args.policy)) if v is not None}
        sc = get_scenario(args.scenario, seed=args.seed, **overrides)
        rt = run_experiment_on_real_engines(
            sc.compile(), arch=args.arch, smoke=args.smoke,
            max_batch=args.max_batch, prompt_len=args.prompt_len,
            max_new_tokens=args.max_new, seed=args.seed)
    else:
        duration = 5.0 if args.duration is None else args.duration
        replicas = 2 if args.replicas is None else args.replicas
        n_clients = 2 if args.clients is None else args.clients
        qps = 20.0 if args.qps is None else args.qps
        engines, _, vocab = build_real_engines(
            args.arch, replicas, smoke=args.smoke,
            max_batch=args.max_batch, prompt_len=args.prompt_len,
            max_new_tokens=args.max_new, seed=args.seed)
        clients = [ClientConfig(i, ConstantQPS(qps / n_clients),
                                end_time=duration, seed=args.seed + i)
                   for i in range(n_clients)]
        rt = EngineRuntime(engines, clients, policy=args.policy or "jsq",
                           duration=duration,
                           prompt_len=args.prompt_len,
                           max_new_tokens=args.max_new,
                           vocab=vocab, seed=args.seed)
        rt.run()
    for inj in rt.unsupported:
        print(f"note: injection {inj.kind}@{inj.at:g}s is simulator-only "
              f"(skipped on the engine backend)")
    s = rt.telemetry.overall()
    print(f"served n={s.n}  mean={s.mean*1e3:.1f}ms  p50={s.p50*1e3:.1f}ms  "
          f"p95={s.p95*1e3:.1f}ms  p99={s.p99*1e3:.1f}ms")
    for cid in rt.telemetry.clients():
        cs = rt.telemetry.client(cid)
        print(f"  client {cid}: n={cs.n} p99={cs.p99*1e3:.1f}ms")
    return s


if __name__ == "__main__":
    main()
