"""Serving launcher: N engine replicas behind the TailBench++ harness.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b --smoke \
      --replicas 2 --qps 40 --duration 5 --policy jsq

Real wall-clock serving of a real JAX model driven by open-loop clients —
the end-to-end driver for this paper's kind (latency-critical serving).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.client import ClientConfig, ConstantQPS, PiecewiseQPS
from repro.core.harness import run_engine_experiment
from repro.models import registry as R
from repro.serving.engine import InferenceEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--qps", type=float, default=20.0)
    ap.add_argument("--duration", type=float, default=5.0)
    ap.add_argument("--policy", default="jsq",
                    choices=["round_robin", "jsq", "p2c", "least_connections"])
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    name = args.arch + ("-smoke" if args.smoke else "")
    cfg = get_config(name)
    params = R.init_params(cfg, jax.random.PRNGKey(args.seed))
    engines = [InferenceEngine(cfg, params, max_batch=args.max_batch,
                               max_len=args.prompt_len + args.max_new + 32)
               for _ in range(args.replicas)]
    # warm compile caches so measured latency is serving, not compilation
    for e in engines:
        e.submit(np.arange(args.prompt_len) % cfg.vocab_size, 2, -1)
        e.run_until_idle()
    clients = [ClientConfig(i, ConstantQPS(args.qps / args.clients),
                            end_time=args.duration, seed=args.seed + i)
               for i in range(args.clients)]
    rec = run_engine_experiment(engines, clients, policy=args.policy,
                                duration=args.duration,
                                prompt_len=args.prompt_len,
                                max_new_tokens=args.max_new,
                                vocab=cfg.vocab_size, seed=args.seed)
    s = rec.overall()
    print(f"served n={s.n}  mean={s.mean*1e3:.1f}ms  p50={s.p50*1e3:.1f}ms  "
          f"p95={s.p95*1e3:.1f}ms  p99={s.p99*1e3:.1f}ms")
    for cid in rec.clients():
        cs = rec.client(cid)
        print(f"  client {cid}: n={cs.n} p99={cs.p99*1e3:.1f}ms")
    return s


if __name__ == "__main__":
    main()
