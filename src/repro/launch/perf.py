"""§Perf hillclimb runner: lower one cell with optimization toggles and
print the roofline delta vs the recorded baseline.

  python -m repro.launch.perf --arch command-r-35b --shape train_4k \
      --strategy sp --opts sp_naive_attn,remat_dots --tag opt1
"""
import os  # noqa: E402
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json      # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--strategy", default="")
    ap.add_argument("--opts", default="")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--no-cost", action="store_true",
                    help="production lowering only (memory iterations)")
    args = ap.parse_args()

    from repro.launch.dryrun import ARTIFACT_DIR, run_cell
    from repro.launch.roofline import analyze

    r = run_cell(args.arch, args.shape, args.multipod, strategy=args.strategy,
                 opts=args.opts, tag=args.tag, with_cost=not args.no_cost)
    if "flops" not in r:
        print(f"[{args.tag}] compile={r['compile_s']}s "
              f"temp={r['memory']['temp_size_in_bytes']/2**30:.1f}GiB "
              f"args={r['memory']['argument_size_in_bytes']/2**30:.1f}GiB")
        return
    a = analyze(r)
    base_path = os.path.join(
        ARTIFACT_DIR, f"{args.arch}_{args.shape}_"
        f"{'multipod' if args.multipod else 'pod'}.json")
    print(f"[{args.tag}] compute={a.compute_s:.3e}s memory={a.memory_s:.3e}s "
          f"collective={a.collective_s:.3e}s dominant={a.dominant} "
          f"bound={a.bound_s:.3e}s roofline={a.roofline_fraction:.3f} "
          f"temp={r['memory']['temp_size_in_bytes']/2**30:.1f}GiB")
    if os.path.exists(base_path):
        b = analyze(json.load(open(base_path)))
        print(f"[baseline] bound={b.bound_s:.3e}s roofline="
              f"{b.roofline_fraction:.3f} -> "
              f"speedup {b.bound_s/a.bound_s:.2f}x")


if __name__ == "__main__":
    main()
