"""Small shared utilities."""
import os


def cost_mode() -> bool:
    """Dry-run cost lowering: unroll scans so HLO FLOPs reflect true trip
    counts (XLA cost analysis counts while-loop bodies once)."""
    return os.environ.get("REPRO_COST_MODE", "0") == "1"


def opt_flags() -> set:
    """Named perf optimizations for §Perf experiments (REPRO_OPTS=a,b,c)."""
    v = os.environ.get("REPRO_OPTS", "")
    return {x.strip() for x in v.split(",") if x.strip()}
