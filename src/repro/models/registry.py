"""Top-level model API: specs, init, and the three forward modes.

Batch dicts:
  train:   {"tokens": (B,S), "targets": (B,S)}                (+frontend)
  prefill: {"tokens": (B,S)}                                  (+frontend)
  decode:  {"tokens": (B,), "positions": (B,)} + cache
Frontend stubs (per assignment: modality frontends provide precomputed
embeddings): vlm adds {"patch_embeds": (B,S_img,1024)}; audio replaces
tokens at prefill with {"frames": (B,T,128)} (encoder input).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ENC_ATTN, ArchConfig
from repro.distributed.sharding import shard
from repro.models import param as P
from repro.models import transformer as T
from repro.models.attention import make_kv_cache_specs
from repro.models.layers import apply_norm, embed_specs, embed_tokens, norm_specs, unembed
from repro.models.param import Spec

FRONTEND_DIMS = {"patch": 1024, "frame": 128}


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------
def model_specs(cfg: ArchConfig) -> dict:
    specs: dict = {"embed": embed_specs(cfg)}
    if cfg.embed_frontend:
        din = FRONTEND_DIMS[cfg.embed_frontend]
        specs["frontend"] = {"proj": Spec((din, cfg.d_model), (None, "embed"))}
    if cfg.enc_dec:
        specs["enc_groups"] = T.stack_block_specs(cfg, (ENC_ATTN,), cfg.num_encoder_layers)
        specs["enc_norm"] = norm_specs(cfg)
        specs["groups"] = T.stack_block_specs(cfg, cfg.resolved_pattern, cfg.n_groups, cross=True)
    else:
        specs["groups"] = T.stack_block_specs(cfg, cfg.resolved_pattern, cfg.n_groups)
    specs["final_norm"] = norm_specs(cfg)
    if not cfg.tie_embeddings:
        # (vocab, embed) layout: vocab takes "model", embed takes "data" --
        # fully sharded storage and a clean contraction in the loss.
        specs["unembed"] = {"kernel": Spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"))}
    return specs


def cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                enc_len: Optional[int] = None) -> dict:
    per_pos = {}
    for i, kind in enumerate(cfg.resolved_pattern):
        c = T.cache_specs_for_kind(cfg, kind, batch, max_len)
        if cfg.enc_dec:
            kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            c = dict(c,
                     ek=Spec((batch, enc_len, kv, hd), ("batch", "kv_seq", "kv_heads", "head_dim"), jnp.bfloat16, "zeros"),
                     ev=Spec((batch, enc_len, kv, hd), ("batch", "kv_seq", "kv_heads", "head_dim"), jnp.bfloat16, "zeros"))
        per_pos[f"pos{i}"] = c
    return P.stack_specs(per_pos, cfg.n_groups)


def init_params(cfg: ArchConfig, key):
    return P.init_tree(model_specs(cfg), key)


def abstract_params(cfg: ArchConfig):
    return P.abstract_tree(model_specs(cfg))


def param_axes(cfg: ArchConfig):
    return P.axes_tree(model_specs(cfg))


def count_params(cfg: ArchConfig, active: bool = False) -> int:
    specs = model_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(specs, is_leaf=P.is_spec)[0]
    total = 0
    for path, s in flat:
        keys = [getattr(k, "key", str(k)) for k in path]
        n = int(np.prod(s.shape))
        if active and cfg.moe is not None and "moe" in keys and "shared" not in keys \
                and keys[-1] in ("wi_0", "wi_1", "wo"):
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        total += n
    return total


# ---------------------------------------------------------------------------
# Forward modes
# ---------------------------------------------------------------------------
def _embed_input(cfg: ArchConfig, params: dict, batch: dict):
    """-> (x (B,S,D), positions (S,) or (B,S))."""
    if "patch_embeds" in batch:
        pe = jnp.einsum("bsd,de->bse", batch["patch_embeds"].astype(jnp.bfloat16),
                        params["frontend"]["proj"])
        te = embed_tokens(cfg, params["embed"], batch["tokens"])
        x = jnp.concatenate([pe, te], axis=1)
    else:
        x = embed_tokens(cfg, params["embed"], batch["tokens"])
    return shard(x, "batch", "res_seq", "embed"), jnp.arange(x.shape[1])


def _encode(cfg: ArchConfig, params: dict, frames, *, impl, remat=True):
    x = jnp.einsum("btd,de->bte", frames.astype(jnp.bfloat16),
                   params["frontend"]["proj"])
    x = T.run_stack_seq(cfg, params["enc_groups"], x,
                        positions=jnp.arange(x.shape[1]), impl=impl,
                        remat=remat, pattern=(ENC_ATTN,))
    return apply_norm(cfg, params["enc_norm"], x)


def lm_hidden(cfg: ArchConfig, params: dict, batch: dict, *, impl: str = "auto",
              moe_impl: str = "dispatch", remat: bool = True) -> jax.Array:
    """Training/eval forward -> final hidden states (B,S,D)."""
    enc_out = None
    if cfg.enc_dec:
        enc_out = _encode(cfg, params, batch["frames"], impl=impl, remat=remat)
    x, positions = _embed_input(cfg, params, batch)
    x = T.run_stack_seq(cfg, params["groups"], x, positions=positions,
                        impl=impl, moe_impl=moe_impl, remat=remat,
                        enc_out=enc_out)
    return apply_norm(cfg, params["final_norm"], x)


def lm_logits(cfg: ArchConfig, params: dict, batch: dict, **kw) -> jax.Array:
    return unembed(cfg, params, lm_hidden(cfg, params, batch, **kw))


def prefill(cfg: ArchConfig, params: dict, batch: dict, max_len: int, *,
            impl: str = "auto", moe_impl: str = "dispatch", lengths=None):
    """-> (last-position logits (B,V), decode cache, next positions (B,)).

    ``lengths`` (B,) supports right-padded ragged prompts: logits are taken
    at ``lengths-1``; pad K/V slots carry positions >= length so decode
    masks them out.
    """
    enc_out = None
    if cfg.enc_dec:
        enc_out = _encode(cfg, params, batch["frames"], impl=impl, remat=False)
    x, positions = _embed_input(cfg, params, batch)
    x, cache = T.run_stack_prefill(cfg, params["groups"], x, positions=positions,
                                   max_len=max_len, impl=impl, moe_impl=moe_impl,
                                   enc_out=enc_out)
    x = apply_norm(cfg, params["final_norm"], x)
    b, s = x.shape[0], x.shape[1]
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
        x_last = x[:, -1, :]
    else:
        lengths = lengths.astype(jnp.int32)
        x_last = jnp.take_along_axis(
            x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0, :]
    logits = unembed(cfg, params, x_last)
    return logits, cache, lengths


def decode_step(cfg: ArchConfig, params: dict, cache: dict, tokens, positions, *,
                impl: str = "auto", moe_impl: str = "dispatch",
                enc_lengths=None):
    """tokens: (B,), positions: (B,) -> (logits (B,V), new cache)."""
    x = embed_tokens(cfg, params["embed"], tokens)
    if cfg.enc_dec and enc_lengths is None:
        # full encoder context by default (benchmarks)
        enc_len = cache["pos0"]["ek"].shape[2]
        enc_lengths = jnp.full((tokens.shape[0],), enc_len, jnp.int32)
    x, new_cache = T.run_stack_decode(cfg, params["groups"], x, cache,
                                      positions=positions, impl=impl,
                                      moe_impl=moe_impl, enc_lengths=enc_lengths)
    x = apply_norm(cfg, params["final_norm"], x)
    return unembed(cfg, params, x), new_cache
