"""Attention: GQA self/cross attention for train, prefill and decode.

Reference path is a query-chunked (flash-style) jnp implementation — memory
safe at 32k prefill and exact (it is also the oracle the Pallas kernels are
validated against; tiny shapes additionally check the naive materializing
form).  ``impl="pallas"`` dispatches to the TPU kernels in repro.kernels.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models.layers import rms_norm, rope
from repro.models.param import Spec

F32 = jnp.float32
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------
def attention_specs(cfg: ArchConfig, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    out = {
        "q": Spec((d, h, hd), ("embed", "heads", "head_dim")),
        "k": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "v": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "o": Spec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.use_bias:
        out["qb"] = Spec((h, hd), ("heads", "head_dim"), jnp.float32, "zeros")
        out["kb"] = Spec((kv, hd), ("kv_heads", "head_dim"), jnp.float32, "zeros")
        out["vb"] = Spec((kv, hd), ("kv_heads", "head_dim"), jnp.float32, "zeros")
        out["ob"] = Spec((d,), ("embed",), jnp.float32, "zeros")
    if cfg.qk_norm and not cross:
        out["q_norm"] = Spec((hd,), ("head_dim",), jnp.float32, "ones")
        out["k_norm"] = Spec((hd,), ("head_dim",), jnp.float32, "ones")
    return out


# ---------------------------------------------------------------------------
# Full block-level application (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------
def _proj_qkv(cfg, p, x, xa=None):
    src = x if xa is None else xa
    q = jnp.einsum("...d,dhk->...hk", x, p["q"])
    k = jnp.einsum("...d,dhk->...hk", src, p["k"])
    v = jnp.einsum("...d,dhk->...hk", src, p["v"])
    if "qb" in p:
        q, k, v = q + p["qb"].astype(q.dtype), k + p["kb"].astype(k.dtype), v + p["vb"].astype(v.dtype)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def _out_proj(p, o):
    y = jnp.einsum("...hk,hkd->...d", o, p["o"])
    if "ob" in p:
        y = y + p["ob"].astype(y.dtype)
    return y


def self_attention(cfg: ArchConfig, p: dict, x: jax.Array, *,
                   positions: jax.Array, causal: bool = True,
                   window: Optional[int] = None, impl: str = "auto") -> jax.Array:
    """Full-sequence self attention (train / prefill / encoder)."""
    q, k, v = _proj_qkv(cfg, p, x)
    q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    q = shard(q, "batch", "res_seq", "heads", "head_dim")
    k = shard(k, "batch", "res_seq", "kv_heads", "head_dim")
    from repro.kernels import ops
    o = ops.flash_attention(q, k, v, causal=causal, window=window, impl=impl)
    o = shard(o, "batch", "res_seq", "heads", "head_dim")
    return _out_proj(p, o)


def make_kv_cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                        window: Optional[int] = None) -> dict:
    """Cache specs for one attention position.  SWA layers get a ring buffer."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    size = min(max_len, window) if window else max_len
    return {
        "k": Spec((batch, size, kv, hd), ("batch", "kv_seq", "kv_heads", "head_dim"), jnp.bfloat16, "zeros"),
        "v": Spec((batch, size, kv, hd), ("batch", "kv_seq", "kv_heads", "head_dim"), jnp.bfloat16, "zeros"),
        # absolute position held by each slot (-1 = empty); ring for SWA
        "pos": Spec((batch, size), ("batch", "kv_seq"), jnp.int32, "constant", -1),
    }


def decode_self_attention(cfg: ArchConfig, p: dict, x: jax.Array, cache: dict, *,
                          positions: jax.Array, lengths: jax.Array,
                          window: Optional[int] = None, impl: str = "auto"):
    """One-token decode with cache update.  x: (B, D); positions: (B,)."""
    b = x.shape[0]
    q, k, v = _proj_qkv(cfg, p, x[:, None, :])          # (B,1,H,hd)
    q = rope(q, positions[:, None], cfg.rope_theta, cfg.rope_fraction)[:, 0]
    k = rope(k, positions[:, None], cfg.rope_theta, cfg.rope_fraction)[:, 0]
    v = v[:, 0]
    size = cache["k"].shape[1]
    slot = positions % size                              # ring for SWA, id for full
    bidx = jnp.arange(b)
    new_k = cache["k"].at[bidx, slot].set(k.astype(cache["k"].dtype))
    new_v = cache["v"].at[bidx, slot].set(v.astype(cache["v"].dtype))
    new_pos = cache["pos"].at[bidx, slot].set(positions)
    new_k = shard(new_k, "batch", "kv_seq", "kv_heads", "head_dim")
    new_v = shard(new_v, "batch", "kv_seq", "kv_heads", "head_dim")
    from repro.kernels import ops
    o = ops.decode_attention(q, new_k, new_v, lengths=lengths,
                             key_positions=new_pos, q_pos=positions,
                             window=window, impl=impl)
    new_cache = {"k": new_k, "v": new_v, "pos": new_pos}
    return _out_proj(p, o), new_cache


def cross_kv(p: dict, enc_out: jax.Array):
    """Project encoder output to cross-attention K/V. enc_out: (B,T,D)."""
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["k"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["v"])
    if "kb" in p:
        k, v = k + p["kb"].astype(k.dtype), v + p["vb"].astype(v.dtype)
    return k, v


def cross_attention_seq(cfg: ArchConfig, p: dict, x: jax.Array,
                        enc_out: jax.Array, impl: str = "auto"):
    """Decoder cross-attention (full dec sequence) over encoder output."""
    q = jnp.einsum("...d,dhk->...hk", x, p["q"])
    if "qb" in p:
        q = q + p["qb"].astype(q.dtype)
    k, v = cross_kv(p, enc_out)
    from repro.kernels import ops
    o = ops.flash_attention(q, k, v, causal=False, impl=impl)
    return _out_proj(p, o)


def cross_attention_decode(cfg: ArchConfig, p: dict, x: jax.Array, ek, ev,
                           enc_lengths: jax.Array, impl: str = "auto"):
    """Single-token cross-attention over cached encoder K/V. x: (B,D)."""
    q = jnp.einsum("bd,dhk->bhk", x, p["q"])
    if "qb" in p:
        q = q + p["qb"].astype(q.dtype)
    from repro.kernels import ops
    o = ops.decode_attention(q, ek, ev, lengths=enc_lengths, impl=impl)
    return _out_proj(p, o)
