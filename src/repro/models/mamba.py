"""Mamba-2 (SSD) mixer block.

Projections are split per component (z/x/B/C/dt) rather than one fused
in_proj so each shards cleanly (heads/d_inner on "model").  The SSD core is
``repro.kernels.ops.ssd_scan`` (chunked: intra-chunk quadratic on the MXU,
inter-chunk state scan) with a pure-jnp reference and a naive per-timestep
oracle.  Decode is the O(1) recurrent update.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models.param import Spec

F32 = jnp.float32
G = 1  # B/C groups (single group = multi-value-attention analogue)


def _dims(cfg: ArchConfig):
    m = cfg.mamba
    d_inner = m.d_inner(cfg.d_model)
    n_heads = m.n_heads(cfg.d_model)
    return m, d_inner, n_heads, m.head_dim, m.d_state


def mamba_specs(cfg: ArchConfig) -> dict:
    m, di, h, p_, n = _dims(cfg)
    d = cfg.d_model
    return {
        "wz": Spec((d, di), ("embed", "mamba_inner")),
        "wx": Spec((d, di), ("embed", "mamba_inner")),
        "wB": Spec((d, G, n), ("embed", None, "mamba_state")),
        "wC": Spec((d, G, n), ("embed", None, "mamba_state")),
        "wdt": Spec((d, h), ("embed", "mamba_heads")),
        "conv_x": Spec((m.d_conv, di), (None, "mamba_inner"), jnp.bfloat16, "normal", 0.2),
        "conv_B": Spec((m.d_conv, G * n), (None, None), jnp.bfloat16, "normal", 0.2),
        "conv_C": Spec((m.d_conv, G * n), (None, None), jnp.bfloat16, "normal", 0.2),
        "conv_bx": Spec((di,), ("mamba_inner",), jnp.float32, "zeros"),
        "conv_bB": Spec((G * n,), (None,), jnp.float32, "zeros"),
        "conv_bC": Spec((G * n,), (None,), jnp.float32, "zeros"),
        "A_log": Spec((h,), ("mamba_heads",), jnp.float32, "constant", 1.386),
        "dt_bias": Spec((h,), ("mamba_heads",), jnp.float32, "constant", -4.6),
        "D": Spec((h,), ("mamba_heads",), jnp.float32, "ones"),
        "gate_norm": Spec((di,), ("mamba_inner",), jnp.float32, "ones"),
        "wo": Spec((di, d), ("mamba_inner", "embed")),
    }


def mamba_cache_specs(cfg: ArchConfig, batch: int) -> dict:
    m, di, h, p_, n = _dims(cfg)
    return {
        "h": Spec((batch, h, p_, n), ("batch", "mamba_heads", None, None), jnp.float32, "zeros"),
        "conv_x": Spec((batch, m.d_conv - 1, di), ("batch", None, "mamba_inner"), jnp.bfloat16, "zeros"),
        "conv_B": Spec((batch, m.d_conv - 1, G * n), ("batch", None, None), jnp.bfloat16, "zeros"),
        "conv_C": Spec((batch, m.d_conv - 1, G * n), ("batch", None, None), jnp.bfloat16, "zeros"),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b.astype(out.dtype))


def _conv_step(cache, xt, w, b):
    """Single-token conv: cache (B,K-1,C), xt (B,C) -> (out, new_cache)."""
    window = jnp.concatenate([cache, xt[:, None, :]], axis=1)   # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window.astype(F32), w.astype(F32))
    return jax.nn.silu(out + b).astype(xt.dtype), window[:, 1:, :]


def _project(cfg, p, u):
    z = jnp.einsum("...d,di->...i", u, p["wz"])
    x = jnp.einsum("...d,di->...i", u, p["wx"])
    Bm = jnp.einsum("...d,dgn->...gn", u, p["wB"])
    Cm = jnp.einsum("...d,dgn->...gn", u, p["wC"])
    dt = jnp.einsum("...d,dh->...h", u.astype(F32), p["wdt"].astype(F32))
    return z, x, Bm, Cm, dt


def apply_mamba(cfg: ArchConfig, p: dict, u: jax.Array, impl: str = "auto",
                h0: Optional[jax.Array] = None):
    """Full-sequence SSD.  u: (B,S,D) -> (B,S,D)."""
    m, di, h, pd, n = _dims(cfg)
    b, s, _ = u.shape
    z, x, Bm, Cm, dt = _project(cfg, p, u)
    x = _causal_conv(x, p["conv_x"], p["conv_bx"])
    Bm = _causal_conv(Bm.reshape(b, s, G * n), p["conv_B"], p["conv_bB"]).reshape(b, s, G, n)
    Cm = _causal_conv(Cm.reshape(b, s, G * n), p["conv_C"], p["conv_bC"]).reshape(b, s, G, n)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(b, s, h, pd)
    xh = shard(xh, "batch", "res_seq", "mamba_heads", None)
    from repro.kernels import ops
    y, _ = ops.ssd_scan(xh, dt, A, Bm, Cm, chunk=m.chunk, impl=impl)
    y = y + xh.astype(F32) * p["D"][:, None]
    y = y.reshape(b, s, di).astype(u.dtype)
    y = y * jax.nn.silu(z)
    from repro.models.layers import rms_norm
    y = rms_norm(y, p["gate_norm"])
    return jnp.einsum("...i,id->...d", y, p["wo"])


def decode_mamba(cfg: ArchConfig, p: dict, u: jax.Array, cache: dict):
    """One-token recurrent step.  u: (B,D)."""
    m, di, h, pd, n = _dims(cfg)
    b = u.shape[0]
    z, x, Bm, Cm, dt = _project(cfg, p, u)
    x, cx = _conv_step(cache["conv_x"], x, p["conv_x"], p["conv_bx"])
    Bf, cB = _conv_step(cache["conv_B"], Bm.reshape(b, G * n), p["conv_B"], p["conv_bB"])
    Cf, cC = _conv_step(cache["conv_C"], Cm.reshape(b, G * n), p["conv_C"], p["conv_bC"])
    Bf, Cf = Bf.reshape(b, G, n), Cf.reshape(b, G, n)
    dt = jax.nn.softplus(dt + p["dt_bias"])                    # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(A * dt)                                    # (B,H)
    xh = x.reshape(b, h, pd).astype(F32)
    # h_new = decay*h + dt * B ⊗ x    (G=1 group broadcast over heads)
    hb = cache["h"] * decay[..., None, None]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bf[:, 0, :].astype(F32))
    hn = hb + upd
    y = jnp.einsum("bhpn,bn->bhp", hn, Cf[:, 0, :].astype(F32))
    y = y + xh * p["D"][:, None]
    y = y.reshape(b, di).astype(u.dtype) * jax.nn.silu(z)
    from repro.models.layers import rms_norm
    y = rms_norm(y, p["gate_norm"])
    out = jnp.einsum("bi,id->bd", y, p["wo"])
    return out, {"h": hn, "conv_x": cx, "conv_B": cB, "conv_C": cC}
