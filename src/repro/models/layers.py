"""Shared layer primitives: norms, MLPs, RoPE, embeddings.

Pure functions over param dicts.  Matmuls run in the params' dtype (bf16)
with fp32 accumulation where it matters (attention logits, softmax, norms).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models.param import Spec

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm_specs(cfg: ArchConfig, d: Optional[int] = None) -> dict:
    d = d or cfg.d_model
    out = {"scale": Spec((d,), ("embed",), jnp.float32, "ones")}
    if cfg.norm == "layernorm":
        out["bias"] = Spec((d,), ("embed",), jnp.float32, "zeros")
    return out


def apply_norm(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    x = x.astype(F32)
    if cfg.norm == "layernorm":
        x = x - jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    if cfg.norm == "layernorm":
        x = x + p["bias"]
    return x.astype(dt)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(F32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * scale).astype(dt)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU/GeGLU or plain)
# ---------------------------------------------------------------------------
def mlp_specs(cfg: ArchConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    out = {"wo": Spec((f, d), ("mlp", "embed"))}
    if cfg.glu:
        out["wi_0"] = Spec((d, f), ("embed", "mlp"))
        out["wi_1"] = Spec((d, f), ("embed", "mlp"))
    else:
        out["wi_0"] = Spec((d, f), ("embed", "mlp"))
    if cfg.use_bias:
        out["bi"] = Spec((f,), ("mlp",), jnp.float32, "zeros")
        out["bo"] = Spec((d,), ("embed",), jnp.float32, "zeros")
    return out


def _act(cfg: ArchConfig, x):
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def apply_mlp(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["wi_0"])
    if "bi" in p:
        h = h + p["bi"].astype(h.dtype)
    h = _act(cfg, h)
    if cfg.glu:
        h = h * jnp.einsum("...d,df->...f", x, p["wi_1"])
    h = shard(h, *(("batch", "res_seq", "mlp") if h.ndim == 3 else ("batch", "mlp")))
    o = jnp.einsum("...f,fd->...d", h, p["wo"])
    if "bo" in p:
        o = o + p["bo"].astype(o.dtype)
    return o


# ---------------------------------------------------------------------------
# RoPE (partial-rotary aware)
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float, fraction: float = 1.0) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freq = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    ang = positions[..., None].astype(F32) * freq            # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = xr[..., :half].astype(F32), xr[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_specs(cfg: ArchConfig) -> dict:
    out = {"tokens": Spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=1.0)}
    return out


def embed_tokens(cfg: ArchConfig, p: dict, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["tokens"], tokens, axis=0)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    axes = ("batch", "seq", "embed") if x.ndim == 3 else ("batch", "embed")
    return shard(x, *axes)


def unembed(cfg: ArchConfig, params: dict, x: jax.Array) -> jax.Array:
    kern = params["embed"]["tokens"] if cfg.tie_embeddings else params["unembed"]["kernel"]
    logits = jnp.einsum("...d,vd->...v", x, kern)
    if cfg.attn_logit_softcap:  # gemma-style final softcap reuse
        c = cfg.attn_logit_softcap
        logits = jnp.tanh(logits / c) * c
    axes = ("batch", "seq", "vocab") if logits.ndim == 3 else ("batch", "vocab")
    return shard(logits, *axes)
