"""Mixture-of-Experts FFN.

Two interchangeable implementations (config/env selectable, allclose-tested
against each other under generous capacity):

  * ``dispatch`` — Mesh-TF style capacity-bounded one-hot dispatch einsums.
    Shards cleanly under GSPMD (experts on "model" when divisible, else
    per-expert d_ff TP) and yields true HLO FLOPs for the roofline.
  * ``dense``    — every expert on every token, masked combine.  Exact;
    used as the oracle and for tiny smoke configs.

deepseek-style shared experts are a fused dense MLP alongside the routed path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.distributed.sharding import shard
from repro.models.layers import _act
from repro.models.param import Spec

F32 = jnp.float32


def moe_specs(cfg: ArchConfig) -> dict:
    from repro.util import opt_flags
    moe = cfg.moe
    d = cfg.d_model
    fe = moe.expert_d_ff or cfg.d_ff
    e = moe.num_experts
    # serving opt "w8_experts": weight-only int8 expert banks (dequant at
    # use) — halves storage vs bf16 and cuts FSDP gather bytes 4x vs the
    # f32 gathers XLA otherwise emits.
    wdt = jnp.int8 if "w8_experts" in opt_flags() else jnp.bfloat16
    # greedy rules resolve the strategy: expert dim takes "model" when it
    # divides (deepseek 64e, jamba 16e = EP); else per-expert d_ff TP
    # (mixtral 8e); expert_embed always FSDPs on "data".
    out = {
        "router": Spec((d, e), ("embed", "expert"), jnp.float32),
        "wi_0": Spec((e, d, fe), ("expert", "expert_embed", "expert_mlp"), wdt),
        "wi_1": Spec((e, d, fe), ("expert", "expert_embed", "expert_mlp"), wdt),
        "wo": Spec((e, fe, d), ("expert", "expert_mlp", "expert_embed"), wdt),
    }
    if wdt == jnp.int8:
        out["wi_0_scale"] = Spec((e,), ("expert",), jnp.float32, "ones")
        out["wi_1_scale"] = Spec((e,), ("expert",), jnp.float32, "ones")
        out["wo_scale"] = Spec((e,), ("expert",), jnp.float32, "ones")
    if moe.num_shared_experts:
        fs = fe * moe.num_shared_experts
        out["shared"] = {
            "wi_0": Spec((d, fs), ("embed", "mlp")),
            "wi_1": Spec((d, fs), ("embed", "mlp")),
            "wo": Spec((fs, d), ("mlp", "embed")),
        }
    return out


def _router(cfg: ArchConfig, p: dict, x: jax.Array):
    """x: (..., d) -> top-k indices (..., k) and fp32 weights (..., k)."""
    moe = cfg.moe
    logits = jnp.einsum("...d,de->...e", x.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, moe.top_k)
    w = w / jnp.clip(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return idx, w, probs


def _dq(p, name):
    """Dequantize int8 expert weights at use (no-op for bf16).

    The replication constraint sits on the *int8* tensor so the SPMD
    partitioner must move the quantized bits (4x fewer than the f32
    gathers it otherwise emits) and dequantize after the collective.
    """
    w = p[name]
    if w.dtype == jnp.int8:
        # gather the FSDP'd embed dim in int8; keep the d_ff TP shard
        axes = (None, "expert_mlp", None) if name == "wo" else (None, None, "expert_mlp")
        w = shard(w, *axes)
        scale = p[name + "_scale"] * (1.0 / 127.0)
        return (w.astype(jnp.bfloat16)
                * scale.astype(jnp.bfloat16)[:, None, None])
    return w


def _expert_ffn(cfg, p, xe):
    """xe: (..., e, c, d) dispatched tokens -> expert MLP output."""
    h0 = jnp.einsum("...ecd,edf->...ecf", xe, _dq(p, "wi_0"))
    h1 = jnp.einsum("...ecd,edf->...ecf", xe, _dq(p, "wi_1"))
    h = _act(cfg, h0) * h1
    h = shard(h, "batch", "expert", None, "expert_mlp")
    return jnp.einsum("...ecf,efd->...ecd", h, _dq(p, "wo"))


def apply_moe(cfg: ArchConfig, p: dict, x: jax.Array, impl: str = "dispatch") -> jax.Array:
    """x: (B, S, d) or (B, d). Returns same shape."""
    moe = cfg.moe
    squeezed = x.ndim == 2
    if squeezed:
        x = x[:, None, :]
    b, s, d = x.shape
    idx, w, probs = _router(cfg, p, x)                  # (b,s,k)

    if impl == "dense":
        onehot = jax.nn.one_hot(idx, moe.num_experts, dtype=F32)   # (b,s,k,e)
        comb = jnp.einsum("bske,bsk->bse", onehot, w)              # (b,s,e)
        h0 = jnp.einsum("bsd,edf->bsef", x, _dq(p, "wi_0"))
        h1 = jnp.einsum("bsd,edf->bsef", x, _dq(p, "wi_1"))
        h = _act(cfg, h0) * h1
        y = jnp.einsum("bsef,efd->bsed", h, _dq(p, "wo"))
        out = jnp.einsum("bsed,bse->bsd", y.astype(F32), comb).astype(x.dtype)
    else:
        e = moe.num_experts
        cap = max(1, int(moe.top_k * s * moe.capacity_factor / e))
        # position of each (token, expert) assignment within the expert queue
        sel = jax.nn.one_hot(idx, e, dtype=jnp.int32)              # (b,s,k,e)
        pos_in_e = jnp.cumsum(sel.reshape(b, s * moe.top_k, e), axis=1)
        pos_in_e = pos_in_e.reshape(b, s, moe.top_k, e) - 1        # 0-based
        keep = (pos_in_e < cap) & (sel > 0)
        slot = jax.nn.one_hot(jnp.clip(pos_in_e, 0, cap - 1), cap, dtype=F32)
        disp = jnp.einsum("bske,bskec->bsec", (sel * keep).astype(F32), slot)
        comb = jnp.einsum("bsec,bsk,bske->bsec", disp, w, (sel * keep).astype(F32))
        xe = jnp.einsum("bsec,bsd->becd", disp.astype(x.dtype), x)
        xe = shard(xe, "batch", "expert", None, None)
        y = _expert_ffn(cfg, p, xe)                                 # (b,e,c,d)
        out = jnp.einsum("bsec,becd->bsd", comb.astype(x.dtype), y)

    if moe.num_shared_experts:
        sp = p["shared"]
        h = _act(cfg, jnp.einsum("bsd,df->bsf", x, sp["wi_0"]))
        h = h * jnp.einsum("bsd,df->bsf", x, sp["wi_1"])
        out = out + jnp.einsum("bsf,fd->bsd", h, sp["wo"])

    out = shard(out, "batch", "res_seq", "embed")
    return out[:, 0, :] if squeezed else out


def aux_load_balance_loss(cfg: ArchConfig, probs: jax.Array, idx: jax.Array) -> jax.Array:
    """Switch-style auxiliary loss (exposed for training)."""
    e = cfg.moe.num_experts
    onehot = jax.nn.one_hot(idx[..., 0], e, dtype=F32)
    frac_tokens = jnp.mean(onehot, axis=tuple(range(onehot.ndim - 1)))
    frac_probs = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return e * jnp.sum(frac_tokens * frac_probs)
