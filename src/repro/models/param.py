"""Parameter spec trees.

A model is described by a nested dict of ``Spec`` leaves — the single source
of truth for shape, dtype, logical sharding axes, and initializer.  From the
same tree we derive:

  * ``init_tree``      — materialized params (smoke tests, examples)
  * ``abstract_tree``  — ShapeDtypeStructs (dry-run lowering: zero allocation)
  * ``axes_tree``      — logical-axis tuples (sharding rules -> NamedSharding)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Spec:
    shape: tuple
    axes: tuple                      # logical axis name (or None) per dim
    dtype: Any = jnp.bfloat16
    init: str = "normal"             # normal | zeros | ones | constant
    scale: Optional[float] = None    # stddev for normal / value for constant

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _map(tree, fn):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def abstract_tree(tree):
    return _map(tree, lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype))


class Axes:
    """Opaque (non-pytree) wrapper for a logical-axes tuple."""

    __slots__ = ("names",)

    def __init__(self, names):
        self.names = tuple(names)

    def __iter__(self):
        return iter(self.names)

    def __repr__(self):
        return f"Axes{self.names}"


def axes_tree(tree):
    return _map(tree, lambda s: Axes(s.axes))


def init_tree(tree, key):
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for s, k in zip(leaves, keys):
        if s.init == "zeros":
            v = jnp.zeros(s.shape, s.dtype)
        elif s.init == "ones":
            v = jnp.ones(s.shape, s.dtype)
        elif s.init == "constant":
            v = jnp.full(s.shape, s.scale, s.dtype)
        else:
            fan_in = s.shape[0] if s.shape else 1
            std = s.scale if s.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
            v = (jax.random.normal(k, s.shape, jnp.float32) * std).astype(s.dtype)
        out.append(v)
    return jax.tree_util.tree_unflatten(treedef, out)


def stack_specs(tree, n: int, axis_name: str = "layer"):
    """Prepend a stacking dim of size n (scanned layer groups)."""
    return _map(
        tree,
        lambda s: dataclasses.replace(s, shape=(n,) + s.shape, axes=(axis_name,) + s.axes),
    )


def count_params_tree(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def tree_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in leaves))
