"""Decoder stack assembly: pattern-scanned heterogeneous blocks.

A model is ``embed -> scan(groups) -> final_norm`` where one *group* is one
repetition of ``cfg.resolved_pattern`` (e.g. gemma3: 5 SWA + 1 global attn;
jamba: 7 mamba + 1 attn, MoE on odd positions).  Params and caches are
stacked along a leading "layer" axis so HLO size is O(|pattern|), not
O(num_layers) — this keeps 512-device compiles fast and is how real JAX
frameworks (MaxText et al.) scale depth.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_SWA, ENC_ATTN, MAMBA, ArchConfig
from repro.distributed.sharding import shard
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import apply_mlp, apply_norm, mlp_specs, norm_specs
from repro.models.param import Spec, stack_specs
from repro.util import cost_mode


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------
def block_specs(cfg: ArchConfig, pos: int, kind: str, cross: bool = False) -> dict:
    out = {"norm1": norm_specs(cfg)}
    if kind == MAMBA:
        out["mamba"] = mamba_mod.mamba_specs(cfg)
    else:
        out["attn"] = attn_mod.attention_specs(cfg)
    if cross:
        out["xnorm"] = norm_specs(cfg)
        out["xattn"] = attn_mod.attention_specs(cfg, cross=True)
    is_moe = cfg.moe is not None and cfg.moe_positions and pos in cfg.moe_positions
    has_ffn = cfg.d_ff > 0 or is_moe
    if has_ffn:
        if not cfg.parallel_block:
            out["norm2"] = norm_specs(cfg)
        out["moe" if is_moe else "mlp"] = (
            moe_mod.moe_specs(cfg) if is_moe else mlp_specs(cfg)
        )
    return out


def stack_block_specs(cfg: ArchConfig, pattern, n_groups: int, cross=False) -> dict:
    per_pos = {f"pos{i}": block_specs(cfg, i, kind, cross=cross)
               for i, kind in enumerate(pattern)}
    return stack_specs(per_pos, n_groups)


def cache_specs_for_kind(cfg: ArchConfig, kind: str, batch: int, max_len: int) -> dict:
    if kind == MAMBA:
        return mamba_mod.mamba_cache_specs(cfg, batch)
    window = cfg.sliding_window if kind == ATTN_SWA else None
    return attn_mod.make_kv_cache_specs(cfg, batch, max_len, window=window)


def stack_cache_specs(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    pattern = cfg.resolved_pattern
    per_pos = {f"pos{i}": cache_specs_for_kind(cfg, kind, batch, max_len)
               for i, kind in enumerate(pattern)}
    return stack_specs(per_pos, cfg.n_groups)


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------
def _ffn(cfg, p, x, moe_impl):
    if "moe" in p:
        return moe_mod.apply_moe(cfg, p["moe"], x, impl=moe_impl)
    if "mlp" in p:
        return apply_mlp(cfg, p["mlp"], x)
    return jnp.zeros_like(x)


def apply_block_seq(cfg: ArchConfig, p: dict, kind: str, x: jax.Array, *,
                    positions: jax.Array, impl: str, moe_impl: str,
                    enc_out=None) -> jax.Array:
    h = apply_norm(cfg, p["norm1"], x)
    if kind == MAMBA:
        mix = mamba_mod.apply_mamba(cfg, p["mamba"], h, impl=impl)
    else:
        window = cfg.sliding_window if kind == ATTN_SWA else None
        mix = attn_mod.self_attention(cfg, p["attn"], h, positions=positions,
                                      causal=(kind != ENC_ATTN), window=window,
                                      impl=impl)
    if cfg.parallel_block:
        return shard(x + mix + _ffn(cfg, p, h, moe_impl),
                     "batch", "res_seq", "embed")
    x = x + mix
    if "xattn" in p:
        hx = apply_norm(cfg, p["xnorm"], x)
        x = x + attn_mod.cross_attention_seq(cfg, p["xattn"], hx, enc_out, impl=impl)
    if "norm2" in p:
        h2 = apply_norm(cfg, p["norm2"], x)
        x = x + _ffn(cfg, p, h2, moe_impl)
    return shard(x, "batch", "res_seq", "embed")


def apply_block_decode(cfg: ArchConfig, p: dict, kind: str, x: jax.Array,
                       cache: dict, *, positions: jax.Array, impl: str,
                       moe_impl: str, enc_lengths=None):
    """x: (B, D) single token."""
    h = apply_norm(cfg, p["norm1"], x)
    if kind == MAMBA:
        mix, new_cache = mamba_mod.decode_mamba(cfg, p["mamba"], h, cache)
    else:
        window = cfg.sliding_window if kind == ATTN_SWA else None
        mix, new_cache = attn_mod.decode_self_attention(
            cfg, p["attn"], h, cache, positions=positions,
            lengths=positions + 1, window=window, impl=impl)
    if cfg.parallel_block:
        return x + mix + _ffn(cfg, p, h, moe_impl), new_cache
    x = x + mix
    if "xattn" in p:
        hx = apply_norm(cfg, p["xnorm"], x)
        x = x + attn_mod.cross_attention_decode(cfg, p["xattn"], hx,
                                                cache["ek"], cache["ev"],
                                                enc_lengths, impl=impl)
        new_cache = dict(new_cache, ek=cache["ek"], ev=cache["ev"])
    if "norm2" in p:
        h2 = apply_norm(cfg, p["norm2"], x)
        x = x + _ffn(cfg, p, h2, moe_impl)
    return x, new_cache


# ---------------------------------------------------------------------------
# Stack runners (scan over groups)
# ---------------------------------------------------------------------------
def run_stack_seq(cfg: ArchConfig, groups: dict, x: jax.Array, *,
                  positions: jax.Array, impl: str = "auto",
                  moe_impl: str = "dispatch", remat: bool = True,
                  pattern=None, enc_out=None) -> jax.Array:
    pattern = pattern or cfg.resolved_pattern

    def group_fn(carry, gp):
        h = carry
        for i, kind in enumerate(pattern):
            h = apply_block_seq(cfg, gp[f"pos{i}"], kind, h,
                                positions=positions, impl=impl,
                                moe_impl=moe_impl, enc_out=enc_out)
        return h, None

    if remat:
        from repro.util import opt_flags
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if "remat_dots" in opt_flags() else None)
        body = jax.checkpoint(group_fn, policy=policy)
    else:
        body = group_fn
    x, _ = jax.lax.scan(body, x, groups, unroll=cost_mode())
    return x


def run_stack_prefill(cfg: ArchConfig, groups: dict, x: jax.Array, *,
                      positions: jax.Array, max_len: int, impl: str = "auto",
                      moe_impl: str = "dispatch", pattern=None, enc_out=None):
    """Like seq, but also emits per-position decode caches (scan ys)."""
    pattern = pattern or cfg.resolved_pattern

    def group_fn(carry, gp):
        h = carry
        caches = {}
        for i, kind in enumerate(pattern):
            p = gp[f"pos{i}"]
            h_new = apply_block_seq(cfg, p, kind, h, positions=positions,
                                    impl=impl, moe_impl=moe_impl,
                                    enc_out=enc_out)
            caches[f"pos{i}"] = _prefill_cache(cfg, p, kind, h, positions,
                                               max_len, impl, enc_out=enc_out)
            h = h_new
        return h, caches

    x, caches = jax.lax.scan(group_fn, x, groups, unroll=cost_mode())
    return x, caches


def _prefill_cache(cfg, p, kind, h_in, positions, max_len, impl, enc_out=None):
    """Build the decode cache entry for one block from its prefill input."""
    b, s, _ = h_in.shape
    if kind == MAMBA:
        hn = apply_norm(cfg, p["norm1"], h_in)
        return _mamba_prefill_cache(cfg, p["mamba"], hn)
    window = cfg.sliding_window if kind == ATTN_SWA else None
    hn = apply_norm(cfg, p["norm1"], h_in)
    _, k, v = attn_mod._proj_qkv(cfg, p["attn"], hn)
    from repro.models.layers import rope
    k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    size = min(max_len, window) if window else max_len
    if size >= s:
        pad = size - s
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        pos = jnp.pad(jnp.broadcast_to(positions, (b, s)), ((0, 0), (0, pad)),
                      constant_values=-1)
    else:  # ring: keep last `size`, placed at slot = pos % size
        import numpy as np
        last = np.arange(s - size, s)
        slot_of = np.zeros(size, np.int64)
        slot_of[last % size] = last
        kc = k[:, slot_of].astype(jnp.bfloat16)
        vc = v[:, slot_of].astype(jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.asarray(slot_of, jnp.int32), (b, size))
    kc = shard(kc, "batch", "kv_seq", "kv_heads", "head_dim")
    vc = shard(vc, "batch", "kv_seq", "kv_heads", "head_dim")
    out = {"k": kc, "v": vc, "pos": pos}
    if "xattn" in p:
        ek, ev = attn_mod.cross_kv(p["xattn"], enc_out)
        out["ek"], out["ev"] = ek.astype(jnp.bfloat16), ev.astype(jnp.bfloat16)
    return out


def _mamba_prefill_cache(cfg, p, hn):
    """Run the mamba projections + SSD once more to get the final state."""
    m, di, nh, pd, n = mamba_mod._dims(cfg)
    b, s, _ = hn.shape
    z, xm, Bm, Cm, dt = mamba_mod._project(cfg, p, hn)
    xm = mamba_mod._causal_conv(xm, p["conv_x"], p["conv_bx"])
    Bmc = mamba_mod._causal_conv(Bm.reshape(b, s, -1), p["conv_B"], p["conv_bB"])
    Cmc = mamba_mod._causal_conv(Cm.reshape(b, s, -1), p["conv_C"], p["conv_bC"])
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    from repro.kernels import ops
    _, hstate = ops.ssd_scan(xm.reshape(b, s, nh, pd), dt, A,
                             Bmc.reshape(b, s, 1, n), Cmc.reshape(b, s, 1, n),
                             chunk=m.chunk, impl="ref")
    # conv caches: last (d_conv - 1) *pre-activation* inputs
    z2, x2, B2, C2, _ = mamba_mod._project(cfg, p, hn[:, -(m.d_conv - 1):, :])
    return {"h": hstate, "conv_x": x2.astype(jnp.bfloat16),
            "conv_B": B2.reshape(b, m.d_conv - 1, -1).astype(jnp.bfloat16),
            "conv_C": C2.reshape(b, m.d_conv - 1, -1).astype(jnp.bfloat16)}


def run_stack_decode(cfg: ArchConfig, groups: dict, x: jax.Array, cache: dict, *,
                     positions: jax.Array, impl: str = "auto",
                     moe_impl: str = "dispatch", pattern=None, enc_lengths=None):
    pattern = pattern or cfg.resolved_pattern

    def group_fn(carry, xs):
        gp, gcache = xs
        h = carry
        new_caches = {}
        for i, kind in enumerate(pattern):
            h, nc = apply_block_decode(cfg, gp[f"pos{i}"], kind, h,
                                       gcache[f"pos{i}"], positions=positions,
                                       impl=impl, moe_impl=moe_impl,
                                       enc_lengths=enc_lengths)
            new_caches[f"pos{i}"] = nc
        return h, new_caches

    x, new_cache = jax.lax.scan(group_fn, x, (groups, cache), unroll=cost_mode())
    return x, new_cache
