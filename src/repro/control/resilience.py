"""Client-side resilience: timeouts, jittered retries, shedding, breaking.

The mechanics here follow the failure-handling literature the paper's
robustness scenarios reproduce ("Tell-Tale Tail Latencies", the AWS
backoff-and-jitter analysis): a timed-out request's server-side work is
NOT cancelled (it completes as a zombie and is discarded — wasted
capacity), naive immediate retries multiply offered load exactly when
the fleet is saturated (the metastable retry storm), and the cure is
exponential backoff with decorrelated jitter plus a retry *budget* that
caps the retry fraction of traffic.

All randomness is drawn from an injected ``numpy`` Generator the owning
runtime seeds with the domain tag ``(0xB0FF, seed, rep)`` — resilience
decisions never perturb the arrival/service RNG streams, and
repetitions draw independent jitter.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Optional

#: RNG domain tag for resilience draws (jitter, probabilistic admission)
RESILIENCE_STREAM = 0xB0FF

JITTER_MODES = ("none", "full", "decorrelated")


@dataclass(frozen=True)
class RetryPolicy:
    """Per-request timeout + bounded retry declaration (hashable,
    sweepable, fingerprintable).

    ``jitter="none"`` is the naive exponential schedule every client
    fires in lockstep; ``"full"`` draws U(0, backoff); ``"decorrelated"``
    draws U(base, 3*previous) per the AWS analysis.  ``budget_ratio``
    caps issued retries at that fraction of primary requests (plus a
    small ``budget_burst`` so short runs can retry at all) — the knob
    that separates recovery from congestion collapse.
    """
    timeout: float = 1.0
    max_retries: int = 0
    backoff_base: float = 0.05
    backoff_cap: float = 2.0
    jitter: str = "full"
    budget_ratio: float = 0.1
    budget_burst: int = 10

    def __post_init__(self):
        if self.jitter not in JITTER_MODES:
            raise ValueError(f"unknown jitter mode {self.jitter!r}; "
                             f"known: {', '.join(JITTER_MODES)}")
        if self.timeout <= 0.0:
            raise ValueError("timeout must be positive")

    def delay(self, attempt: int, prev: float, rng) -> float:
        """Backoff before retry ``attempt`` (1-based).  ``prev`` is the
        previous delay (decorrelated jitter chains on it); ``rng`` is
        the runtime's resilience Generator."""
        cap = self.backoff_cap
        if self.jitter == "decorrelated":
            lo = self.backoff_base
            hi = max(3.0 * max(prev, lo), lo)
            return min(cap, lo + float(rng.random()) * (hi - lo))
        base = min(cap, self.backoff_base * (2.0 ** (attempt - 1)))
        if self.jitter == "full":
            return float(rng.random()) * base
        return base


class RetryBudget:
    """Caps retries at ``ratio`` x primary requests (+ ``burst``)."""

    def __init__(self, ratio: float, burst: int = 10):
        self.ratio = float(ratio)
        self.burst = int(burst)
        self.primaries = 0
        self.retries = 0

    def note_primary(self) -> None:
        self.primaries += 1

    def allow(self) -> bool:
        return self.retries < self.ratio * self.primaries + self.burst

    def note_retry(self) -> None:
        self.retries += 1


class AdmissionController:
    """Load shedding at the admission point: probabilistic (admit each
    request with probability ``admit``) or token-bucket (``rate``
    requests/sec with ``burst`` capacity).  Probabilistic decisions
    draw from the injected resilience RNG; the token bucket is
    RNG-free, so it sheds bit-identically on both event backends."""

    def __init__(self, admit: Optional[float] = None,
                 rate: Optional[float] = None, burst: float = 1.0):
        if admit is None and rate is None:
            raise ValueError("set_admission needs admit= or rate=")
        self.admit = 1.0 if admit is None else min(max(float(admit), 0.0), 1.0)
        self.rate = None if rate is None else float(rate)
        self.burst = float(burst)
        self._tokens = self.burst
        self._last_t: Optional[float] = None

    def allow(self, t: float, rng) -> bool:
        if self.rate is not None:
            if self._last_t is not None:
                self._tokens = min(self.burst,
                                   self._tokens + (t - self._last_t)
                                   * self.rate)
            self._last_t = t
            if self._tokens >= 1.0:
                self._tokens -= 1.0
            else:
                return False
        if self.admit >= 1.0:
            return True
        if self.admit <= 0.0:
            return False
        return float(rng.random()) < self.admit

    @property
    def level(self) -> float:
        """The probabilistic admit level (the AIMD shedder's state)."""
        return self.admit


@dataclass(frozen=True)
class BreakerSpec:
    """Per-server circuit breaker declaration: open when the failure
    fraction over the last ``window`` outcomes reaches ``threshold``
    (with at least ``min_samples`` observed), hold open ``cooldown``
    seconds, then half-open — one probe request decides."""
    window: int = 20
    threshold: float = 0.5
    cooldown: float = 5.0
    min_samples: int = 5


class CircuitBreaker:
    """Mutable per-server breaker state for one run."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, spec: BreakerSpec):
        self.spec = spec
        self._outcomes: dict[int, deque] = {}
        self._state: dict[int, str] = {}
        self._opened_at: dict[int, float] = {}

    def state(self, sid: int) -> str:
        return self._state.get(sid, self.CLOSED)

    def record(self, sid: int, ok: bool, now: float) -> None:
        st = self.state(sid)
        if st == self.HALF_OPEN:
            if ok:                       # probe succeeded: close + reset
                self._state[sid] = self.CLOSED
                self._outcomes.pop(sid, None)
            else:                        # probe failed: re-open
                self._state[sid] = self.OPEN
                self._opened_at[sid] = now
            return
        q = self._outcomes.get(sid)
        if q is None:
            q = self._outcomes[sid] = deque(maxlen=self.spec.window)
        q.append(ok)
        if st == self.CLOSED and len(q) >= self.spec.min_samples:
            bad = sum(1 for o in q if not o)
            if bad >= self.spec.threshold * len(q):
                self._state[sid] = self.OPEN
                self._opened_at[sid] = now

    def allow(self, sid: int, now: float) -> bool:
        st = self.state(sid)
        if st == self.CLOSED:
            return True
        if st == self.OPEN:
            if now - self._opened_at.get(sid, now) >= self.spec.cooldown:
                self._state[sid] = self.HALF_OPEN
                return True              # the probe request
            return False
        return False                     # half-open: probe already in flight
