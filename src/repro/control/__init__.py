"""Closed-loop control plane + client resilience primitives.

``ControlPolicy`` implementations observe windowed telemetry
(``Observation``) and emit actions — scale the fleet, tune admission
control — which the runtimes apply with actuation lag and cooldowns
(``ControlLoop``).  The resilience side (``RetryPolicy``,
``AdmissionController``, ``CircuitBreaker``, ``RetryBudget``) gives
clients timeouts, bounded jittered retries, and shedding whose refused
requests are accounted explicitly in the latency statistics (see
``LatencyRecorder.record_failure``) instead of vanishing from the
percentiles.

The package deliberately imports nothing from ``repro.core`` — the
runtimes import it, never the reverse.
"""
from repro.control.loop import ControlLoop, observe_runtime
from repro.control.policy import (CONTROLLERS, AdmissionShedder,
                                  ControlPolicy, ControlSpec, Observation,
                                  ThresholdAutoscaler)
from repro.control.resilience import (AdmissionController, BreakerSpec,
                                      CircuitBreaker, RetryBudget,
                                      RetryPolicy)

__all__ = [
    "AdmissionController", "AdmissionShedder", "BreakerSpec",
    "CircuitBreaker", "CONTROLLERS", "ControlLoop", "ControlPolicy",
    "ControlSpec", "Observation", "observe_runtime", "RetryBudget",
    "RetryPolicy", "ThresholdAutoscaler",
]
