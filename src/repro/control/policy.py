"""Control policies: observe windowed telemetry, emit actions.

A ``ControlPolicy`` is the reactive half of the harness: once per
control interval the owning runtime builds an ``Observation`` from its
telemetry (served QPS, windowed p99, utilization, queue depth,
SLO-violation fraction) and the policy answers with zero or more
actions — ``("set_scale", {"n": ...})`` / ``("set_admission",
{"admit": ...})`` tuples shaped exactly like injection records, so one
application path serves scripted injections and closed-loop control.

Policies are *declared* as ``ControlSpec`` — a frozen, hashable,
fingerprintable record — so they sweep as first-class axes through
``repro.sweep`` and key result-cache entries; ``spec.build()``
instantiates the mutable per-run policy object from the
``CONTROLLERS`` registry.

The two stock policies key on utilization and queue depth, which every
backend can observe (the vector runtime's fluid pre-pass included);
percentile-keyed policies run on the event backends only — the fluid
observation carries ``p99 = nan`` and a policy must treat NaN fields
as "unobserved", never act on them.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class Observation:
    """One control-interval window of telemetry, backend-agnostic.
    Fields a backend cannot measure are NaN (fluid limit: p99,
    slo_frac) — policies must no-op on NaN, not compare against it."""
    t: float                     # window end (virtual seconds)
    n: int                       # requests served in the window
    qps: float                   # served throughput over the window
    p99: float                   # windowed p99 latency (NaN: unobserved)
    mean: float                  # windowed mean latency (NaN: unobserved)
    util: float                  # mean utilization across active servers
    qdepth: float                # total queued requests across the fleet
    slo_frac: float              # windowed SLO-violation fraction (NaN ok)
    n_active: int                # servers currently accepting work
    admit: float                 # current admission level in [0, 1]


class ControlPolicy:
    """Base class: ``update(obs) -> [(kind, params), ...]``."""

    def update(self, obs: Observation) -> list:
        raise NotImplementedError


class ThresholdAutoscaler(ControlPolicy):
    """Scale out when the keyed metric crosses ``high``, in below
    ``low`` — the classic reactive autoscaler whose actuation lag and
    cooldown (enforced by ``ControlLoop``) create the over/undershoot
    dynamics the paper's flash-crowd scenarios exercise."""

    def __init__(self, high: float = 0.85, low: float = 0.40,
                 metric: str = "util", step: int = 1,
                 min_servers: int = 1, max_servers: int = 1024):
        self.high = float(high)
        self.low = float(low)
        self.metric = metric
        self.step = int(step)
        self.min_servers = int(min_servers)
        self.max_servers = int(max_servers)

    def update(self, obs: Observation) -> list:
        x = getattr(obs, self.metric)
        if x != x:                          # NaN: metric unobserved here
            return []
        if x > self.high and obs.n_active < self.max_servers:
            n = min(obs.n_active + self.step, self.max_servers)
            return [("set_scale", {"n": n})]
        if x < self.low and obs.n_active > self.min_servers:
            n = max(obs.n_active - self.step, self.min_servers)
            return [("set_scale", {"n": n})]
        return []


class AdmissionShedder(ControlPolicy):
    """AIMD admission control: when per-server queue depth exceeds
    ``target_qdepth`` the admit level drops multiplicatively
    (``decrease``); while the fleet is healthy it recovers additively
    (``increase``) back to 1.0.  Floor keeps a trickle of traffic
    flowing so recovery is observable."""

    def __init__(self, target_qdepth: float = 8.0, decrease: float = 0.7,
                 increase: float = 0.1, floor: float = 0.05):
        self.target_qdepth = float(target_qdepth)
        self.decrease = float(decrease)
        self.increase = float(increase)
        self.floor = float(floor)

    def update(self, obs: Observation) -> list:
        if obs.qdepth != obs.qdepth or obs.n_active <= 0:
            return []
        per_server = obs.qdepth / obs.n_active
        if per_server > self.target_qdepth:
            admit = max(self.floor, obs.admit * self.decrease)
        elif obs.admit < 1.0:
            admit = min(1.0, obs.admit + self.increase)
        else:
            return []
        if admit == obs.admit:
            return []
        return [("set_admission", {"admit": admit})]


#: name -> policy class; ``ControlSpec.build`` resolves through this
CONTROLLERS = {
    "threshold_autoscaler": ThresholdAutoscaler,
    "admission_shedder": AdmissionShedder,
}


@dataclass(frozen=True)
class ControlSpec:
    """Declarative, hashable form of one closed-loop controller.

    ``params`` is a sorted tuple of ``(name, value)`` pairs so the spec
    hashes, pickles across sweep workers, and fingerprints for the
    result cache.  ``interval`` is the observation cadence, ``lag`` the
    actuation delay between a decision and its effect (provisioning
    time), ``cooldown`` the minimum time between consecutive actions.
    """
    name: str
    params: tuple = ()
    interval: float = 1.0
    lag: float = 0.0
    cooldown: float = 0.0

    @classmethod
    def make(cls, name: str, *, interval: float = 1.0, lag: float = 0.0,
             cooldown: float = 0.0, **params) -> "ControlSpec":
        if name not in CONTROLLERS:
            raise ValueError(f"unknown controller {name!r}; known: "
                             f"{', '.join(sorted(CONTROLLERS))}")
        return cls(name=name, params=tuple(sorted(params.items())),
                   interval=float(interval), lag=float(lag),
                   cooldown=float(cooldown))

    def build(self) -> ControlPolicy:
        cls = CONTROLLERS.get(self.name)
        if cls is None:
            raise ValueError(f"unknown controller {self.name!r}; known: "
                             f"{', '.join(sorted(CONTROLLERS))}")
        return cls(**dict(self.params))
