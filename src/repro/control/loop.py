"""The shared control loop: windowed observation + cooldown/lag gating.

Both event backends drive the same machinery: once per control interval
they call ``loop.observe(...)`` to build an ``Observation`` from their
``LatencyRecorder`` and live server handles, then ``loop.tick(obs,
now)`` to let the policy act.  The loop enforces the spec's cooldown
(actions within ``cooldown`` of the previous action are suppressed);
the *caller* applies returned actions at ``now + spec.lag`` through its
own scheduler, so actuation lag rides the backend's native event order
and stays deterministic.

Windowed statistics come straight from the recorder: in exact mode the
window is the raw latency slice recorded since the previous tick; in
streaming mode it is the bounded reservoir of the latest closed stats
interval (approximate, like every streaming statistic).  Shed/timed-out
/failed requests recorded via ``record_failure`` count into the
window's SLO-violation fraction — the controller sees honest numbers.
"""
from __future__ import annotations

import math

import numpy as np

from repro.control.policy import ControlSpec, Observation


def observe_runtime(recorder, servers, t: float, slo, admit: float,
                    prev: dict) -> Observation:
    """Build one control-window ``Observation``.

    ``servers`` is the backend's *alive* server collection (``SimServer``
    or ``EngineServerHandle`` both fit: ``busy``, ``load()``, and a
    ``workers``/``max_batch`` capacity).  ``prev`` is the loop's mutable
    window state: ``{"n": ..., "bad": ..., "t": ...}`` counters as of
    the previous tick, updated in place.
    """
    servers = list(servers)
    utils = []
    qdepth = 0
    for s in servers:
        cap = getattr(s, "workers", None)
        if cap is None:
            cap = getattr(s, "max_batch", None)
        if cap is None:
            cap = 1
        busy = s.busy if hasattr(s, "busy") else s.load()
        utils.append(min(busy / cap, 1.0) if cap else 0.0)
        qdepth += max(s.load() - busy, 0)
    util = sum(utils) / len(utils) if utils else 0.0

    bad_total = recorder.failed_total()
    bad = bad_total - prev.get("bad", 0)
    window = max(t - prev.get("t", 0.0), 1e-12)
    if recorder.mode == "exact":
        xs = recorder.all[prev.get("n", 0):]
        n = len(xs)
        prev["n"] = len(recorder.all)
        if xs:
            arr = np.asarray(xs, float)
            p99 = float(np.percentile(arr, 99))
            mean = float(arr.mean())
            slow = int(np.count_nonzero(arr > slo)) if slo is not None else 0
        else:
            p99 = mean = float("nan")
            slow = 0
    else:
        n_total = recorder._all.n
        n = n_total - prev.get("n", 0)
        prev["n"] = n_total
        ivl = int(t / recorder.interval) - 1
        stat = recorder._by_ivl.get(ivl)
        if stat is not None and stat.res.data:
            arr = np.asarray(stat.res.data, float)
            p99 = float(np.percentile(arr, 99))
            mean = float(arr.mean())
            frac = (float(np.count_nonzero(arr > slo)) / arr.size
                    if slo is not None else 0.0)
            slow = frac * n               # scale the reservoir estimate
        else:
            p99 = mean = float("nan")
            slow = 0
    prev["bad"] = bad_total
    prev["t"] = t
    if slo is None or (n + bad) == 0:
        slo_frac = float("nan")
    else:
        slo_frac = (slow + bad) / (n + bad)
    return Observation(t=t, n=n, qps=n / window, p99=p99, mean=mean,
                       util=util, qdepth=float(qdepth), slo_frac=slo_frac,
                       n_active=len(servers), admit=admit)


class ControlLoop:
    """Cooldown/window bookkeeping around one policy instance."""

    def __init__(self, spec: ControlSpec):
        self.spec = spec
        self.policy = spec.build()
        self._last_action = -math.inf
        self._prev: dict = {}

    def observe(self, recorder, servers, t: float, slo,
                admit: float) -> Observation:
        return observe_runtime(recorder, servers, t, slo, admit,
                               self._prev)

    def tick(self, obs: Observation, now: float) -> list:
        """Policy update gated by the cooldown.  Returns ``(kind,
        params)`` actions for the caller to apply at ``now + lag``."""
        actions = self.policy.update(obs)
        if not actions:
            return []
        if now - self._last_action < self.spec.cooldown:
            return []
        self._last_action = now
        return actions
