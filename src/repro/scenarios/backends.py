"""Engine-fleet builders shared by the scenario CLI and launch/serve.

One place constructs the engine replicas (and the join factory) a
compiled scenario's engine backend needs — real JAX ``InferenceEngine``s
with warmed compile caches, or profile-timed ``StubEngine``s on a
virtual clock.
"""
from __future__ import annotations

from typing import Callable


def build_real_engines(arch: str, n: int, *, smoke: bool = False,
                       max_batch: int = 4, prompt_len: int = 16,
                       max_new_tokens: int = 4, seed: int = 0):
    """-> (engines, factory, vocab_size): ``n`` warmed real engines plus a
    ``factory(server_id)`` for servers that join mid-scenario."""
    import jax

    from repro.configs.base import get_config
    from repro.models import registry as R
    from repro.serving.engine import make_warmed_engine

    cfg = get_config(arch + ("-smoke" if smoke else ""))
    params = R.init_params(cfg, jax.random.PRNGKey(seed))

    def factory(sid=None):
        return make_warmed_engine(cfg, params, max_batch=max_batch,
                                  prompt_len=prompt_len,
                                  max_new_tokens=max_new_tokens)
    return [factory() for _ in range(n)], factory, cfg.vocab_size


def run_experiment_on_real_engines(exp, *, arch: str, smoke: bool = False,
                                   max_batch: int = 4, prompt_len: int = 16,
                                   max_new_tokens: int = 4, seed: int = 0,
                                   time_scale: float = 1.0):
    """Run a compiled experiment wall-clock on warmed real engines and
    return the finished ``EngineRuntime`` — the single assembly path the
    scenario CLI and ``launch/serve --scenario`` both use.  When the
    experiment samples per-request token sizes, the engines are sized for
    the distribution's maxima so no sampled prompt overflows the cache."""
    from repro.core.runtime import EngineRuntime

    lengths = exp.resolved_lengths()
    if lengths is not None:
        prompt_len = max(prompt_len, getattr(lengths, "prompt_max", prompt_len))
        max_new_tokens = max(max_new_tokens,
                             getattr(lengths, "new_max", max_new_tokens))
    n_base = sum(1 for s in exp.servers if s.join_at == 0.0)
    engines, factory, vocab = build_real_engines(
        arch, n_base, smoke=smoke, max_batch=max_batch,
        prompt_len=prompt_len, max_new_tokens=max_new_tokens, seed=seed)
    rt = EngineRuntime.from_experiment(
        exp, engines, engine_factory=factory, vocab=vocab,
        prompt_len=prompt_len, max_new_tokens=max_new_tokens,
        time_scale=time_scale)
    rt.run()
    return rt


def build_stub_engines(exp, clock: Callable[[], float], seed: int = 0):
    """-> (engines, factory): one stub replica per initial server spec of
    the compiled experiment, honoring workers/max_batch and speed.

    A scalar experiment gets profile-timed ``StubEngine`` slots; an
    experiment with a batched ``service_model`` gets ``BatchedStubEngine``
    replicas running the same ``BatchScheduler``/``BatchedService``
    dynamics as the simulator's batched serve loop."""
    from repro.serving.engine import BatchedStubEngine, StubEngine

    service = exp.resolved_service()
    batched = getattr(service, "kind", "scalar") == "batched"
    profile = exp.resolved_profile()
    specs = {s.server_id: s for s in exp.servers}

    def make(sid: int, workers: int, speed: float, max_batch, noise: float):
        if batched:
            return BatchedStubEngine(service, max_batch=max_batch or 8,
                                     speed=speed, service_noise=noise,
                                     seed=seed + sid, clock=clock)
        return StubEngine(profile, workers=workers, speed=speed,
                          service_noise=noise, seed=seed + sid, clock=clock)

    engines = {s.server_id: make(s.server_id, s.workers, s.speed, s.max_batch,
                                 s.service_noise)
               for s in exp.servers if s.join_at == 0.0}

    def factory(sid: int):
        spec = specs.get(sid)
        return make(sid,
                    spec.workers if spec else 1,
                    spec.speed if spec else 1.0,
                    spec.max_batch if spec else None,
                    spec.service_noise if spec else 0.0)
    return engines, factory
