"""Engine-fleet builders shared by the scenario CLI and launch/serve.

One place constructs the engine replicas (and the join factory) a
compiled scenario's engine backend needs — real JAX ``InferenceEngine``s
with warmed compile caches, or profile-timed ``StubEngine``s on a
virtual clock.
"""
from __future__ import annotations

from typing import Callable


def build_real_engines(arch: str, n: int, *, smoke: bool = False,
                       max_batch: int = 4, prompt_len: int = 16,
                       max_new_tokens: int = 4, seed: int = 0):
    """-> (engines, factory, vocab_size): ``n`` warmed real engines plus a
    ``factory(server_id)`` for servers that join mid-scenario."""
    import jax

    from repro.configs.base import get_config
    from repro.models import registry as R
    from repro.serving.engine import make_warmed_engine

    cfg = get_config(arch + ("-smoke" if smoke else ""))
    params = R.init_params(cfg, jax.random.PRNGKey(seed))

    def factory(sid=None):
        return make_warmed_engine(cfg, params, max_batch=max_batch,
                                  prompt_len=prompt_len,
                                  max_new_tokens=max_new_tokens)
    return [factory() for _ in range(n)], factory, cfg.vocab_size


def run_experiment_on_real_engines(exp, *, arch: str, smoke: bool = False,
                                   max_batch: int = 4, prompt_len: int = 16,
                                   max_new_tokens: int = 4, seed: int = 0,
                                   time_scale: float = 1.0):
    """Run a compiled experiment wall-clock on warmed real engines and
    return the finished ``EngineRuntime`` — the single assembly path the
    scenario CLI and ``launch/serve --scenario`` both use."""
    from repro.core.runtime import EngineRuntime

    n_base = sum(1 for s in exp.servers if s.join_at == 0.0)
    engines, factory, vocab = build_real_engines(
        arch, n_base, smoke=smoke, max_batch=max_batch,
        prompt_len=prompt_len, max_new_tokens=max_new_tokens, seed=seed)
    rt = EngineRuntime.from_experiment(
        exp, engines, engine_factory=factory, vocab=vocab,
        prompt_len=prompt_len, max_new_tokens=max_new_tokens,
        time_scale=time_scale)
    rt.run()
    return rt


def build_stub_engines(exp, clock: Callable[[], float], seed: int = 0):
    """-> (engines, factory): one profile-timed ``StubEngine`` per initial
    server spec of the compiled experiment, honoring workers and speed."""
    from repro.serving.engine import StubEngine

    profile = exp.resolved_profile()
    specs = {s.server_id: s for s in exp.servers}
    engines = {s.server_id: StubEngine(profile, workers=s.workers,
                                       speed=s.speed, seed=seed + s.server_id,
                                       clock=clock)
               for s in exp.servers if s.join_at == 0.0}

    def factory(sid: int):
        spec = specs.get(sid)
        return StubEngine(profile,
                          workers=spec.workers if spec else 1,
                          speed=spec.speed if spec else 1.0,
                          seed=seed + sid, clock=clock)
    return engines, factory
