"""The canonical TailBench++ scenarios.

Each builder returns a ``Scenario`` exercising one dynamic-cloud pattern
the paper's harness exists to reproduce (DeathStarBench's argument:
benchmark value comes from scenario breadth).  All are deterministic
functions of their seed, run on both backends, and accept keyword
overrides (duration, seed, app, policy, slo, ...).
"""
from __future__ import annotations

from repro.core.client import DiurnalQPS, PiecewiseQPS
from repro.core.harness import ServerSpec
from repro.core.profiles import BatchedService, TokenLengths
from repro.core.scenario import (ClientArrival, ClientChurn, FlashCrowd,
                                 Scenario, ServerDrain, ServerFail,
                                 ServerJoin, SetHedge, SetPolicy)
from repro.scenarios import register


def default_batched_service() -> BatchedService:
    """A small-model serving cost profile: 2ms weight-streaming per decode
    step (memory term), ridge point at batch 8, prompt prefill at
    10us/token.  Calibrate from a real architecture's roofline with
    ``BatchedService.from_arch("phi3-mini-3.8b")`` instead."""
    return BatchedService("batched:default", t_memory=2e-3,
                          t_compute_per_seq=2.5e-4,
                          t_prefill_per_token=1e-5)


@register("steady")
def steady(*, duration: float = 30.0, seed: int = 0, app: str = "xapian",
           policy: str = "round_robin", n_clients: int = 4,
           qps: float = 800.0, n_servers: int = 2, slo: float = None,
           **kw) -> Scenario:
    """Baseline: a fixed fleet under constant aggregate load."""
    return Scenario(
        name="steady", duration=duration, app=app, policy=policy, seed=seed,
        slo=slo,
        servers=tuple(ServerSpec(i) for i in range(n_servers)),
        events=[ClientArrival(0.0, qps / n_clients, count=n_clients)], **kw)


@register("flash-crowd")
def flash_crowd(*, duration: float = 45.0, seed: int = 0,
                app: str = "xapian", policy: str = "round_robin",
                base_qps: float = 600.0, peak_qps: float = 1800.0,
                burst_at: float = None, burst_len: float = None,
                slo: float = None, **kw) -> Scenario:
    """A viral traffic spike: 3x the offered load for a mid-run window
    (timing defaults scale with the duration override)."""
    burst_at = duration / 3 if burst_at is None else burst_at
    burst_len = duration / 4.5 if burst_len is None else burst_len
    return Scenario(
        name="flash-crowd", duration=duration, app=app, policy=policy,
        seed=seed, slo=slo,
        servers=(ServerSpec(0, workers=2), ServerSpec(1, workers=2)),
        events=[ClientArrival(0.0, base_qps / 3, count=3),
                FlashCrowd(burst_at, burst_len, peak_qps, clients=6)], **kw)


@register("diurnal-fleet")
def diurnal_fleet(*, duration: float = 60.0, seed: int = 0,
                  app: str = "xapian", policy: str = "jsq",
                  base_qps: float = 500.0, amplitude: float = 400.0,
                  period: float = None, slo: float = None, **kw) -> Scenario:
    """Day/night sinusoidal load with the fleet tracking it: two extra
    servers join for the daytime peak and drain for the night (one full
    day per run by default)."""
    period = duration if period is None else period
    return Scenario(
        name="diurnal-fleet", duration=duration, app=app, policy=policy,
        seed=seed, slo=slo,
        servers=(ServerSpec(0, workers=2), ServerSpec(1, workers=2)),
        events=[ClientArrival(0.0, DiurnalQPS(base_qps / 2, amplitude / 2,
                                              period=period), count=2),
                ServerJoin(period * 0.15, 2, workers=2),
                ServerJoin(period * 0.25, 3, workers=2),
                ServerDrain(period * 0.55, 2),
                ServerDrain(period * 0.65, 3)], **kw)


@register("server-failure")
def server_failure(*, duration: float = 45.0, seed: int = 0,
                   app: str = "xapian", policy: str = "jsq",
                   qps: float = 1200.0, fail_at: float = None,
                   recover_at: float = None, slo: float = None,
                   **kw) -> Scenario:
    """Fault injection: one of three servers dies mid-run (queued and
    in-flight requests lost, clients rebalance); a replacement joins."""
    fail_at = duration / 3 if fail_at is None else fail_at
    recover_at = duration * 2 / 3 if recover_at is None else recover_at
    return Scenario(
        name="server-failure", duration=duration, app=app, policy=policy,
        seed=seed, slo=slo,
        servers=tuple(ServerSpec(i) for i in range(3)),
        events=[ClientArrival(0.0, qps / 4, count=4),
                ServerFail(fail_at, 2),
                ServerJoin(recover_at, 3)], **kw)


@register("elastic-autoscale")
def elastic_autoscale(*, duration: float = 60.0, seed: int = 0,
                      app: str = "xapian", policy: str = "jsq",
                      slo: float = None, **kw) -> Scenario:
    """Load ramps 400 -> 1600 QPS and back; servers join as it rises and
    drain as it falls (the paper's elastic scale-out, as one scenario).
    All breakpoints scale with the duration override."""
    d = duration / 60.0
    half = PiecewiseQPS([(0, 200), (15 * d, 400), (25 * d, 800),
                         (40 * d, 400), (50 * d, 200)])   # per client, x2
    return Scenario(
        name="elastic-autoscale", duration=duration, app=app, policy=policy,
        seed=seed, slo=slo,
        servers=(ServerSpec(0, workers=2),),
        events=[ClientArrival(0.0, half, count=2),
                ServerJoin(14.0 * d, 1, workers=2),
                ServerJoin(24.0 * d, 2, workers=2),
                ServerDrain(42.0 * d, 2),
                ServerDrain(52.0 * d, 1)], **kw)


@register("batched-serving")
def batched_serving(*, duration: float = 30.0, seed: int = 0,
                    policy: str = "jsq", n_clients: int = 4,
                    qps: float = 150.0, n_servers: int = 2,
                    max_batch: int = 8, arch: str = None,
                    service=None, lengths=None, slo: float = None,
                    **kw) -> Scenario:
    """Continuous-batching inference fleet: BatchedService servers admit
    up to max_batch token-sized requests, per-step cost = max(compute,
    memory) from the roofline — throughput saturates sub-linearly with
    occupancy like the real engine, and the same scenario runs on the
    simulator, the batched stub engine, or real JAX engines."""
    if service is None:
        service = (BatchedService.from_arch(arch) if arch
                   else default_batched_service())
    if lengths is None:
        # bounded maxima keep the real-engine backend's cache sizing
        # (prompt_max + new_max tokens) practical
        lengths = TokenLengths(prompt_max=512, new_max=128)
    return Scenario(
        name="batched-serving", duration=duration, policy=policy, seed=seed,
        slo=slo, service_model=service, lengths=lengths,
        servers=tuple(ServerSpec(i, max_batch=max_batch)
                      for i in range(n_servers)),
        events=[ClientArrival(0.0, qps / n_clients, count=n_clients)], **kw)


@register("churn-storm")
def churn_storm(*, duration: float = 40.0, seed: int = 0,
                app: str = "masstree", policy: str = "load_aware",
                arrival_rate: float = 4.0, hold_mean: float = 3.0,
                client_qps: float = 120.0, slo: float = None,
                **kw) -> Scenario:
    """Heavy connection churn: a Poisson storm of short-lived clients on
    top of a small steady base, plus a mid-run policy change and a late
    hedging experiment — the balancer lifecycle under stress."""
    return Scenario(
        name="churn-storm", duration=duration, app=app, policy=policy,
        seed=seed, slo=slo,
        servers=tuple(ServerSpec(i) for i in range(3)),
        events=[ClientArrival(0.0, 200.0, count=2),
                ClientChurn(duration * 0.05, duration * 0.875,
                            arrival_rate, hold_mean, client_qps),
                SetPolicy(duration / 2, "jsq"),
                SetHedge(duration * 0.75, 0.02)], **kw)
