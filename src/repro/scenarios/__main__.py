"""One CLI for every canonical scenario, on either runtime backend.

    PYTHONPATH=src python -m repro.scenarios --list
    PYTHONPATH=src python -m repro.scenarios flash-crowd
    PYTHONPATH=src python -m repro.scenarios server-failure --backend engine --stub
    PYTHONPATH=src python -m repro.scenarios steady --backend engine \
        --arch phi3-mini-3.8b --smoke --replicas 2 --duration 5

``--backend sim`` (default) runs virtual-time; ``--backend engine``
drives the wall-clock runtime — against ``StubEngine`` replicas in
accelerated virtual time (``--stub``, the default) or against real JAX
``InferenceEngine`` replicas (``--arch ...``); ``--backend vector``
runs the batched array backend (statistically equivalent fast lane —
exact mode is ``sim``).
"""
from __future__ import annotations

import argparse
import sys

from repro import scenarios
from repro.core.runtime import EngineRuntime, VirtualClock, run_scenario


def _print_report(rt, scenario, backend: str) -> None:
    s = rt.telemetry.overall()
    print(f"scenario={scenario.name} backend={backend} "
          f"n={s.n} dropped={rt.dropped} mean={s.mean*1e3:.2f}ms "
          f"p50={s.p50*1e3:.2f}ms p95={s.p95*1e3:.2f}ms "
          f"p99={s.p99*1e3:.2f}ms")
    res = {m: int(getattr(rt, m, 0) or 0)
           for m in ("shed", "timeouts", "retries")}
    if any(res.values()):
        print(f"  resilience: shed={res['shed']} "
              f"timeouts={res['timeouts']} retries={res['retries']}")
    unsupported = getattr(rt, "unsupported", ())
    for inj in unsupported:
        print(f"  note: injection {inj.kind}@{inj.at:g}s not supported on "
              f"this backend (skipped)")
    print(f"{'t':>4} {'n':>7} {'qps':>9} {'p50ms':>8} {'p99ms':>9} "
          f"{'util':>5} {'qdepth':>6}  slo_viol")
    for r in rt.telemetry.to_rows():        # same aggregation as --csv
        viol = ("-" if r["slo_violation_frac"] != r["slo_violation_frac"]
                else f"{r['slo_violation_frac']:.3f}")
        print(f"{r['t']:4d} {r['n']:7d} {r['qps']:9.1f} {r['p50_ms']:8.2f} "
              f"{r['p99_ms']:9.2f} {r['mean_util']:5.2f} "
              f"{r['total_qdepth']:6d}  {viol}")


def _write_csv(rt, path: str) -> None:
    rows = rt.telemetry.to_rows()
    if not rows:
        return
    cols = list(rows[0])
    with open(path, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r[c]) for c in cols) + "\n")
    print(f"wrote {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.scenarios",
                                 description=__doc__)
    ap.add_argument("name", nargs="?", help="scenario name (see --list)")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--backend", default="sim",
                    choices=["sim", "engine", "vector"])
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--app", default=None)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--slo", type=float, default=None,
                    help="latency SLO in seconds (telemetry violation frac)")
    ap.add_argument("--csv", default=None, help="write interval frames here")
    # engine-backend options
    ap.add_argument("--stub", action="store_true",
                    help="engine backend: profile-timed StubEngine replicas "
                         "in virtual time (default when --arch is absent)")
    ap.add_argument("--arch", default=None,
                    help="engine backend: real JAX InferenceEngine replicas")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="engine backend: virtual->wall time stretch")
    # vector-backend options (all bit-preserving — see repro.vector)
    ap.add_argument("--vector-impl", default="auto",
                    choices=["auto", "ref", "pallas"],
                    help="vector backend: kernel impl (auto = Pallas on "
                         "TPU, jnp reference elsewhere)")
    ap.add_argument("--vector-backend", default="auto",
                    choices=["auto", "jax", "numpy"],
                    help="vector backend: array backend (auto = jax when "
                         "importable)")
    ap.add_argument("--vector-devices", type=int, default=0,
                    help="vector backend: shard cells over N local "
                         "devices (0 = all)")
    from repro.cache import add_cache_args, cache_from_args
    add_cache_args(ap)
    args = ap.parse_args(argv)

    if args.list or not args.name:
        print("canonical scenarios:")
        for n in scenarios.names():
            builder = scenarios.SCENARIOS[n]
            doc = (builder.__doc__ or "").strip().splitlines()[0]
            print(f"  {n:<18} {doc}")
        return 0

    # overrides go to the scenario *builder* so event times scale with them
    overrides = {k: v for k, v in (("duration", args.duration),
                                   ("app", args.app),
                                   ("policy", args.policy),
                                   ("slo", args.slo)) if v is not None}
    sc = scenarios.get(args.name, seed=args.seed, **overrides)

    cache = cache_from_args(args)
    if args.backend in ("sim", "vector"):
        vcfg = None
        if args.backend == "vector":
            from repro.vector import VectorConfig
            vcfg = VectorConfig(backend=args.vector_backend,
                                impl=args.vector_impl,
                                devices=args.vector_devices)
        rt = run_scenario(sc, args.backend, vector_config=vcfg, cache=cache)
    else:
        from repro.scenarios.backends import (build_stub_engines,
                                              run_experiment_on_real_engines)
        exp = sc.compile()
        if args.arch:
            rt = run_experiment_on_real_engines(
                exp, arch=args.arch, smoke=args.smoke,
                max_batch=args.max_batch, prompt_len=args.prompt_len,
                max_new_tokens=args.max_new, seed=args.seed,
                time_scale=args.time_scale)
        else:
            if args.time_scale != 1.0:
                # stub service times and recorded latencies are unscaled
                # profile seconds; stretching only the arrivals would
                # distort utilization and SLO accounting
                ap.error("--time-scale requires a real engine (--arch); "
                         "the stub backend runs in virtual time already")
            clock = VirtualClock()
            engines, factory = build_stub_engines(exp, clock, args.seed)
            rt = EngineRuntime.from_experiment(
                exp, engines, engine_factory=factory, clock=clock,
                sleep=clock.sleep)
            rt.run()

    _print_report(rt, sc, args.backend)
    if cache is not None:
        print(f"cache[{cache.cache_dir}] {cache.stats}")
    if args.csv:
        _write_csv(rt, args.csv)
    return 0


if __name__ == "__main__":
    sys.exit(main())
