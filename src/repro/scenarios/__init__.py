"""Named scenario registry.

Canonical dynamic scenarios (``repro.scenarios.canonical``) register
themselves here; ``get()`` builds one by name with optional overrides.

    from repro.scenarios import get, names
    sc = get("flash-crowd", duration=30.0, seed=3)

Run any of them from the command line on either backend:

    PYTHONPATH=src python -m repro.scenarios --list
    PYTHONPATH=src python -m repro.scenarios flash-crowd --backend sim
    PYTHONPATH=src python -m repro.scenarios flash-crowd --backend engine --stub
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.core.scenario import Scenario

SCENARIOS: Dict[str, Callable[..., Scenario]] = {}


def register(name: str):
    """Decorator: register a ``(**overrides) -> Scenario`` builder."""
    def deco(fn):
        SCENARIOS[name] = fn
        fn.scenario_name = name
        return fn
    return deco


def names() -> list[str]:
    return sorted(SCENARIOS)


def get(name: str, **overrides) -> Scenario:
    try:
        builder = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; available: {names()}") \
            from None
    return builder(**overrides)


from repro.scenarios import canonical as _canonical  # noqa: E402,F401  (registers)
from repro.scenarios import chaos as _chaos  # noqa: E402,F401  (registers)
