"""Chaos scenarios: correlated failures, gray failure, retry storms,
and closed-loop autoscaling under a flash crowd.

These extend the canonical registry with the failure modes that
resilience machinery exists for (the "Metastable Failures in
Distributed Systems" playbook): a retry storm that keeps a fleet
saturated after the original overload has passed, a rack-level
correlated failure, a gray-failing server that is slow but not dead,
and a reactive controller riding out a flash crowd on a standby pool.
All are deterministic functions of their seed and run on every backend
the capability matrix admits.
"""
from __future__ import annotations

from repro.control import BreakerSpec, ControlSpec, RetryPolicy
from repro.core.harness import ServerSpec
from repro.core.scenario import (ClientArrival, CorrelatedFailure,
                                 FlashCrowd, Scenario, ServerJoin,
                                 ServerSlowdown)
from repro.scenarios import register


@register("retry-storm")
def retry_storm(*, duration: float = 30.0, seed: int = 0,
                app: str = "xapian", policy: str = "jsq",
                qps: float = 1400.0, burst_qps: float = 2800.0,
                mode: str = "naive", timeout: float = 0.25,
                max_retries: int = 3, burst_at: float = None,
                burst_len: float = None, slo: float = 0.25,
                **kw) -> Scenario:
    """A transient overload burst under aggressive client timeouts.

    ``mode="naive"`` retries immediately with no jitter and no budget —
    every timeout adds offered load while the server still holds the
    zombie request, the classic metastable feedback loop.
    ``mode="backoff"`` uses capped exponential backoff with
    decorrelated jitter and a 10% retry budget; the same trigger then
    drains instead of amplifying.  The trigger is a flash-crowd burst
    (not a slowdown) so the storm reproduces on every backend.
    """
    if mode == "naive":
        retry = RetryPolicy(timeout=timeout, max_retries=max_retries,
                            backoff_base=0.0, backoff_cap=0.0,
                            jitter="none", budget_ratio=1.0,
                            budget_burst=10 ** 9)
    elif mode == "backoff":
        retry = RetryPolicy(timeout=timeout, max_retries=max_retries,
                            backoff_base=0.05, backoff_cap=1.0,
                            jitter="decorrelated", budget_ratio=0.1,
                            budget_burst=20)
    else:
        raise ValueError(f"unknown retry-storm mode {mode!r} "
                         f"(naive | backoff)")
    burst_at = duration / 3 if burst_at is None else burst_at
    burst_len = duration / 6 if burst_len is None else burst_len
    return Scenario(
        name="retry-storm", duration=duration, app=app, policy=policy,
        seed=seed, slo=slo, retry=retry,
        servers=(ServerSpec(0, workers=2), ServerSpec(1, workers=2)),
        events=[ClientArrival(0.0, qps / 4, count=4),
                FlashCrowd(burst_at, burst_len, burst_qps,
                           clients=4)], **kw)


@register("correlated-failure")
def correlated_failure(*, duration: float = 40.0, seed: int = 0,
                       app: str = "xapian", policy: str = "jsq",
                       qps: float = 1200.0, fail_at: float = None,
                       recover_at: float = None, slo: float = 0.25,
                       **kw) -> Scenario:
    """Shared-rack failure: two of four servers die at the SAME instant
    (lowered to same-timestamp injections, applied in declaration
    order), then rejoin later as replacements."""
    fail_at = duration / 3 if fail_at is None else fail_at
    recover_at = duration * 2 / 3 if recover_at is None else recover_at
    return Scenario(
        name="correlated-failure", duration=duration, app=app,
        policy=policy, seed=seed, slo=slo,
        servers=tuple(ServerSpec(i) for i in range(4)),
        events=[ClientArrival(0.0, qps / 4, count=4),
                CorrelatedFailure(fail_at, (2, 3)),
                ServerJoin(recover_at, 4),
                ServerJoin(recover_at, 5)], **kw)


@register("gray-failure")
def gray_failure(*, duration: float = 30.0, seed: int = 0,
                 app: str = "xapian", policy: str = "round_robin",
                 qps: float = 900.0, factor: float = 20.0,
                 slow_at: float = None, slow_len: float = None,
                 breaker: bool = False, slo: float = 0.25,
                 **kw) -> Scenario:
    """Gray failure ("Gray Failure: The Achilles' Heel of Cloud-Scale
    Systems"): a server turns pathologically slow but keeps accepting —
    health checks pass, tails explode.  With ``breaker=True`` a
    timeout + circuit breaker pair detects it from the client side and
    routes around it.  Round-robin balancing by default — a
    queue-aware policy (jsq) would mask the gray server on its own,
    which is exactly the contrast worth measuring."""
    slow_at = duration / 3 if slow_at is None else slow_at
    slow_len = duration / 3 if slow_len is None else slow_len
    retry = (RetryPolicy(timeout=0.3, max_retries=1, backoff_base=0.02,
                         backoff_cap=0.2, jitter="full",
                         budget_ratio=0.2, budget_burst=10)
             if breaker else None)
    brk = (BreakerSpec(window=20, threshold=0.5, cooldown=3.0,
                       min_samples=5) if breaker else None)
    return Scenario(
        name="gray-failure", duration=duration, app=app, policy=policy,
        seed=seed, slo=slo, retry=retry, breaker=brk,
        servers=tuple(ServerSpec(i) for i in range(3)),
        events=[ClientArrival(0.0, qps / 3, count=3),
                ServerSlowdown(slow_at, 2, factor,
                               until=slow_at + slow_len)], **kw)


@register("flash-crowd-autoscale")
def flash_crowd_autoscale(*, duration: float = 45.0, seed: int = 0,
                          app: str = "xapian", policy: str = "jsq",
                          base_qps: float = 600.0,
                          peak_qps: float = 2400.0,
                          controller: str = "threshold_autoscaler",
                          interval: float = 1.0, lag: float = 2.0,
                          cooldown: float = 4.0, slo: float = 0.25,
                          **kw) -> Scenario:
    """The flash-crowd spike with a closed loop on top: 2 active + 4
    standby servers and a reactive controller (autoscaler by default,
    ``controller="admission_shedder"`` for brownout-style shedding)
    observing windowed telemetry and actuating with lag + cooldown."""
    if controller == "threshold_autoscaler":
        ctrl = ControlSpec.make("threshold_autoscaler", interval=interval,
                                lag=lag, cooldown=cooldown,
                                high=0.85, low=0.35, metric="util",
                                min_servers=2, max_servers=6)
    elif controller == "admission_shedder":
        ctrl = ControlSpec.make("admission_shedder", interval=interval,
                                lag=lag, cooldown=cooldown,
                                target_qdepth=8.0)
    else:
        raise ValueError(f"unknown controller {controller!r} "
                         f"(threshold_autoscaler | admission_shedder)")
    burst_at, burst_len = duration / 3, duration / 4.5
    servers = tuple(ServerSpec(i, workers=2) for i in range(2)) + \
        tuple(ServerSpec(i, workers=2, standby=True) for i in range(2, 6))
    return Scenario(
        name="flash-crowd-autoscale", duration=duration, app=app,
        policy=policy, seed=seed, slo=slo, control=ctrl, servers=servers,
        events=[ClientArrival(0.0, base_qps / 3, count=3),
                FlashCrowd(burst_at, burst_len, peak_qps, clients=6)], **kw)
