"""Gradient-based capacity planning: a handful of Adam steps through
the smoothed surrogate replaces the dense provisioning grid.

``run_plan`` drives ``jax.value_and_grad(plan_loss)`` with the repo's
own AdamW (``repro.training.optimizer``) under box-constraint
projection and deterministic multi-start, then — because the surrogate
is never trusted alone — rounds the continuous capacity to an integer
fleet and walks a short probe ladder on the EXACT (non-soft) vector
runtime: a few repetitions per candidate decide the smallest integer
fleet meeting the target, and the final answer is re-measured at full
repetition count.  Every exact cell is counted; ``PlanResult.cell_evals``
is the honest number a dense grid sweep gets compared against
(``benchmarks/bench_plan.py``).

``run_plan_sweep`` adapts a ``mode="optimize"`` sweep spec onto the
same driver so planner runs flow through the existing ResultFrame /
CSV / artifact machinery.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.plan.model import (OBJECTIVES, PlanConfig, PlanData, PlanError,
                              build_plan_data, hard_metrics, plan_loss)

#: default (init, lo, hi) box per learnable parameter
DEFAULT_BOXES = {
    "capacity": (4.0, 1.0, 32.0),
    "hedge_delay": (0.05, 1e-4, 1.0),
    "admit": (1.0, 0.1, 1.0),
    "scale_threshold": (0.7, 0.05, 2.0),
}


@dataclass
class PlanSpec:
    """One planning problem: a scenario, an objective, and the box of
    learnable parameters."""
    scenario: str = "steady"
    objective: str = "p99"              # one of OBJECTIVES
    slo: float = 0.02
    target: Optional[float] = None      # default: slo (0.05 for slo_frac)
    overrides: dict = field(default_factory=dict)
    params: dict = field(default_factory=lambda:
                         {"capacity": DEFAULT_BOXES["capacity"]})
    autoscale: Optional[tuple] = None   # (base, extra) servers
    steps: int = 150
    starts: int = 3
    lr: float = 0.15
    schedule: str = "cosine"            # cosine | constant
    seed: int = 0
    dt: float = 0.005
    samples: int = 16384
    tau: float = 0.05
    band_frac: float = 2e-3
    penalty: float = 25.0
    cost_weight: float = 1.0
    reps: int = 13                      # final-answer verification reps
    probe_reps: int = 5                 # ladder-probe reps
    verify: bool = True

    def config(self) -> PlanConfig:
        return PlanConfig(tau=self.tau, band_frac=self.band_frac,
                          penalty=self.penalty,
                          cost_weight=self.cost_weight)


@dataclass
class PlanResult:
    """Everything one planning run produced."""
    spec: dict
    pooled: bool
    n_ref: float
    starts: list                        # per-start {params, loss, history}
    best_start: int
    params: dict                        # best continuous parameters
    surrogate: dict                     # smoothed metrics at the optimum
    hard: dict                          # hard-twin metrics at the optimum
    n_star: Optional[int] = None        # verified integer fleet
    verified: Optional[dict] = None     # exact-runtime measurement
    probes: list = field(default_factory=list)
    cell_evals: int = 0                 # exact vector cells consumed
    feasible: bool = True

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _metric_of(result, objective: str) -> float:
    """Extract the objective metric from one exact VectorResult."""
    if objective == "slo_frac":
        from repro.vector import VectorTelemetry
        return float(VectorTelemetry(result).slo_frac())
    return float(getattr(result, objective))


def _mean_ci95(vals) -> tuple:
    vals = np.asarray(vals, float)
    m = float(vals.mean())
    if vals.size < 2:
        return m, float("nan")
    return m, float(1.96 * vals.std(ddof=1) / np.sqrt(vals.size))


class _ExactEvaluator:
    """Runs integer fleet candidates on the exact vector runtime and
    counts every cell.  One compile per candidate; repetitions differ
    only in their (seed, stream) pairs, derived through the sweep
    machinery's SeedSequence spawn tree."""

    def __init__(self, spec: PlanSpec, vector_config=None, cache=None):
        from repro.vector import VectorConfig
        self.spec = spec
        base = vector_config or VectorConfig()
        if base.soft:
            raise PlanError("verification must run the exact runtime "
                            "(vector_config.soft must be False)")
        self.cfg = dataclasses.replace(base, dt=spec.dt)
        self.cells = 0
        self._progs: dict = {}
        # content-addressed reuse: cells repeat across the ladder's
        # multi-start restarts (memory LRU) and across runs (disk) —
        # e.g. a planner run after a dense sweep of the same scenario
        # finds nearly every cell already stored.  Cells served from
        # the cache are NOT counted: ``cells`` is genuinely new work.
        self.cache = cache

    def _program(self, n: int):
        from repro.scenarios import get
        prog = self._progs.get(n)
        if prog is None:
            try:
                sc = get(self.spec.scenario, seed=int(self.spec.seed),
                         slo=self.spec.slo,
                         **{**self.spec.overrides, "n_servers": int(n)})
            except TypeError as e:
                raise PlanError(
                    f"scenario {self.spec.scenario!r} does not accept an "
                    f"n_servers override — exact capacity verification "
                    f"needs one ({e})") from e
            from repro.vector import compile_experiment
            prog = compile_experiment(sc.compile(), dt=self.spec.dt)
            self._progs[n] = prog
        return prog

    def measure(self, n: int, reps: int) -> list:
        """-> objective-metric value per repetition (exact runtime)."""
        from repro.sweep.spec import spawn_seed
        from repro.vector import run_cells
        prog = self._program(n)
        seeds = [(spawn_seed(self.spec.seed, int(n), rep), rep)
                 for rep in range(reps)]
        if self.cache is None:
            results = run_cells([prog] * reps, seeds, self.cfg)
            self.cells += reps
        else:
            before = self.cache.stats.hits
            results = run_cells([prog] * reps, seeds, self.cfg,
                                cache=self.cache)
            self.cells += reps - (self.cache.stats.hits - before)
        return [_metric_of(r, self.spec.objective) for r in results]


def _spread_inits(box: tuple, start: int, starts: int) -> float:
    """Deterministic multi-start: start 0 takes the declared init, the
    rest spread evenly over the box interior."""
    init, lo, hi = box
    if start == 0:
        return float(init)
    frac = (2 * start + 1) / (2.0 * starts)
    return float(lo + frac * (hi - lo))


def run_plan(spec: PlanSpec, *,
             progress: Optional[Callable[[str], None]] = None,
             vector_config=None, cache=None) -> PlanResult:
    """Execute one planning problem end to end: multi-start Adam on the
    smoothed surrogate, then integer rounding verified on the exact
    vector runtime.

    ``cache`` (a ``repro.cache.ResultCache``) lets the exact ladder
    reuse cells within the run and across runs; ``cell_evals`` then
    counts only cells that were actually computed."""
    from repro.vector import has_jax
    if not has_jax():
        raise PlanError("repro.plan needs jax (the surrogate is "
                        "differentiated with jax.value_and_grad)")
    import jax
    import jax.numpy as jnp

    from repro.training.optimizer import (OptConfig, adamw_update,
                                          init_opt_state)

    if spec.objective not in OBJECTIVES:
        raise PlanError(f"unknown objective {spec.objective!r}")
    if not spec.params:
        raise PlanError("no learnable parameters declared")
    boxes = {}
    for name, box in spec.params.items():
        if name not in DEFAULT_BOXES:
            raise PlanError(f"unknown parameter {name!r}; "
                            f"one of {sorted(DEFAULT_BOXES)}")
        boxes[name] = tuple(float(v) for v in (
            box if box is not None else DEFAULT_BOXES[name]))
    if "scale_threshold" in boxes and spec.autoscale is None:
        raise PlanError("scale_threshold needs autoscale=(base, extra)")

    def note(msg: str) -> None:
        if progress is not None:
            progress(msg)

    data = build_plan_data(
        spec.scenario, slo=spec.slo, objective=spec.objective,
        target=spec.target, overrides=spec.overrides,
        autoscale=spec.autoscale, seed=spec.seed, dt=spec.dt,
        samples=spec.samples)
    cfg = spec.config()

    def _loss(p):
        return plan_loss(p, data, cfg)

    vg = jax.jit(jax.value_and_grad(_loss, has_aux=True))
    opt_cfg = OptConfig(lr=spec.lr, weight_decay=0.0, grad_clip=5.0,
                        warmup_steps=max(2, spec.steps // 20),
                        total_steps=spec.steps, m_dtype="float32",
                        schedule=spec.schedule)
    lo = {k: b[1] for k, b in boxes.items()}
    hi = {k: b[2] for k, b in boxes.items()}

    start_rows = []
    for s in range(spec.starts):
        params = {k: jnp.asarray(_spread_inits(boxes[k], s, spec.starts),
                                 jnp.float32) for k in boxes}
        state = init_opt_state(params, opt_cfg)
        history = []
        for _ in range(spec.steps):
            (val, _aux), grads = vg(params)
            params, state, _m = adamw_update(params, grads, state, opt_cfg)
            params = {k: jnp.clip(v, lo[k], hi[k])
                      for k, v in params.items()}
            history.append(float(val))
        (val, aux), _ = vg(params)
        start_rows.append({
            "params": {k: float(v) for k, v in params.items()},
            "loss": float(val),
            "metrics": {k: float(v) for k, v in aux.items()},
            "history": history,
        })
        note(f"plan[{spec.scenario}] start {s}: loss={float(val):.4f} "
             f"params={start_rows[-1]['params']}")

    best = int(np.argmin([r["loss"] for r in start_rows]))
    best_params = dict(start_rows[best]["params"])
    result = PlanResult(
        spec={**dataclasses.asdict(spec), "target": data.target,
              "params": {k: list(v) for k, v in boxes.items()}},
        pooled=data.pooled, n_ref=data.n_ref,
        starts=start_rows, best_start=best, params=best_params,
        surrogate=start_rows[best]["metrics"],
        hard=hard_metrics(best_params, data, cfg))

    if not (spec.verify and "capacity" in best_params):
        return result

    # ---- integer rounding + exact-runtime ladder ---------------------------
    ev = _ExactEvaluator(spec, vector_config=vector_config, cache=cache)
    lo_n = int(np.ceil(lo["capacity"]))
    hi_n = int(np.floor(hi["capacity"]))
    n = int(np.clip(round(best_params["capacity"]), lo_n, hi_n))

    def probe(k: int) -> bool:
        vals = ev.measure(k, spec.probe_reps)
        mean, ci = _mean_ci95(vals)
        ok = mean <= data.target
        result.probes.append({"n": k, "mean": mean, "ci95": ci,
                              "reps": spec.probe_reps, "meets": ok})
        note(f"plan[{spec.scenario}] probe n={k}: "
             f"{spec.objective}={mean:.4g} "
             f"({'meets' if ok else 'misses'} {data.target:.4g})")
        return ok

    if probe(n):
        while n > lo_n and probe(n - 1):
            n -= 1
    else:
        while n < hi_n:
            n += 1
            if probe(n):
                break
    vals = ev.measure(n, spec.reps)
    mean, ci = _mean_ci95(vals)
    result.n_star = n
    result.feasible = bool(mean <= data.target or
                           mean - ci <= data.target)
    result.verified = {"n": n, "metric": spec.objective, "values": vals,
                       "mean": mean, "ci95": ci, "reps": spec.reps,
                       "target": data.target}
    result.cell_evals = ev.cells
    note(f"plan[{spec.scenario}] verified n={n}: "
         f"{spec.objective}={mean:.4g} +- {ci:.4g} "
         f"({ev.cells} exact cells)")
    return result


# ---------------------------------------------------------------------------
# Sweep integration (mode="optimize")
# ---------------------------------------------------------------------------
#: PlanSpec fields a sweep's ``optimize`` block may set
_OPTIMIZE_KEYS = ("scenario", "objective", "slo", "target", "params",
                  "autoscale", "steps", "starts", "lr", "schedule",
                  "dt", "samples", "tau", "band_frac", "penalty",
                  "cost_weight", "probe_reps", "verify")


def plan_spec_from_sweep(sweep) -> PlanSpec:
    """Lower a ``mode="optimize"`` sweep onto a ``PlanSpec``: the
    ``optimize`` block carries the planner knobs, ``fixed`` becomes the
    scenario overrides, and reps/base_seed keep their sweep meanings."""
    opt = dict(sweep.optimize or {})
    unknown = set(opt) - set(_OPTIMIZE_KEYS)
    if unknown:
        raise PlanError(f"unknown optimize keys: {sorted(unknown)}; "
                        f"known: {sorted(_OPTIMIZE_KEYS)}")
    if "slo" not in opt:
        raise PlanError("optimize block needs an 'slo'")
    params = opt.pop("params", None)
    if params is not None:
        params = {k: (tuple(v) if v is not None else None)
                  for k, v in params.items()}
        opt["params"] = params
    autoscale = opt.pop("autoscale", None)
    if autoscale is not None:
        opt["autoscale"] = tuple(autoscale)
    return PlanSpec(scenario=opt.pop("scenario", sweep.name),
                    overrides=dict(sweep.fixed), seed=sweep.base_seed,
                    reps=sweep.reps, **opt)


def run_plan_sweep(sweep, *,
                   progress: Optional[Callable[[str], None]] = None,
                   vector_config=None, cache=None):
    """Execute a ``mode="optimize"`` sweep -> ``ResultFrame`` whose rows
    are phase-tagged: one row per optimizer start, one per exact-ladder
    probe, and one final verified row — so planner runs archive through
    the same CSV/artifact machinery as grid sweeps."""
    from repro.sweep.results import ResultFrame, SweepRow

    spec = plan_spec_from_sweep(sweep)
    res = run_plan(spec, progress=progress, vector_config=vector_config,
                   cache=cache)
    rows = []
    for s, row in enumerate(res.starts):
        rows.append(SweepRow(
            index=0, params={"phase": "optimize", "start": s,
                             **row["params"]},
            rep=s, seed=sweep.base_seed, stream=0,
            metrics={"loss": row["loss"], **row["metrics"]}))
    for i, p in enumerate(res.probes):
        rows.append(SweepRow(
            index=1, params={"phase": "probe", "n_servers": p["n"]},
            rep=i, seed=sweep.base_seed, stream=0,
            metrics={spec.objective: p["mean"], "ci95": p["ci95"],
                     "meets": float(p["meets"])}))
    if res.verified is not None:
        rows.append(SweepRow(
            index=2, params={"phase": "final",
                             "n_servers": res.n_star},
            rep=0, seed=sweep.base_seed, stream=0,
            metrics={spec.objective: res.verified["mean"],
                     "ci95": res.verified["ci95"],
                     "cell_evals": float(res.cell_evals),
                     "feasible": float(res.feasible)}))
    frame = ResultFrame(name=sweep.name,
                        spec={**sweep.describe(), "plan": res.to_dict()},
                        rows=rows)
    return frame
