"""The planner's objective model: a differentiable surrogate of one
scenario's tail-latency law, plus its hard (tau -> 0) twin.

``build_plan_data`` compiles a canonical scenario onto the vector
runtime's array program and freezes everything the optimizer loop does
NOT differentiate through: the offered-load schedule, the service-law
moments, and one reparameterized batch of per-request draws (arrival
slot, service demand, queue-indicator uniform, conditional-wait
exponential, and a hedge twin of each).  ``surrogate_metrics`` then
maps continuous provisioning parameters to smoothed p50/p95/p99 /
SLO-violation metrics through ``repro.vector.soft`` primitives — every
step differentiable, so ``jax.value_and_grad(plan_loss)`` is the whole
planner gradient.

The surrogate deliberately models a HOMOGENEOUS fleet at nominal speed
(capacity = x * mean workers-per-server): scenarios with speed or
failure schedules still optimize on nominal capacity and rely on the
exact-runtime verification ladder for the final answer — the contract
everywhere in ``repro.plan`` is that the surrogate proposes and the
exact vector runtime decides.

Learnable parameters (any subset, each a scalar):

* ``capacity``        — server count relaxed to continuous fleet size;
* ``hedge_delay``     — request-hedging delay (seconds);
* ``admit``           — per-class admission fraction in [0, 1];
* ``scale_threshold`` — autoscale trigger (utilization of the base
  fleet at which ``autoscale=(base, extra)`` spins up the extras).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.stats import quantiles_partition
from repro.vector.compile import compile_experiment
from repro.vector.soft import RHO_MAX, smooth_min, smooth_rho, soft_erlang_c

_EPS = 1e-12

#: metrics a plan objective may target
OBJECTIVES = ("p50", "p95", "p99", "mean", "slo_frac")

#: quantile order shared with the vector runtime's extraction head
PLAN_QS = (50.0, 95.0, 99.0)


class PlanError(ValueError):
    """The scenario/spec cannot be lowered onto the planner's model."""


@dataclass(frozen=True)
class PlanConfig:
    """Smoothing and loss-shaping knobs (NOT learnable)."""
    tau: float = 0.05           # shared relaxation temperature
    band_frac: float = 2e-3     # soft-quantile kernel bandwidth fraction
    cmax: int = 64              # Erlang-C truncation (matches runtime)
    penalty: float = 25.0       # SLO-violation softplus weight
    slo_scale: float = 0.05     # softplus width, as a fraction of target
    cost_weight: float = 1.0    # fleet-size cost weight
    reject_weight: float = 2.0  # admission-rejection cost weight
    hedge_weight: float = 0.5   # duplicate-load cost weight


@dataclass
class PlanData:
    """Frozen scenario data the surrogate closes over."""
    scenario: str
    objective: str
    slo: float
    target: float               # threshold for the chosen objective
    duration: float
    dt: float
    pooled: bool                # request-level routing -> pooled Erlang
    unit_c: float               # concurrency slots per server
    n_ref: float                # scenario's reference server count
    m_bar: float                # E[service work] (noise folded in)
    e2_bar: float               # E[work^2]
    lam: np.ndarray             # [T] offered QPS per slot
    centers: np.ndarray         # [T] slot centers (s)
    scale_base: float = 0.0     # autoscale base fleet (servers)
    scale_extra: float = 0.0    # autoscale extra fleet (servers)
    # one reparameterized draw batch (primary + hedge twin)
    ts: np.ndarray = None       # [K] arrival slot index
    svc: np.ndarray = None      # [K] service demand (s)
    u: np.ndarray = None        # [K] queue-indicator uniform
    g: np.ndarray = None        # [K] conditional-wait exponential
    svc2: np.ndarray = None
    u2: np.ndarray = None
    g2: np.ndarray = None


def build_plan_data(scenario: str, *, slo: float, objective: str = "p99",
                    target: Optional[float] = None, overrides=None,
                    autoscale=None, seed: int = 0, dt: float = 0.005,
                    samples: int = 16384) -> PlanData:
    """Compile ``scenario`` and freeze the surrogate's inputs.

    The draw batch is reparameterized: gradients flow through the
    deterministic map from parameters to latency at FIXED noise, so
    every optimizer step sees the same stochastic landscape (no
    gradient-through-sampling estimators needed).
    """
    from repro.scenarios import get

    if objective not in OBJECTIVES:
        raise PlanError(f"unknown objective {objective!r}; "
                        f"one of {OBJECTIVES}")
    if not slo or slo <= 0.0:
        raise PlanError("capacity planning needs a positive SLO")
    exp = get(scenario, seed=int(seed), **dict(overrides or {})).compile()
    prog = compile_experiment(exp, dt=dt)
    if prog.batched:
        raise PlanError("the surrogate models scalar service laws only "
                        "(batched serving has no smoothed law yet)")
    lam = prog.rate_conn.sum(axis=1) + prog.rate_free
    if float(lam.sum()) * dt <= 0.0:
        raise PlanError(f"scenario {scenario!r} offers no load")
    # fold the mean multiplicative execution-noise factor into demand
    nf1 = float(np.mean(np.exp(prog.noise_sigma ** 2 / 2.0)))
    m_bar = float(np.mean(prog.work_mean))
    e2_bar = float(np.mean(prog.work_var + prog.work_mean ** 2))
    centers = (np.arange(prog.n_slots) + 0.5) * dt

    rng = np.random.default_rng((0x9A71, int(seed), 0))
    w = np.maximum(lam, 0.0) * dt
    cum = np.cumsum(w)
    K = int(samples)
    ts = np.searchsorted(cum, rng.random(K) * cum[-1], side="right")
    ts = np.minimum(ts, prog.n_slots - 1).astype(np.int64)
    svc = prog.profile.sample_batch(rng, K) * nf1
    u = rng.random(K)
    g = rng.standard_exponential(K)
    svc2 = prog.profile.sample_batch(rng, K) * nf1
    u2 = rng.random(K)
    g2 = rng.standard_exponential(K)

    if target is None:
        target = 0.05 if objective == "slo_frac" else float(slo)
    base, extra = (0.0, 0.0) if autoscale is None \
        else (float(autoscale[0]), float(autoscale[1]))
    return PlanData(
        scenario=scenario, objective=objective, slo=float(slo),
        target=float(target), duration=prog.duration, dt=dt,
        pooled=bool(prog.rate_free.sum() > 0.0),
        unit_c=float(prog.workers.mean()), n_ref=float(prog.n_servers),
        m_bar=m_bar, e2_bar=e2_bar, lam=lam, centers=centers,
        scale_base=base, scale_extra=extra,
        ts=ts, svc=svc, u=u, g=g, svc2=svc2, u2=u2, g2=g2)


# ---------------------------------------------------------------------------
# Smoothed forward pass (jax)
# ---------------------------------------------------------------------------
def _capacity_profile(xp, params, data: PlanData, cfg: PlanConfig, lam):
    """[T] fleet capacity (work-seconds per second) from the learnable
    parameters — constant for a ``capacity`` plan, load-tracking for an
    autoscale-threshold plan."""
    thr = params.get("scale_threshold")
    if thr is not None:
        base = data.scale_base * data.unit_c
        extra = data.scale_extra * data.unit_c
        from repro.vector.soft import stable_sigmoid
        util = lam * data.m_bar / max(base, _EPS)
        return base + extra * stable_sigmoid(xp, (util - thr) / cfg.tau)
    return params["capacity"] * data.unit_c + 0.0 * lam


def surrogate_metrics(params: dict, data: PlanData,
                      cfg: PlanConfig) -> dict:
    """Smoothed metrics as jnp scalars — fully differentiable in every
    entry of ``params``.  Keys: p50/p95/p99/mean/slo_frac plus the
    fleet/rho diagnostics the loss and reports consume."""
    import jax
    import jax.numpy as jnp

    from repro.vector.soft import soft_quantiles, stable_sigmoid

    lam = jnp.asarray(data.lam)
    admit = params.get("admit")
    if admit is not None:
        lam = lam * jnp.clip(admit, 0.0, 1.0)
    cap = _capacity_profile(jnp, params, data, cfg, lam)
    dtype = jnp.result_type(lam.dtype, cap.dtype)
    work = (lam * data.m_bar).astype(dtype)
    cap = cap.astype(dtype)
    rho = smooth_rho(jnp, work / jnp.maximum(cap, _EPS), cfg.tau)
    if data.pooled:
        c_eff = smooth_min(jnp, cap, float(cfg.cmax),
                           cfg.tau * cfg.cmax)
        cap_wait = cap
    else:
        c_one = min(data.unit_c, float(cfg.cmax))
        c_eff = jnp.full_like(cap, c_one)
        cap_wait = jnp.full_like(cap, data.unit_c)
    pC = soft_erlang_c(jnp, c_eff, rho, cfg.cmax, cfg.tau)
    resid = data.e2_bar / (2.0 * data.m_bar)
    w_cond = resid / jnp.maximum(cap_wait * (1.0 - rho), _EPS)

    def _backlog(carry, xs):
        w_in, cp = xs
        u_next = jnp.maximum(carry + (w_in - cp) * data.dt, 0.0)
        return u_next, u_next

    _, U = jax.lax.scan(_backlog, jnp.zeros((), dtype), (work, cap))
    wait_fluid = U / jnp.maximum(cap, _EPS)

    ts = jnp.asarray(data.ts)
    lat = (wait_fluid[ts]
           + stable_sigmoid(jnp, (pC[ts] - jnp.asarray(data.u)) / cfg.tau)
           * jnp.asarray(data.g) * w_cond[ts]
           + jnp.asarray(data.svc)).astype(dtype)
    hedge = params.get("hedge_delay")
    dup_frac = jnp.zeros((), dtype)
    if hedge is not None:
        lat2 = (wait_fluid[ts]
                + stable_sigmoid(jnp,
                                 (pC[ts] - jnp.asarray(data.u2)) / cfg.tau)
                * jnp.asarray(data.g2) * w_cond[ts]
                + jnp.asarray(data.svc2)).astype(dtype)
        dup_frac = jnp.mean(stable_sigmoid(
            jnp, (lat - hedge) / (cfg.tau * data.m_bar + _EPS)))
        lat = smooth_min(jnp, lat, hedge + lat2,
                         cfg.tau * data.m_bar + _EPS)
    arrive = jnp.asarray(data.centers)[ts].astype(dtype)
    w_keep = stable_sigmoid(
        jnp, (data.duration - (arrive + lat)) / (4.0 * data.dt))
    qs = soft_quantiles(lat[None, :], w_keep[None, :], qs=PLAN_QS,
                        band_frac=cfg.band_frac)[0]
    n_eff = jnp.maximum(jnp.sum(w_keep), _EPS)
    mean = jnp.sum(w_keep * lat) / n_eff
    width = cfg.slo_scale * data.slo
    slo_frac = jnp.sum(
        w_keep * stable_sigmoid(jnp, (lat - data.slo) / width)) / n_eff
    return {"p50": qs[0], "p95": qs[1], "p99": qs[2], "mean": mean,
            "slo_frac": slo_frac, "n_eff": n_eff,
            "fleet": jnp.mean(cap) / data.unit_c,
            "rho_max": jnp.max(rho), "dup_frac": dup_frac}


def plan_loss(params: dict, data: PlanData, cfg: PlanConfig):
    """Scalar planning loss -> ``(loss, metrics)``: provisioning cost
    plus a softplus barrier on the objective metric exceeding its
    target.  Shaped so the minimum sits where the metric just meets the
    target — the cost term supplies the downward pressure the barrier
    pushes back against."""
    import jax.numpy as jnp

    from repro.vector.soft import softplus

    m = surrogate_metrics(params, data, cfg)
    scale = cfg.slo_scale * max(data.target, 1e-6)
    over = softplus(jnp, (m[data.objective] - data.target) / scale)
    cost = cfg.cost_weight * m["fleet"] / data.n_ref
    admit = params.get("admit")
    if admit is not None:
        cost = cost + cfg.reject_weight * (1.0 - jnp.clip(admit, 0.0, 1.0))
    if "hedge_delay" in params:
        cost = cost + cfg.hedge_weight * m["dup_frac"]
    return cost + cfg.penalty * over, m


# ---------------------------------------------------------------------------
# Hard twin (numpy) + the analytic oracle
# ---------------------------------------------------------------------------
_HARD_TAU = 1e-4


def hard_metrics(params: dict, data: PlanData,
                 cfg: Optional[PlanConfig] = None) -> dict:
    """The same sample model with HARD operators (the tau -> 0 limit of
    ``surrogate_metrics``): hard Bernoulli queue indicator, clipped
    utilization, exact percentile extraction, hard censoring.  NumPy,
    cheap, and the reference the finite-difference/agreement tests and
    the analytic bisection oracle run against."""
    cfg = cfg or PlanConfig()
    lam = np.asarray(data.lam, float)
    admit = params.get("admit")
    if admit is not None:
        lam = lam * np.clip(float(admit), 0.0, 1.0)
    thr = params.get("scale_threshold")
    if thr is not None:
        base = data.scale_base * data.unit_c
        extra = data.scale_extra * data.unit_c
        util = lam * data.m_bar / max(base, _EPS)
        cap = base + extra * (util > float(thr))
    else:
        cap = float(params["capacity"]) * data.unit_c + 0.0 * lam
    work = lam * data.m_bar
    rho = np.clip(work / np.maximum(cap, _EPS), 1e-9, RHO_MAX)
    if data.pooled:
        c_eff = np.minimum(cap, float(cfg.cmax))
        cap_wait = cap
    else:
        c_eff = np.full_like(cap, min(data.unit_c, float(cfg.cmax)))
        cap_wait = np.full_like(cap, data.unit_c)
    pC = soft_erlang_c(np, c_eff, rho, cfg.cmax, _HARD_TAU)
    resid = data.e2_bar / (2.0 * data.m_bar)
    w_cond = resid / np.maximum(cap_wait * (1.0 - rho), _EPS)
    U = np.zeros_like(work)
    acc = 0.0
    for t in range(work.size):
        acc = max(acc + (work[t] - cap[t]) * data.dt, 0.0)
        U[t] = acc
    wait_fluid = U / np.maximum(cap, _EPS)

    ts = data.ts
    lat = (wait_fluid[ts] + (data.u < pC[ts]) * data.g * w_cond[ts]
           + data.svc)
    hedge = params.get("hedge_delay")
    if hedge is not None:
        lat2 = (wait_fluid[ts] + (data.u2 < pC[ts]) * data.g2 * w_cond[ts]
                + data.svc2)
        lat = np.minimum(lat, float(hedge) + lat2)
    keep = (data.centers[ts] + lat) <= data.duration
    kept = lat[keep]
    if kept.size == 0:
        nanq = float("nan")
        return {"p50": nanq, "p95": nanq, "p99": nanq, "mean": nanq,
                "slo_frac": nanq, "n_eff": 0.0}
    q = quantiles_partition(kept, PLAN_QS)
    return {"p50": float(q[0]), "p95": float(q[1]), "p99": float(q[2]),
            "mean": float(kept.mean()),
            "slo_frac": float(np.mean(kept > data.slo)),
            "n_eff": float(kept.size)}


def analytic_capacity(data: PlanData, cfg: Optional[PlanConfig] = None,
                      lo: float = 0.5, hi: float = 64.0,
                      tol: float = 1e-3, iters: int = 60) -> float:
    """Smallest continuous capacity whose HARD objective metric meets
    the target — bisection on ``hard_metrics`` (the metric is monotone
    non-increasing in capacity under the frozen draws).  This is the
    oracle the CI smoke gate holds the gradient planner to."""
    cfg = cfg or PlanConfig()

    def metric(x: float) -> float:
        return hard_metrics({"capacity": x}, data, cfg)[data.objective]

    if metric(hi) > data.target:
        return hi                   # infeasible inside the box
    for _ in range(iters):
        if hi - lo <= tol:
            break
        mid = 0.5 * (lo + hi)
        if metric(mid) <= data.target:
            hi = mid
        else:
            lo = mid
    return hi
