"""Gradient-based capacity planning from the command line.

    PYTHONPATH=src python -m repro.plan steady --slo 0.02 \
        --set policy=jsq --set qps=2600 --capacity 4,1,24
    PYTHONPATH=src python -m repro.plan steady --slo 0.05 \
        --objective slo_frac --target 0.02 --capacity 2,1,16
    PYTHONPATH=src python -m repro.plan steady --slo 0.02 \
        --capacity 4,1,24 --hedge 0.05,0.001,0.5 --steps 200

The planner runs a few hundred Adam steps through the smoothed
surrogate (``repro.vector.soft``), rounds the continuous capacity to an
integer fleet, and verifies it on the exact vector runtime — the probe
ladder plus the final measurement are the only exact cells spent.
``--no-verify`` reports the continuous optimum alone.

Writes ``<out>/plan_<scenario>.json`` (the full ``PlanResult``) and
prints the verified provisioning point.  Exit status is non-zero when
no fleet inside the box meets the target (infeasible).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.plan.planner import DEFAULT_BOXES, PlanSpec, run_plan

OUT_DEFAULT = os.path.join("artifacts", "plan")


def _scalar(text: str):
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            continue
    return text


def _box(text: str, name: str) -> tuple:
    parts = [float(v) for v in text.split(",")]
    if len(parts) == 1:
        init = parts[0]
        _, lo, hi = DEFAULT_BOXES[name]
        return (init, lo, hi)
    if len(parts) != 3:
        raise SystemExit(f"--{name} wants init[,lo,hi] (got {text!r})")
    return tuple(parts)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.plan",
                                 description=__doc__,
                                 formatter_class=argparse
                                 .RawDescriptionHelpFormatter)
    ap.add_argument("scenario", nargs="?",
                    help="canonical scenario to plan (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="list plannable scenarios and objectives")
    ap.add_argument("--slo", type=float, default=None,
                    help="latency SLO in seconds (required)")
    ap.add_argument("--objective", default="p99",
                    choices=["p50", "p95", "p99", "mean", "slo_frac"])
    ap.add_argument("--target", type=float, default=None,
                    help="objective threshold (default: the SLO; 0.05 "
                         "for slo_frac)")
    ap.add_argument("--set", action="append", default=[], dest="fixed",
                    metavar="NAME=VALUE", help="scenario builder override")
    ap.add_argument("--capacity", default="4,1,32", metavar="INIT[,LO,HI]",
                    help="fleet-capacity box (default 4,1,32)")
    ap.add_argument("--hedge", default=None, metavar="INIT[,LO,HI]",
                    help="also learn the hedge delay (seconds)")
    ap.add_argument("--admit", default=None, metavar="INIT[,LO,HI]",
                    help="also learn the admission fraction")
    ap.add_argument("--autoscale", default=None, metavar="BASE,EXTRA",
                    help="learn the autoscale threshold over a "
                         "(base, extra) fleet instead of capacity")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--starts", type=int, default=3)
    ap.add_argument("--lr", type=float, default=0.15)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "constant"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--samples", type=int, default=16384,
                    help="surrogate draw-batch size")
    ap.add_argument("--dt", type=float, default=0.005)
    ap.add_argument("--tau", type=float, default=0.05,
                    help="relaxation temperature")
    ap.add_argument("--penalty", type=float, default=25.0,
                    help="SLO-barrier weight")
    ap.add_argument("--reps", type=int, default=13,
                    help="exact reps for the final verification")
    ap.add_argument("--probe-reps", type=int, default=5,
                    help="exact reps per rounding-ladder probe")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the exact-runtime verification ladder")
    ap.add_argument("--out", default=OUT_DEFAULT,
                    help=f"artifact directory (default {OUT_DEFAULT})")
    ap.add_argument("--quiet", action="store_true")
    from repro.cache import add_cache_args, cache_from_args
    add_cache_args(ap)
    args = ap.parse_args(argv)

    if args.list:
        from repro import scenarios
        print("plannable canonical scenarios:")
        for n in scenarios.names():
            print(f"  {n}")
        print("objectives: p50 p95 p99 mean slo_frac")
        print(f"parameters: {', '.join(sorted(DEFAULT_BOXES))}")
        return 0
    if not args.scenario:
        ap.print_usage()
        return 2
    if args.slo is None:
        raise SystemExit("--slo is required (planning needs a target)")

    overrides = {}
    for kv in args.fixed:
        if "=" not in kv:
            raise SystemExit(f"--set wants name=value (got {kv!r})")
        k, v = kv.split("=", 1)
        overrides[k] = _scalar(v)

    params = {}
    autoscale = None
    if args.autoscale is not None:
        base, extra = (float(v) for v in args.autoscale.split(","))
        autoscale = (base, extra)
        params["scale_threshold"] = DEFAULT_BOXES["scale_threshold"]
    else:
        params["capacity"] = _box(args.capacity, "capacity")
    if args.hedge is not None:
        params["hedge_delay"] = _box(args.hedge, "hedge_delay")
    if args.admit is not None:
        params["admit"] = _box(args.admit, "admit")

    spec = PlanSpec(
        scenario=args.scenario, objective=args.objective, slo=args.slo,
        target=args.target, overrides=overrides, params=params,
        autoscale=autoscale, steps=args.steps, starts=args.starts,
        lr=args.lr, schedule=args.schedule, seed=args.seed,
        dt=args.dt, samples=args.samples, tau=args.tau,
        penalty=args.penalty, reps=args.reps, probe_reps=args.probe_reps,
        verify=not args.no_verify)

    def _progress(msg: str) -> None:
        print(msg, file=sys.stderr, flush=True)

    cache = cache_from_args(args)
    res = run_plan(spec, progress=None if args.quiet else _progress,
                   cache=cache)

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"plan_{args.scenario}.json")
    with open(path, "w") as f:
        json.dump(res.to_dict(), f, indent=2, sort_keys=True)

    print(f"plan={args.scenario} objective={args.objective} "
          f"target={res.spec['target'] or args.slo}")
    print(f"continuous optimum: {res.params} "
          f"(loss={res.starts[res.best_start]['loss']:.4f}, "
          f"surrogate {args.objective}="
          f"{res.surrogate[args.objective]:.4g})")
    if res.verified is not None:
        v = res.verified
        print(f"verified fleet: n={res.n_star} "
              f"{args.objective}={v['mean']:.4g} +- {v['ci95']:.4g} "
              f"({'feasible' if res.feasible else 'INFEASIBLE'}; "
              f"{res.cell_evals} exact cells)")
    if cache is not None:
        print(f"cache[{cache.cache_dir}] {cache.stats}")
    print(f"wrote {path}")
    return 0 if res.feasible else 1


if __name__ == "__main__":
    sys.exit(main())
