"""Gradient-based capacity planning over the smoothed vector runtime.

The dense alternative — sweeping a provisioning grid on the exact
vector runtime — costs O(grid points x repetitions) cell evaluations
per capacity question.  ``repro.plan`` answers the same question with
O(optimizer steps) through ``jax.value_and_grad`` of a smoothed
surrogate (``repro.vector.soft``), then spends a SMALL number of exact
cells verifying the rounded answer: the surrogate proposes, the exact
runtime decides.  ``benchmarks/bench_plan.py`` holds the planner to
>= 10x fewer exact cells than the dense grid while landing inside the
grid optimum's 95% CI.
"""
from repro.plan.model import (OBJECTIVES, PlanConfig, PlanData, PlanError,
                              analytic_capacity, build_plan_data,
                              hard_metrics, plan_loss, surrogate_metrics)
from repro.plan.planner import (DEFAULT_BOXES, PlanResult, PlanSpec,
                                plan_spec_from_sweep, run_plan,
                                run_plan_sweep)

__all__ = [
    "OBJECTIVES", "PlanConfig", "PlanData", "PlanError", "PlanResult",
    "PlanSpec", "DEFAULT_BOXES", "analytic_capacity", "build_plan_data",
    "hard_metrics", "plan_loss", "plan_spec_from_sweep", "run_plan",
    "run_plan_sweep", "surrogate_metrics",
]
