"""Original-TailBench baseline semantics (the paper's comparison target).

The four restrictions the paper lifts:
  1. server waits for a fixed number of clients before processing
  2. no new client connections once processing starts
  3. server terminates when all predefined clients disconnect
  4. per-client request totals are fixed server-side

``legacy_experiment`` builds an Experiment with these semantics enabled;
Fig. 4 / Table 4 compare it against the TailBench++ mode and verify the
latency distributions are statistically indistinguishable (Welch).
"""
from __future__ import annotations

from dataclasses import replace
from typing import Optional, Sequence

from repro.core.client import ClientConfig, ConstantQPS
from repro.core.harness import Experiment, ServerSpec


def legacy_experiment(n_clients: int, qps_per_client: float, *,
                      requests_per_client: int, app: str = "xapian",
                      duration: float = 60.0, seed: int = 0,
                      workers: int = 1) -> Experiment:
    """All clients start at t=0 with identical server-assigned budgets."""
    clients = [ClientConfig(client_id=i, schedule=ConstantQPS(qps_per_client),
                            start_time=0.0, total_requests=requests_per_client,
                            seed=seed)
               for i in range(n_clients)]
    return Experiment(clients=clients, servers=(ServerSpec(0, workers=workers),),
                      app=app, duration=duration, seed=seed,
                      legacy_mode=True,
                      legacy_requests_per_client=requests_per_client)


def plusplus_equivalent(exp: Experiment) -> Experiment:
    """The same workload expressed with TailBench++ semantics (client-side
    budgets, dynamic admission) — the paper's equivalence claim is that this
    produces statistically identical latency distributions."""
    return replace(exp, legacy_mode=False, legacy_requests_per_client=None)
