"""Workload generators — the TailBench++ client module.

Feature 3 (independent client behavior): every client owns its start time,
request budget, and service-demand distribution.
Feature 4 (variable client load): ``QPSSchedule`` changes the arrival rate
during execution (piecewise-constant = the paper's Table 5; diurnal and
trace schedules model the cited real-world patterns).

Arrivals are open-loop Poisson (exponential inter-arrival at the current
rate) — TailBench's generator — with Zipf-like service demands preserved
(the paper validates that its changes keep this distribution intact).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# QPS schedules (Feature 4)
# ---------------------------------------------------------------------------
class QPSSchedule:
    def rate(self, t: float) -> float:
        raise NotImplementedError


@dataclass
class ConstantQPS(QPSSchedule):
    qps: float

    def rate(self, t: float) -> float:
        return self.qps


@dataclass
class PiecewiseQPS(QPSSchedule):
    """[(t_start, qps), ...] — e.g. the paper's Table 5:
    [(0,100),(10,300),(20,500),(30,600),(40,800),(50,100)]."""
    points: Sequence[tuple]

    def rate(self, t: float) -> float:
        r = 0.0
        for t0, q in self.points:
            if t >= t0:
                r = q
        return r


@dataclass
class DiurnalQPS(QPSSchedule):
    """Sinusoidal day/night load (Atikoglu et al. diurnal pattern)."""
    base: float
    amplitude: float
    period: float = 60.0
    phase: float = 0.0

    def rate(self, t: float) -> float:
        return max(0.0, self.base + self.amplitude
                   * math.sin(2 * math.pi * (t + self.phase) / self.period))


@dataclass
class TraceQPS(QPSSchedule):
    """Replay a recorded per-second QPS trace."""
    trace: Sequence[float]
    dt: float = 1.0

    def rate(self, t: float) -> float:
        i = min(int(t / self.dt), len(self.trace) - 1)
        return float(self.trace[max(i, 0)])


# ---------------------------------------------------------------------------
# Client configuration (Features 3 + 4)
# ---------------------------------------------------------------------------
@dataclass
class ClientConfig:
    client_id: int
    schedule: QPSSchedule
    start_time: float = 0.0
    total_requests: Optional[int] = None   # None = run until end_time
    end_time: Optional[float] = None
    seed: int = 0
    # service-demand distribution (overridden by the app profile if None)
    profile: Optional[object] = None


class ClientGenerator:
    """Open-loop arrival process for one client."""

    def __init__(self, cfg: ClientConfig, profile, rng_stream: int = 0):
        self.cfg = cfg
        self.profile = cfg.profile or profile
        self.rng = np.random.default_rng((cfg.seed, cfg.client_id, rng_stream))
        self.t = cfg.start_time
        self.sent = 0

    def exhausted(self, t: Optional[float] = None) -> bool:
        if self.cfg.total_requests is not None and self.sent >= self.cfg.total_requests:
            return True
        if self.cfg.end_time is not None and (t or self.t) >= self.cfg.end_time:
            return True
        return False

    MAX_STEP = 0.25  # re-sample the rate at least this often (seconds)

    def next_arrival(self) -> Optional[tuple]:
        """-> (time, service_demand) of the next request, or None if done.

        Exponential memorylessness: if the drawn gap crosses a re-sampling
        boundary we advance to the boundary and redraw at the new rate —
        statistically exact for piecewise-constant schedules.
        """
        while True:
            if self.exhausted(self.t):
                return None
            rate = self.cfg.schedule.rate(self.t)
            if rate <= 0:
                self.t += self.MAX_STEP
                continue
            gap = self.rng.exponential(1.0 / rate)
            # never step across a grid boundary: memorylessness makes
            # redrawing at the boundary exact for piecewise-constant rates
            next_grid = (math.floor(self.t / self.MAX_STEP) + 1) * self.MAX_STEP
            if self.t + gap >= next_grid:
                self.t = next_grid
                continue
            self.t += gap
            if self.exhausted(self.t):
                return None
            self.sent += 1
            return self.t, self.profile.sample(self.rng)
