"""Workload generators — the TailBench++ client module.

Feature 3 (independent client behavior): every client owns its start time,
request budget, and service-demand distribution.
Feature 4 (variable client load): ``QPSSchedule`` changes the arrival rate
during execution (piecewise-constant = the paper's Table 5; diurnal and
trace schedules model the cited real-world patterns).

Arrivals are open-loop Poisson (exponential inter-arrival at the current
rate) — TailBench's generator — with Zipf-like service demands preserved
(the paper validates that its changes keep this distribution intact).
"""
from __future__ import annotations

import math
from bisect import bisect_right
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# QPS schedules (Feature 4)
# ---------------------------------------------------------------------------
class QPSSchedule:
    def rate(self, t: float) -> float:
        raise NotImplementedError

    def rate_array(self, ts) -> np.ndarray:
        """Vectorized ``rate`` over an array of times — the same law
        evaluated as one array op, so the vector runtime can lay a whole
        sweep grid's arrival rates out structure-of-arrays.  Subclasses
        override with closed-form array math; this fallback loops."""
        return np.asarray([self.rate(float(t)) for t in np.asarray(ts)],
                          float)

    def next_change(self, t: float) -> Optional[float]:
        """Earliest time > t at which the rate may change.

        ``math.inf`` means the rate is constant from ``t`` on; ``None``
        means unknown (continuously varying) — callers must re-sample on
        the MAX_STEP grid.  Schedules with breakpoints override this so
        generators can skip zero-rate regions (e.g. night-time trace
        gaps) in one step instead of spinning through them."""
        return None


@dataclass
class ConstantQPS(QPSSchedule):
    qps: float

    def rate(self, t: float) -> float:
        return self.qps

    def rate_array(self, ts) -> np.ndarray:
        return np.full(np.shape(ts), float(self.qps))

    def next_change(self, t: float) -> float:
        return math.inf


@dataclass
class PiecewiseQPS(QPSSchedule):
    """[(t_start, qps), ...] — e.g. the paper's Table 5:
    [(0,100),(10,300),(20,500),(30,600),(40,800),(50,100)].

    Lookups are O(log n) via bisect over the (sorted) breakpoints — the
    generator re-samples the rate every MAX_STEP, so this sits on the
    arrival hot path.  Times before the first breakpoint have rate 0."""
    points: Sequence[tuple]

    def __post_init__(self):
        pts = sorted((float(t0), float(q)) for t0, q in self.points)
        self._ts = [t0 for t0, _ in pts]
        self._qs = [q for _, q in pts]

    def rate(self, t: float) -> float:
        i = bisect_right(self._ts, t) - 1
        return self._qs[i] if i >= 0 else 0.0

    def rate_array(self, ts) -> np.ndarray:
        idx = np.searchsorted(self._ts, np.asarray(ts, float),
                              side="right") - 1
        qs = np.concatenate([[0.0], self._qs])      # idx -1 -> rate 0
        return qs[idx + 1]

    def next_change(self, t: float) -> float:
        i = bisect_right(self._ts, t)
        return self._ts[i] if i < len(self._ts) else math.inf


@dataclass
class DiurnalQPS(QPSSchedule):
    """Sinusoidal day/night load (Atikoglu et al. diurnal pattern)."""
    base: float
    amplitude: float
    period: float = 60.0
    phase: float = 0.0

    def rate(self, t: float) -> float:
        return max(0.0, self.base + self.amplitude
                   * math.sin(2 * math.pi * (t + self.phase) / self.period))

    def rate_array(self, ts) -> np.ndarray:
        ts = np.asarray(ts, float)
        return np.maximum(0.0, self.base + self.amplitude * np.sin(
            2 * np.pi * (ts + self.phase) / self.period))

    def next_change(self, t: float) -> Optional[float]:
        """When ``amplitude >= base`` the clipped sinusoid bottoms out at
        zero for a whole sub-interval of each period; without this,
        generators spin through the trough at the MAX_STEP fallback.
        Inside a trough we return the exact zero-exit time (the rising
        crossing of ``sin = -base/amplitude``).  No RNG draws happen at
        zero rate, so only the resume instant moves (to the true
        crossing instead of an entry-dependent grid point); schedules
        that never clip (``amplitude < base``) are untouched.  Elsewhere
        the rate varies continuously: None keeps the grid re-sampling."""
        if self.amplitude == 0.0:
            return math.inf                       # constant rate forever
        if self.rate(t) > 0.0:
            return None
        # a negative amplitude is the same sinusoid half a period out of
        # phase: fold it into the positive-amplitude math
        amp, phase = self.amplitude, self.phase
        if amp < 0.0:
            amp, phase = -amp, phase + self.period / 2.0
        s0 = -self.base / amp                     # sin level of the clip
        if s0 > 1.0:
            return math.inf                       # rate is zero forever
        two_pi = 2.0 * math.pi
        theta = (two_pi * (t + phase) / self.period) % two_pi
        # zero region: sin(theta) <= s0, i.e. theta in
        # [pi - asin(s0), 2*pi + asin(s0)]; the exit is the upper edge
        theta_exit = two_pi + math.asin(max(min(s0, 1.0), -1.0))
        delta = (theta_exit - theta) % two_pi
        return t + delta * self.period / two_pi


@dataclass
class TraceQPS(QPSSchedule):
    """Replay a recorded per-second QPS trace (uniform dt -> O(1) lookup).

    An empty trace has no defined rate: NaN, not an IndexError."""
    trace: Sequence[float]
    dt: float = 1.0

    def __post_init__(self):
        # change-point indices (cells whose rate differs from their
        # predecessor), precomputed once: next_change is O(log changes)
        # instead of a linear rescan from the current cell — O(n^2) over
        # a long flat trace when the generator walks it breakpoint by
        # breakpoint
        self._changes = [j for j in range(1, len(self.trace))
                         if self.trace[j] != self.trace[j - 1]]

    def rate(self, t: float) -> float:
        if len(self.trace) == 0:
            return float("nan")
        i = min(int(t / self.dt), len(self.trace) - 1)
        return float(self.trace[max(i, 0)])

    def rate_array(self, ts) -> np.ndarray:
        ts = np.asarray(ts, float)
        if len(self.trace) == 0:
            return np.full(ts.shape, float("nan"))
        idx = np.clip((ts / self.dt).astype(np.int64), 0,
                      len(self.trace) - 1)
        return np.asarray(self.trace, float)[idx]

    def next_change(self, t: float) -> float:
        """Start time of the next cell whose rate differs from rate(t) —
        lets generators jump a whole idle night in one step."""
        n = len(self.trace)
        if n == 0:
            return math.inf
        i = max(min(int(t / self.dt), n - 1), 0)
        # cells between two change points share one rate, so the first
        # change index > i is exactly the next differing cell
        k = bisect_right(self._changes, i)
        if k >= len(self._changes):
            return math.inf
        return self._changes[k] * self.dt


# ---------------------------------------------------------------------------
# Client configuration (Features 3 + 4)
# ---------------------------------------------------------------------------
@dataclass
class ClientConfig:
    client_id: int
    schedule: QPSSchedule
    start_time: float = 0.0
    total_requests: Optional[int] = None   # None = run until end_time
    end_time: Optional[float] = None
    seed: int = 0
    # service-demand distribution (overridden by the app profile if None)
    profile: Optional[object] = None
    # per-request token sizes (TokenLengths); None = unsized requests
    lengths: Optional[object] = None


# domain-separation salt for the size-RNG stream: request sizes must not
# perturb the arrival-time draws (bit-compatibility of unsized configs)
_SIZE_STREAM = 0x512E


class ClientGenerator:
    """Open-loop arrival process for one client.

    When a ``TokenLengths`` distribution is configured (``cfg.lengths``
    or the harness default), every arrival also samples
    ``(prompt_tokens, max_new_tokens)`` into ``last_sizes`` — from a
    *separate* RNG stream keyed by the same (seed, client_id, rep), so
    both runtime backends see identical sizes and unsized runs keep
    bit-identical arrival draws."""

    def __init__(self, cfg: ClientConfig, profile, rng_stream: int = 0,
                 lengths=None):
        self.cfg = cfg
        self.profile = cfg.profile or profile
        self.rng = np.random.default_rng((cfg.seed, cfg.client_id, rng_stream))
        self.t = cfg.start_time
        self.sent = 0
        self.lengths = cfg.lengths if cfg.lengths is not None else lengths
        self.last_sizes: tuple = (0, 0)     # (prompt_tokens, max_new_tokens)
        if self.lengths is not None:
            self._size_rng = np.random.default_rng(
                (cfg.seed, cfg.client_id, rng_stream, _SIZE_STREAM))
            self._sample_sizes = self.lengths.sample
        else:
            self._sample_sizes = None
        # hot-path bindings (next_arrival runs once per generated request)
        self._budget = math.inf if cfg.total_requests is None else cfg.total_requests
        self._end = math.inf if cfg.end_time is None else cfg.end_time
        self._rate = cfg.schedule.rate
        self._next_change = cfg.schedule.next_change
        self._draw = self.rng.exponential
        self._sample = self.profile.sample

    def exhausted(self, t: Optional[float] = None) -> bool:
        if self.sent >= self._budget:
            return True
        # explicit None check: t == 0.0 is a real timestamp, not "unset"
        return (self.t if t is None else t) >= self._end

    MAX_STEP = 0.25  # re-sample the rate at least this often (seconds)

    def next_arrival(self) -> Optional[tuple]:
        """-> (time, service_demand) of the next request, or None if done.

        Exponential memorylessness: if the drawn gap crosses a re-sampling
        boundary we advance to the boundary and redraw at the new rate —
        statistically exact for piecewise-constant schedules.
        """
        t = self.t
        budget, end, step = self._budget, self._end, self.MAX_STEP
        if self.sent >= budget or t >= end:
            return None
        while True:
            rate = self._rate(t)
            if rate != rate:       # NaN (e.g. empty TraceQPS): no defined
                self.t = t         # rate, treat the client as exhausted —
                return None        # NaN would slip past the <= 0 guard
            if rate <= 0:
                # skip dead air: jump straight to the schedule's next
                # breakpoint instead of spinning in MAX_STEP increments
                # (no RNG draws happen at zero rate, so skipping is exact)
                nc = self._next_change(t)
                if nc is None:              # continuous schedule: re-sample
                    t += step               # on the grid as before
                elif nc == math.inf:        # zero rate forever -> done
                    self.t = t
                    return None
                else:
                    t = max(nc, t + 1e-12)  # breakpoints are > t by contract
                if t >= end:
                    self.t = t
                    return None
                continue
            gap = self._draw(1.0 / rate)
            # never step across a grid boundary: memorylessness makes
            # redrawing at the boundary exact for piecewise-constant rates
            next_grid = (math.floor(t / step) + 1.0) * step
            if t + gap >= next_grid:
                t = next_grid
                if t >= end:
                    self.t = t
                    return None
                continue
            t += gap
            self.t = t
            if t >= end:
                return None
            self.sent += 1
            if self._sample_sizes is not None:
                self.last_sizes = self._sample_sizes(self._size_rng)
            return t, self._sample(self.rng)


class BatchedClientGenerator(ClientGenerator):
    """Vectorized arrival generation for constant-rate open-loop clients.

    Draws inter-arrival gaps and service demands in numpy chunks instead
    of one scalar RNG call per request — ~10x cheaper per arrival, which
    matters when a 10k-server run pumps millions of requests.  The
    arrival process is the same Poisson law (for a constant rate the
    MAX_STEP re-gridding of the base class is a statistical no-op by
    memorylessness), but the RNG stream differs from the scalar path, so
    this is opt-in (``SimConfig.fast_clients``) and never used by the
    bit-compatible figure configs.
    """

    CHUNK = 4096

    def __init__(self, cfg: ClientConfig, profile, rng_stream: int = 0,
                 lengths=None):
        super().__init__(cfg, profile, rng_stream, lengths=lengths)
        if not isinstance(cfg.schedule, ConstantQPS) or cfg.schedule.qps <= 0:
            raise ValueError("BatchedClientGenerator needs ConstantQPS > 0")
        self._scale = 1.0 / cfg.schedule.qps
        self._ts: list[float] = []
        self._ds: list[float] = []
        self._i = 0

    def _refill(self) -> int:
        k = min(self.CHUNK, int(self._budget - self.sent)) \
            if self._budget != math.inf else self.CHUNK
        if k <= 0:
            return 0
        gaps = self.rng.standard_exponential(k) * self._scale
        ts = self.t + np.cumsum(gaps)
        self._ts = ts.tolist()              # python floats: fast scalar reads
        self._ds = self.profile.sample_batch(self.rng, k).tolist()
        self._i = 0
        return k

    def next_arrival(self) -> Optional[tuple]:
        if self.sent >= self._budget:
            return None
        i = self._i
        if i >= len(self._ts):
            if self._refill() == 0:
                return None
            i = 0
        t = self._ts[i]
        self._i = i + 1
        self.t = t
        if t >= self._end:
            return None
        self.sent += 1
        if self._sample_sizes is not None:
            self.last_sizes = self._sample_sizes(self._size_rng)
        return t, self._ds[i]
