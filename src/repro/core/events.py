"""Calendar-queue event scheduler for the discrete-event simulator.

A classic binary heap costs O(log n) per operation with n pending events;
at 10k servers the heap holds tens of thousands of entries and every
push/pop walks ~17 levels.  A calendar queue (Brown 1988) exploits the
fact that simulation time only moves forward: events are hashed into
fixed-width time buckets, so insertion is O(1) and dequeue is O(1)
amortized.

This variant is a *timeline* calendar: the bucket array spans
``[0, horizon]`` (the simulator's configured duration), so there is no
year wrap-around to reason about.  Events inside the currently-active
bucket window live in a small binary heap (C-implemented ``heapq`` on a
few dozen entries), which gives an exact global ``(t, seq)`` total order
— identical to the order the seed heap engine produced, so results are
bit-reproducible across engines.

Entries are tuples whose first two fields are ``(t, seq)``; ties on ``t``
are broken by the monotone sequence number, never by the payload, so
heterogeneous payloads are safe.

The bucket array grows (4x, with full redistribution) whenever the
pending-event count exceeds ``GROW_FACTOR`` entries per bucket, keeping
the active-window heap small under load.  If the caller passes a horizon
much larger than the span events actually occupy, the structure degrades
gracefully to a single heap — correct, just not faster than the seed.
"""
from __future__ import annotations

from heapq import heapify, heappop, heappush

GROW_FACTOR = 8          # pending events per bucket before growing
MAX_BUCKETS = 1 << 20


class CalendarQueue:
    """Monotone priority queue over ``[0, horizon]`` keyed on ``(t, seq)``."""

    __slots__ = ("horizon", "_nb", "_inv", "_buckets", "_act", "_idx", "_n",
                 "_last_t")

    def __init__(self, horizon: float, n_buckets: int = 256):
        self.horizon = max(float(horizon), 1e-9)
        self._nb = n_buckets
        self._inv = n_buckets / self.horizon        # 1 / bucket width
        self._buckets: list[list] = [[] for _ in range(n_buckets)]
        self._act: list = []       # heap for the active bucket window
        self._idx = -1             # last promoted bucket index
        self._n = 0
        self._last_t = 0.0

    def __len__(self) -> int:
        return self._n

    def push(self, item: tuple) -> None:
        i = int(item[0] * self._inv)
        if i >= self._nb:          # clamp BEFORE the active-window check:
            i = self._nb - 1       # a beyond-horizon event must land in the
        if i <= self._idx:         # heap when the last bucket is already
            heappush(self._act, item)  # active, or pop() would never see it
        else:
            self._buckets[i].append(item)
        self._n += 1
        if self._n > GROW_FACTOR * self._nb and self._nb < MAX_BUCKETS:
            self._grow()

    def pop(self):
        """Next event in global ``(t, seq)`` order, or None when empty."""
        act = self._act
        if act:
            self._n -= 1
            item = heappop(act)
            self._last_t = item[0]
            return item
        buckets, nb = self._buckets, self._nb
        idx = self._idx
        while idx + 1 < nb:
            idx += 1
            b = buckets[idx]
            if b:
                buckets[idx] = []
                heapify(b)
                self._act = b
                self._idx = idx
                self._n -= 1
                item = heappop(b)
                self._last_t = item[0]
                return item
        self._idx = idx
        return None

    def _grow(self) -> None:
        pending = self._act
        for i in range(self._idx + 1, self._nb):
            pending += self._buckets[i]
        self._nb *= 4
        self._inv = self._nb / self.horizon
        self._buckets = [[] for _ in range(self._nb)]
        self._idx = min(int(self._last_t * self._inv), self._nb - 1)
        act: list = []
        last = self._nb - 1
        for item in pending:
            i = min(int(item[0] * self._inv), last)
            if i <= self._idx:
                act.append(item)
            else:
                self._buckets[i].append(item)
        heapify(act)
        self._act = act
