"""Experiment orchestration — the TailBench++ harness entry point.

``Experiment`` describes clients, servers, balancer, app profile and mode
(tailbench++ vs legacy baseline); ``run()`` executes one deterministic
simulation; ``run_repeated()`` gives the paper's 13-repetition confidence
intervals.  Declarative dynamic scenarios compile down to ``Experiment``
(see ``repro.core.scenario``), and the same compiled experiment also runs
wall-clock against real inference engines via
``repro.core.runtime.EngineRuntime`` (the end-to-end validation path).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Optional, Sequence

from repro.core.balancer import POLICIES
from repro.core.client import ClientConfig
from repro.core.profiles import tailbench_profile
from repro.core.simulator import SimConfig, SimServer, Simulator
from repro.core.stats import LatencyRecorder, confidence95


@dataclass
class ServerSpec:
    server_id: int
    workers: int = 1
    speed: float = 1.0
    service_noise: float = 0.0     # log-sigma of per-execution server noise
    join_at: float = 0.0
    drain_at: Optional[float] = None
    max_batch: Optional[int] = None   # batch slots (batched ServiceModels)
    # standby pool for elastic scale (set_scale injections / reactive
    # controllers): the server exists from t=0 — engines are built and
    # warmed up front — but starts drained (not accepting) until a scale
    # action activates it.  join_at/drain_at schedules don't apply.
    standby: bool = False


@dataclass
class Experiment:
    clients: Sequence[ClientConfig]
    servers: Sequence[ServerSpec] = (ServerSpec(0),)
    app: str = "xapian"
    policy: str = "round_robin"
    duration: float = 60.0
    interval: float = 1.0
    seed: int = 0
    legacy_mode: bool = False
    legacy_requests_per_client: Optional[int] = None
    legacy_expected_clients: Optional[int] = None   # default: len(clients)
    hedge_delay: Optional[float] = None
    profile: Optional[object] = None          # overrides `app`
    stats_mode: str = "exact"                 # "exact" | "streaming" recorder
    fast_clients: bool = False                # vectorized constant-QPS arrivals
    slo: Optional[float] = None               # latency SLO (telemetry frames)
    injections: Sequence = ()                 # compiled Scenario injections
    # pluggable ServiceModel: None = scalar default (the app profile);
    # a BatchedService switches servers to the continuous-batching loop
    service_model: Optional[object] = None
    lengths: Optional[object] = None          # default per-request TokenLengths
    # resilience + closed-loop control (repro.control; all sweepable):
    # RetryPolicy (client timeouts/retries; sim+engine), BreakerSpec
    # (per-server circuit breaking; sim+engine), ControlSpec (reactive
    # controller; all three backends — see the capability matrix)
    retry: Optional[object] = None
    breaker: Optional[object] = None
    control: Optional[object] = None

    def resolved_profile(self):
        if self.profile is not None:
            return self.profile
        if self.service_model is not None:
            if getattr(self.service_model, "kind", "scalar") == "batched":
                # batched servers cost requests by token counts, not by a
                # client-sampled scalar demand — don't burn RNG draws on one
                from repro.core.profiles import FixedProfile
                return FixedProfile("tokens", 0.0)
            prof = getattr(self.service_model, "profile", None)
            if prof is not None:
                # a ScalarService wrapper IS a profile choice — honor it
                # instead of silently falling back to the app default
                return prof
        return tailbench_profile(self.app)

    def resolved_service(self):
        """The effective ServiceModel (scalar wraps the profile)."""
        from repro.core.profiles import resolve_service_model
        return resolve_service_model(self.service_model,
                                     self.resolved_profile())

    def resolved_lengths(self):
        """The effective per-request TokenLengths.  A batched service
        model costs requests by token counts, so leaving ``lengths``
        unset must not degenerate every request to a single prompt token
        and zero decode steps — default to the stock distribution."""
        if self.lengths is not None:
            return self.lengths
        if (self.service_model is not None
                and getattr(self.service_model, "kind", "scalar") == "batched"):
            from repro.core.profiles import TokenLengths
            return TokenLengths()
        return None


def build_simulator(exp: Experiment, rep: int = 0) -> Simulator:
    """Build one deterministic simulation.

    ``rep`` is the repetition index: every client's arrival stream is
    derived from ``(client seed, client_id, rep)``, so repetitions draw
    independent arrival processes even for clients that pin an explicit
    seed (repetition 0 reproduces the un-repeated run bit-for-bit).
    """
    def _srv_seed(sid: int) -> tuple:
        # domain-separated (seed, server_id, rep): repetitions draw
        # independent server-noise streams (mirrors the client-RNG fix)
        return (9176, exp.seed, sid, rep)

    servers = []
    for s in exp.servers:
        if s.join_at != 0.0:
            continue
        srv = SimServer(s.server_id, s.workers, s.speed, s.service_noise,
                        rng_seed=_srv_seed(s.server_id),
                        service_model=exp.service_model,
                        max_batch=s.max_batch)
        if s.standby:
            # standby pool: present (engine parity: built and warm) but
            # drained until a set_scale action activates it
            srv.draining = True
            srv.accepting = False
        servers.append(srv)
    balancer = POLICIES[exp.policy]() if isinstance(exp.policy, str) else exp.policy
    n_expected = exp.legacy_expected_clients
    if n_expected is None:
        n_expected = len(exp.clients)
    cfg = SimConfig(duration=exp.duration, interval=exp.interval, seed=exp.seed,
                    legacy_mode=exp.legacy_mode,
                    legacy_expected_clients=n_expected if exp.legacy_mode else 0,
                    legacy_requests_per_client=exp.legacy_requests_per_client,
                    hedge_delay=exp.hedge_delay, rep=rep,
                    stats_mode=exp.stats_mode, fast_clients=exp.fast_clients,
                    slo=exp.slo, retry=exp.retry, breaker=exp.breaker,
                    control=exp.control)
    sim = Simulator(cfg, servers, balancer, profile=exp.resolved_profile(),
                    lengths=exp.resolved_lengths(),
                    service_model=exp.service_model)
    for c in exp.clients:
        c2 = replace(c, seed=c.seed if c.seed else exp.seed)
        sim.add_client(c2)
    for s in exp.servers:
        if s.join_at > 0.0:
            sim.add_server(SimServer(s.server_id, s.workers, s.speed,
                                     s.service_noise,
                                     rng_seed=_srv_seed(s.server_id),
                                     service_model=exp.service_model,
                                     max_batch=s.max_batch),
                           s.join_at)
        if s.drain_at is not None:
            sim.drain_server(s.server_id, s.drain_at)
    for inj in exp.injections:
        sim.apply_injection(inj.kind, inj.at, inj.params)
    return sim


def run(exp: Experiment, rep: int = 0) -> Simulator:
    sim = build_simulator(exp, rep=rep)
    sim.run()
    return sim


def _repeated_point(exp: Experiment, ctx):
    """Sweep factory for ``run_repeated``: replay the base experiment
    under the derived per-repetition seed."""
    return replace(exp, seed=ctx.seed)


def run_repeated(exp: Experiment, reps: int = 13,
                 metric: Callable[[LatencyRecorder], float] = lambda r: r.overall().p99):
    """Paper methodology: 13 seeded repetitions -> (mean, 95% CI half-width).

    Now a thin shim over a one-point ``repro.sweep`` declaration with
    the ``"run-repeated"`` seeder — bit-compatible with the historical
    ``seed + 1000*(rep+1)`` derivation (which new sweeps should NOT
    inherit: it collides across grid points; the sweep default
    ``"spawn"`` seeder never does).  Each repetition perturbs the
    experiment seed AND threads the repetition index into every
    client's RNG stream, so explicitly-seeded clients still draw
    independent arrival processes per repetition.
    """
    from functools import partial

    from repro.sweep import Sweep, run_sweep
    sweep = Sweep(name="run_repeated",
                  factory=partial(_repeated_point, exp),
                  reps=reps, base_seed=exp.seed, seeder="run-repeated",
                  metrics=(("value", lambda rt: metric(rt.recorder)),))
    # fail_fast: the old loop propagated the original exception at the
    # first failing repetition — keep that contract
    frame = run_sweep(sweep, executor="serial", progress=None,
                      fail_fast=True)
    vals = [row.metrics["value"] for row in frame.rows]
    return confidence95(vals), vals


# ---------------------------------------------------------------------------
# Real-engine mode: deprecated shim over repro.core.runtime.EngineRuntime.
# ---------------------------------------------------------------------------
def run_engine_experiment(engines: list, clients: Sequence[ClientConfig], *,
                          policy: str = "round_robin", duration: float = 10.0,
                          prompt_len: int = 16, max_new_tokens: int = 4,
                          vocab: int = 256, seed: int = 0,
                          time_scale: float = 1.0) -> LatencyRecorder:
    """Deprecated: use ``repro.core.runtime.EngineRuntime``.

    The bespoke wall-clock loop that used to live here silently diverged
    from the simulator's client/balancer machinery; ``EngineRuntime``
    reuses ``ClientGenerator``, ``Balancer`` (assign/route/release
    lifecycle) and ``LatencyRecorder`` verbatim, so one scenario runs on
    either backend.  This shim survives one release for callers of the
    old entry point and returns the recorder as before.
    """
    import warnings

    from repro.core.runtime import EngineRuntime
    warnings.warn("run_engine_experiment is deprecated; use "
                  "repro.core.runtime.EngineRuntime", DeprecationWarning,
                  stacklevel=2)
    rt = EngineRuntime(engines, clients, policy=policy, duration=duration,
                       prompt_len=prompt_len, max_new_tokens=max_new_tokens,
                       vocab=vocab, seed=seed, time_scale=time_scale)
    rt.run()
    return rt.recorder
