"""Experiment orchestration — the TailBench++ harness entry point.

``Experiment`` describes clients, servers, balancer, app profile and mode
(tailbench++ vs legacy baseline); ``run()`` executes one deterministic
simulation; ``run_repeated()`` gives the paper's 13-repetition confidence
intervals.  ``run_engine_experiment()`` drives a *real* JAX inference
engine in wall-clock time with the same client machinery (the end-to-end
validation path).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.balancer import POLICIES, Balancer
from repro.core.client import ClientConfig, ClientGenerator, ConstantQPS
from repro.core.profiles import tailbench_profile
from repro.core.simulator import SimConfig, SimServer, Simulator
from repro.core.stats import LatencyRecorder, Summary, confidence95


@dataclass
class ServerSpec:
    server_id: int
    workers: int = 1
    speed: float = 1.0
    service_noise: float = 0.0     # log-sigma of per-execution server noise
    join_at: float = 0.0
    drain_at: Optional[float] = None


@dataclass
class Experiment:
    clients: Sequence[ClientConfig]
    servers: Sequence[ServerSpec] = (ServerSpec(0),)
    app: str = "xapian"
    policy: str = "round_robin"
    duration: float = 60.0
    interval: float = 1.0
    seed: int = 0
    legacy_mode: bool = False
    legacy_requests_per_client: Optional[int] = None
    legacy_expected_clients: Optional[int] = None   # default: len(clients)
    hedge_delay: Optional[float] = None
    profile: Optional[object] = None          # overrides `app`
    stats_mode: str = "exact"                 # "exact" | "streaming" recorder
    fast_clients: bool = False                # vectorized constant-QPS arrivals

    def resolved_profile(self):
        return self.profile or tailbench_profile(self.app)


def build_simulator(exp: Experiment, rep: int = 0) -> Simulator:
    """Build one deterministic simulation.

    ``rep`` is the repetition index: every client's arrival stream is
    derived from ``(client seed, client_id, rep)``, so repetitions draw
    independent arrival processes even for clients that pin an explicit
    seed (repetition 0 reproduces the un-repeated run bit-for-bit).
    """
    servers = [SimServer(s.server_id, s.workers, s.speed, s.service_noise)
               for s in exp.servers if s.join_at == 0.0]
    balancer = POLICIES[exp.policy]() if isinstance(exp.policy, str) else exp.policy
    n_expected = exp.legacy_expected_clients
    if n_expected is None:
        n_expected = len(exp.clients)
    cfg = SimConfig(duration=exp.duration, interval=exp.interval, seed=exp.seed,
                    legacy_mode=exp.legacy_mode,
                    legacy_expected_clients=n_expected if exp.legacy_mode else 0,
                    legacy_requests_per_client=exp.legacy_requests_per_client,
                    hedge_delay=exp.hedge_delay, rep=rep,
                    stats_mode=exp.stats_mode, fast_clients=exp.fast_clients)
    sim = Simulator(cfg, servers, balancer, profile=exp.resolved_profile())
    for c in exp.clients:
        c2 = replace(c, seed=c.seed if c.seed else exp.seed)
        sim.add_client(c2)
    for s in exp.servers:
        if s.join_at > 0.0:
            sim.add_server(SimServer(s.server_id, s.workers, s.speed,
                                     s.service_noise), s.join_at)
        if s.drain_at is not None:
            sim.drain_server(s.server_id, s.drain_at)
    return sim


def run(exp: Experiment, rep: int = 0) -> Simulator:
    sim = build_simulator(exp, rep=rep)
    sim.run()
    return sim


def run_repeated(exp: Experiment, reps: int = 13,
                 metric: Callable[[LatencyRecorder], float] = lambda r: r.overall().p99):
    """Paper methodology: 13 seeded repetitions -> (mean, 95% CI half-width).

    Each repetition perturbs the experiment seed AND threads the
    repetition index into every client's RNG stream — a client with an
    explicit ``ClientConfig.seed`` still sees an independent arrival
    process per repetition (previously all 13 reps replayed identical
    arrivals, collapsing the confidence interval to zero width).
    """
    vals = []
    for rep in range(reps):
        sim = run(replace(exp, seed=exp.seed + 1000 * (rep + 1)), rep=rep)
        vals.append(metric(sim.recorder))
    return confidence95(vals), vals


# ---------------------------------------------------------------------------
# Real-engine mode: same clients, wall-clock time, actual JAX inference.
# ---------------------------------------------------------------------------
def run_engine_experiment(engines: list, clients: Sequence[ClientConfig], *,
                          policy: str = "round_robin", duration: float = 10.0,
                          prompt_len: int = 16, max_new_tokens: int = 4,
                          vocab: int = 256, seed: int = 0,
                          time_scale: float = 1.0) -> LatencyRecorder:
    """Drive real InferenceEngine(s) with the harness's open-loop clients.

    Arrival times are pre-generated (virtual seconds x time_scale); the loop
    admits due requests and steps engines round-robin.  Latency = wall time
    from (scaled) arrival to completion.
    """
    from repro.core.profiles import FixedProfile
    from repro.core.request import Request as Rec

    rng = np.random.default_rng(seed)
    # pre-generate every client's arrival timeline
    arrivals = []      # (t, client_id, req_id)
    rid = 0
    for c in clients:
        gen = ClientGenerator(c, FixedProfile("tok", 0.0))
        while True:
            nxt = gen.next_arrival()
            if nxt is None or nxt[0] > duration:
                break
            arrivals.append((nxt[0] * time_scale, c.client_id, rid))
            rid += 1
    arrivals.sort()
    balancer = POLICIES[policy]()

    class _EngineShim:
        def __init__(self, i, eng):
            self.server_id, self.eng = i, eng
            self.connected: set = set()
            self.accepting = True

        def load(self):
            return self.eng.pending() + self.eng.n_active()

        def connect(self, cid):
            self.connected.add(cid)
            return True

    shims = [_EngineShim(i, e) for i, e in enumerate(engines)]
    assignment: dict[int, _EngineShim] = {}
    recorder = LatencyRecorder()
    meta: dict[int, tuple] = {}
    t0 = time.monotonic()
    idx = 0
    pending_total = len(arrivals)
    done_total = 0
    while done_total < pending_total:
        now = time.monotonic() - t0
        while idx < len(arrivals) and arrivals[idx][0] <= now:
            t_arr, cid, req_id = arrivals[idx]
            idx += 1
            if cid not in assignment:
                class _C:  # minimal client view for the balancer
                    cfg = [c for c in clients if c.client_id == cid][0]
                assignment[cid] = balancer.assign(_C(), shims) or shims[0]
            shim = balancer.route(None, shims, assignment[cid])
            prompt = rng.integers(0, vocab, size=prompt_len)
            meta[req_id] = (cid, t_arr)
            shim.eng.submit(prompt, max_new_tokens, req_id)
        stepped = False
        for shim in shims:
            if not shim.eng.idle():
                for comp in shim.eng.step():
                    cid, t_arr = meta[comp.req_id]
                    wall = time.monotonic() - t0
                    rec = Rec(comp.req_id, cid, t_arr, 0.0)
                    rec.enqueued = t_arr
                    rec.started = wall - comp.latency
                    rec.completed = wall
                    recorder.record(rec)
                    done_total += 1
                stepped = True
        if not stepped and idx < len(arrivals):
            time.sleep(min(0.001, max(0.0, arrivals[idx][0] - (time.monotonic() - t0))))
    return recorder
