"""Service-time profiles.

Two kinds of "application" can sit behind a server:

1. The paper's eight TailBench apps, reproduced as calibrated service-time
   distributions.  TailBench spans "very short - large (10us - 10s)"
   (Table 1); per-app medians follow the paper's Fig. 4 latency scales.
   Request work is log-normal around the median (the Zipf-like heavy tail
   the harness is required to preserve) with a deterministic seed stream.

2. The 10 assigned architectures: service time per request derived from the
   dry-run roofline model — max(compute, memory) term of one batched decode
   step at the serving batch, divided across the batch, plus a prefill term
   proportional to prompt length.  See repro/launch/roofline.py.

Both expose ``sample(rng) -> seconds of server work``.

On top of the raw profiles sits the pluggable **ServiceModel** layer — the
contract between a workload and the thing that executes it:

* ``ScalarService`` wraps a profile: one request occupies one worker slot
  for a profile-sampled number of seconds.  This is the paper's TailBench
  semantics and the bit-identical default everywhere.
* ``BatchedService`` models a continuous-batching inference engine,
  calibrated from the roofline model (``repro.launch.roofline``): one
  decode step costs ``max(compute x batch, memory)`` seconds — weight/KV
  streaming is batch-independent, compute scales per sequence — so
  throughput rises sub-linearly with occupancy exactly like the real
  ``InferenceEngine``.  Prefill cost is proportional to prompt tokens.

``BatchScheduler`` is the shared continuous-batching op sequencer: the
virtual-time ``SimServer`` serve loop and the wall-clock
``BatchedStubEngine`` both drive it, so the simulator and the engine
backend agree on batching dynamics *by construction*.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


def _phi(x: float) -> float:
    """Standard normal CDF."""
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


@dataclass(frozen=True)
class LogNormalProfile:
    """Median service time + heavy right tail (sigma in log space)."""
    name: str
    median: float                  # seconds
    sigma: float = 0.45
    max_factor: float = 30.0       # truncate the tail (bounded work)

    def sample(self, rng: np.random.Generator) -> float:
        x = self.median * math.exp(self.sigma * rng.standard_normal())
        return float(min(x, self.median * self.max_factor))

    def sample_batch(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Vectorized draw (same law as ``sample``, bulk RNG stream)."""
        x = self.median * np.exp(self.sigma * rng.standard_normal(n))
        return np.minimum(x, self.median * self.max_factor)

    def moments(self) -> tuple[float, float]:
        """Exact (mean, variance) of the truncated law ``min(X, M)`` —
        closed form via the normal CDF, no Monte Carlo.  The vector
        runtime feeds these into its CLT per-slot work aggregation."""
        m, s, M = self.median, self.sigma, self.median * self.max_factor
        if s == 0.0:
            return min(m, M), 0.0
        a = math.log(M / m) / s
        e1 = m * math.exp(s * s / 2.0) * _phi(a - s) + M * (1.0 - _phi(a))
        e2 = (m * m * math.exp(2.0 * s * s) * _phi(a - 2.0 * s)
              + M * M * (1.0 - _phi(a)))
        return e1, max(e2 - e1 * e1, 0.0)

    @property
    def mean(self) -> float:
        return self.median * math.exp(self.sigma ** 2 / 2)


@dataclass(frozen=True)
class FixedProfile:
    name: str
    value: float

    def sample(self, rng) -> float:
        return self.value

    def sample_batch(self, rng, n: int) -> np.ndarray:
        return np.full(n, self.value)

    def moments(self) -> tuple[float, float]:
        return float(self.value), 0.0

    @property
    def mean(self) -> float:
        return self.value


# ---------------------------------------------------------------------------
# The eight TailBench applications (service-time scales from the paper:
# Table 1 range 10us-10s; relative ordering from Fig. 4's per-app axes).
# ---------------------------------------------------------------------------
TAILBENCH_APPS: dict[str, LogNormalProfile] = {
    # key-value store: tens of microseconds
    "masstree": LogNormalProfile("masstree", 120e-6, 0.35),
    # in-memory OLTP: sub-millisecond
    "silo": LogNormalProfile("silo", 300e-6, 0.40),
    # search over a 15GB index: low milliseconds
    "xapian": LogNormalProfile("xapian", 1.2e-3, 0.50),
    # handwriting recognition: milliseconds
    "img-dnn": LogNormalProfile("img-dnn", 1.5e-3, 0.35),
    # java business middleware: milliseconds
    "specjbb": LogNormalProfile("specjbb", 1.0e-3, 0.45),
    # disk-based OLTP (SSD): several ms, high variance
    "shore": LogNormalProfile("shore", 4.0e-3, 0.70),
    # statistical MT: tens-hundreds of ms
    "moses": LogNormalProfile("moses", 60e-3, 0.55),
    # speech recognition: seconds
    "sphinx": LogNormalProfile("sphinx", 1.0, 0.50),
}


def tailbench_profile(app: str) -> LogNormalProfile:
    return TAILBENCH_APPS[app]


# ---------------------------------------------------------------------------
# Request token-size distributions (shared by both runtime backends)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TokenLengths:
    """Per-request size distribution: log-normal prompt and output token
    counts (median + log-sigma), truncated to [1, max].

    Sampled by ``ClientGenerator`` from a dedicated RNG stream derived
    from the same (seed, client_id, rep) tuple as the arrival stream —
    so the simulator and the engine runtime draw *identical request
    sizes* without perturbing the arrival-time draws."""
    prompt_median: float = 128.0
    prompt_sigma: float = 0.6
    new_median: float = 32.0
    new_sigma: float = 0.5
    prompt_max: int = 2048
    new_max: int = 512

    def sample(self, rng: np.random.Generator) -> tuple[int, int]:
        z1, z2 = rng.standard_normal(2)
        p = self.prompt_median * math.exp(self.prompt_sigma * z1)
        n = self.new_median * math.exp(self.new_sigma * z2)
        return (max(1, min(int(p), self.prompt_max)),
                max(1, min(int(n), self.new_max)))

    def sample_batch(self, rng: np.random.Generator,
                     n: int) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized size draws: same clipped-integer law as ``sample``
        (``max(1, min(int(x), max))`` == clip of the floored draw)."""
        z = rng.standard_normal((2, n))
        p = self.prompt_median * np.exp(self.prompt_sigma * z[0])
        m = self.new_median * np.exp(self.new_sigma * z[1])
        return (np.clip(p.astype(np.int64), 1, self.prompt_max),
                np.clip(m.astype(np.int64), 1, self.new_max))

    @staticmethod
    def int_pmf(median: float, sigma: float,
                vmax: int) -> tuple[np.ndarray, np.ndarray]:
        """(support [1..vmax], pmf) of ``max(1, min(int(X), vmax))``
        for log-normal X, from CDF differences (``vmax`` <= a few
        thousand, evaluated once per compile).  ``sigma == 0`` is a
        point mass — the log-argument division is never taken."""
        ks = np.arange(1, vmax + 1, dtype=float)
        pmf = np.zeros(vmax)
        if sigma == 0.0:
            pmf[max(1, min(int(median), vmax)) - 1] = 1.0
            return ks, pmf
        # P(result <= k) = P(X < k+1) for k < vmax, 1 at vmax
        upper = np.array([_phi(math.log((k + 1.0) / median) / sigma)
                          for k in ks[:-1]] + [1.0])
        return ks, np.diff(np.concatenate([[0.0], upper]))

    @staticmethod
    def _int_moments(median: float, sigma: float,
                     vmax: int) -> tuple[float, float]:
        """Exact (mean, var) of the clipped integer law."""
        ks, pmf = TokenLengths.int_pmf(median, sigma, vmax)
        mean = float(pmf @ ks)
        return mean, max(float(pmf @ (ks * ks)) - mean * mean, 0.0)

    def moments(self) -> tuple[tuple[float, float], tuple[float, float]]:
        """((prompt mean, var), (new-token mean, var)) of the clipped
        integer laws — what the vector runtime's fluid token backlog
        uses."""
        return (self._int_moments(self.prompt_median, self.prompt_sigma,
                                  self.prompt_max),
                self._int_moments(self.new_median, self.new_sigma,
                                  self.new_max))

    @property
    def mean_new_tokens(self) -> float:
        return self.new_median * math.exp(self.new_sigma ** 2 / 2)


# ---------------------------------------------------------------------------
# ServiceModel layer
# ---------------------------------------------------------------------------
def apply_service_noise(dur: float, sigma: float, rng) -> float:
    """Multiplicative log-normal execution noise (interference, GC
    pauses — what hedged requests exploit, Dean & Barroso).  The one
    noise law every backend shares: SimServer and the stub engines must
    perturb service identically or the cross-backend parity the
    ServiceModel layer guarantees silently breaks.  Draws from ``rng``
    only when ``sigma > 0`` (zero noise consumes no stream)."""
    if sigma > 0.0:
        dur *= float(np.exp(sigma * rng.standard_normal()))
    return dur


@dataclass(frozen=True)
class ScalarService:
    """One request = one worker slot for ``profile``-sampled seconds.

    The bit-identical default: wrapping an existing LogNormal/Fixed
    profile changes nothing about how the simulator executes requests —
    the profile is still sampled client-side at generation time and the
    server still runs G/G/c FIFO slots."""
    profile: object
    kind: str = field(default="scalar", init=False)

    def sample(self, rng) -> float:
        return self.profile.sample(rng)

    def sample_batch(self, rng, n: int):
        return self.profile.sample_batch(rng, n)

    def moments(self) -> tuple[float, float]:
        return self.profile.moments()

    @property
    def mean(self) -> float:
        return self.profile.mean

    @property
    def name(self) -> str:
        return getattr(self.profile, "name", "scalar")


@dataclass(frozen=True)
class BatchedService:
    """Continuous-batching service cost model (roofline-calibrated).

    Per decode step the whole batch advances one token:

        step_time(b) = max(t_compute_per_seq * b, t_memory)

    ``t_memory`` is the weight/state streaming time (batch-independent —
    the roofline's memory term), ``t_compute_per_seq`` the per-sequence
    MXU time (the compute term scales with batch).  While memory-bound,
    adding occupancy is nearly free (throughput rises ~linearly); past
    the ridge point the step time grows linearly and per-request latency
    pays for sharing — the sub-linear throughput curve of the real
    engine.  Prefill costs ``t_prefill_per_token * prompt_tokens``
    seconds, floored at one weight pass."""
    name: str
    t_memory: float                      # s per decode step (streaming)
    t_compute_per_seq: float             # s per sequence per decode step
    t_prefill_per_token: float           # s per prompt token
    kind: str = field(default="batched", init=False)

    def step_time(self, batch: int) -> float:
        return max(self.t_compute_per_seq * max(batch, 1), self.t_memory)

    def prefill_time(self, prompt_tokens: int) -> float:
        return max(self.t_prefill_per_token * max(prompt_tokens, 1),
                   self.t_memory)

    def step_time_array(self, batch):
        """``step_time`` as an array op — the roofline step law the
        vector runtime applies per time slot."""
        return np.maximum(self.t_compute_per_seq * np.maximum(batch, 1),
                          self.t_memory)

    def prefill_time_array(self, prompt_tokens):
        return np.maximum(
            self.t_prefill_per_token * np.maximum(prompt_tokens, 1),
            self.t_memory)

    @property
    def ridge_batch(self) -> float:
        """Batch size where the step flips memory- to compute-bound."""
        return self.t_memory / self.t_compute_per_seq

    def service_rate(self, batch: int) -> float:
        """Tokens/sec the whole server sustains at occupancy ``batch``."""
        b = max(batch, 1)
        return b / self.step_time(b)

    @classmethod
    def from_arch(cls, arch: str, *, chips: int = 8) -> "BatchedService":
        """Calibrate from an assigned architecture's roofline terms:
        memory = one pass over the active parameters (2 bytes each) at
        HBM bandwidth, compute = 2*N_active FLOPs per token at bf16 peak
        (prefill is compute-bound at the same per-token cost), spread
        over a ``chips``-chip serving slice."""
        from repro.configs.base import get_config
        from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
        from repro.models import registry as R
        cfg = get_config(arch)
        n_active = R.count_params(cfg, active=True)
        t_mem = 2.0 * n_active / (chips * HBM_BW)
        t_comp = 2.0 * n_active / (chips * PEAK_FLOPS_BF16)
        return cls(f"batched:{arch}", t_mem, t_comp, t_comp)


def resolve_service_model(model, profile) -> "ScalarService | BatchedService":
    """Normalize an Experiment's service model: ``None`` means the
    scalar default wrapping the resolved profile."""
    if model is None:
        return ScalarService(profile)
    return model


# ---------------------------------------------------------------------------
# Shared continuous-batching op sequencer
# ---------------------------------------------------------------------------
@dataclass(slots=True)
class BatchItem:
    """One request inside a ``BatchScheduler`` (key is caller-opaque:
    a ``Request`` in the simulator, a req_id in the stub engine)."""
    key: object
    prompt_tokens: int
    remaining: int                       # new tokens still to emit


class BatchScheduler:
    """Prefill-priority continuous batching, one op at a time.

    Mirrors ``serving.engine.InferenceEngine.step()``: each op is either
    ONE prefill (a waiting request enters a free slot; its first token is
    emitted when the prefill finishes) or ONE batched decode step (every
    active sequence emits one token).  Requests whose token budget is
    exhausted complete at the end of the op that produced their last
    token.

    The class is clock-free: callers ask ``start_op`` for the next op's
    base duration (un-scaled by server speed/noise) and later apply it
    with ``finish_op``.  The simulator drives it from calendar-queue
    events; ``BatchedStubEngine`` drives it from a wall/virtual clock —
    identical dynamics by construction.
    """

    __slots__ = ("service", "max_batch", "waiting", "active", "tokens_done",
                 "op")

    def __init__(self, service: BatchedService, max_batch: int):
        self.service = service
        self.max_batch = max_batch
        self.waiting: deque[BatchItem] = deque()
        self.active: list[BatchItem] = []
        self.tokens_done = 0
        self.op: Optional[tuple] = None          # ("prefill", item) | ("decode",)

    # ---- submission / introspection ---------------------------------------
    def submit(self, key, prompt_tokens: int, max_new_tokens: int) -> None:
        self.waiting.append(BatchItem(key, max(int(prompt_tokens), 1),
                                      max(int(max_new_tokens), 1)))

    def pending(self) -> int:
        return len(self.waiting)

    def occupancy(self) -> int:
        """Sequences resident in the batch (incl. one mid-prefill)."""
        n = len(self.active)
        if self.op is not None and self.op[0] == "prefill":
            n += 1
        return n

    def idle(self) -> bool:
        return self.op is None and not self.waiting and not self.active

    # ---- op lifecycle ------------------------------------------------------
    def start_op(self, skip: Optional[Callable] = None,
                 ready: Optional[Callable] = None) -> Optional[float]:
        """Begin the next op; -> base duration in seconds, or None if
        there is nothing to do.  ``skip(key) -> bool`` drops waiting
        entries (hedge-cancelled twins) without admitting them;
        ``ready(key) -> bool`` holds back entries that have not arrived
        yet at the op's start instant (wall-clock replay) — a not-ready
        FIFO head falls through to a decode op, like the real engine
        seeing an empty queue."""
        if self.op is not None:       # survives python -O, unlike assert
            raise RuntimeError("previous op not finished")
        while self.waiting and len(self.active) < self.max_batch:
            item = self.waiting[0]
            if skip is not None and skip(item.key):
                self.waiting.popleft()
                continue
            if ready is not None and not ready(item.key):
                break
            self.waiting.popleft()
            self.op = ("prefill", item)
            return self.service.prefill_time(item.prompt_tokens)
        if self.active:
            self.op = ("decode", None)
            return self.service.step_time(len(self.active))
        return None

    def finish_op(self) -> list:
        """Apply the current op; -> keys of requests it completed."""
        kind, item = self.op
        self.op = None
        done = []
        if kind == "prefill":
            self.tokens_done += 1
            item.remaining -= 1
            if item.remaining <= 0:
                done.append(item.key)
            else:
                self.active.append(item)
        else:
            self.tokens_done += len(self.active)
            still = []
            for it in self.active:
                it.remaining -= 1
                if it.remaining <= 0:
                    done.append(it.key)
                else:
                    still.append(it)
            self.active = still
        return done

    def abort(self) -> list:
        """Drop every resident request (server failure); -> their keys.
        Waiting entries are the caller's to account for."""
        keys = [it.key for it in self.active]
        if self.op is not None and self.op[0] == "prefill":
            keys.append(self.op[1].key)
        self.active = []
        self.op = None
        return keys


def arch_profile(arch: str, *, tokens_out: int = 64,
                 step_time: float | None = None,
                 batch: int = 8) -> LogNormalProfile:
    """Serving profile for an assigned architecture.

    step_time = per-decode-step seconds for the whole batch (roofline-derived
    via launch.roofline; a fallback table is used if not supplied).  A
    request's demand ~ tokens_out × step_time / batch with log-normal spread
    over output lengths.
    """
    if step_time is None:
        from repro.launch.roofline import decode_step_time_fallback
        step_time = decode_step_time_fallback(arch)
    median = tokens_out * step_time / batch
    return LogNormalProfile(f"arch:{arch}", median, 0.6)
