"""Service-time profiles.

Two kinds of "application" can sit behind a server:

1. The paper's eight TailBench apps, reproduced as calibrated service-time
   distributions.  TailBench spans "very short - large (10us - 10s)"
   (Table 1); per-app medians follow the paper's Fig. 4 latency scales.
   Request work is log-normal around the median (the Zipf-like heavy tail
   the harness is required to preserve) with a deterministic seed stream.

2. The 10 assigned architectures: service time per request derived from the
   dry-run roofline model — max(compute, memory) term of one batched decode
   step at the serving batch, divided across the batch, plus a prefill term
   proportional to prompt length.  See repro/launch/roofline.py.

Both expose ``sample(rng) -> seconds of server work``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class LogNormalProfile:
    """Median service time + heavy right tail (sigma in log space)."""
    name: str
    median: float                  # seconds
    sigma: float = 0.45
    max_factor: float = 30.0       # truncate the tail (bounded work)

    def sample(self, rng: np.random.Generator) -> float:
        x = self.median * math.exp(self.sigma * rng.standard_normal())
        return float(min(x, self.median * self.max_factor))

    def sample_batch(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Vectorized draw (same law as ``sample``, bulk RNG stream)."""
        x = self.median * np.exp(self.sigma * rng.standard_normal(n))
        return np.minimum(x, self.median * self.max_factor)

    @property
    def mean(self) -> float:
        return self.median * math.exp(self.sigma ** 2 / 2)


@dataclass(frozen=True)
class FixedProfile:
    name: str
    value: float

    def sample(self, rng) -> float:
        return self.value

    def sample_batch(self, rng, n: int) -> np.ndarray:
        return np.full(n, self.value)

    @property
    def mean(self) -> float:
        return self.value


# ---------------------------------------------------------------------------
# The eight TailBench applications (service-time scales from the paper:
# Table 1 range 10us-10s; relative ordering from Fig. 4's per-app axes).
# ---------------------------------------------------------------------------
TAILBENCH_APPS: dict[str, LogNormalProfile] = {
    # key-value store: tens of microseconds
    "masstree": LogNormalProfile("masstree", 120e-6, 0.35),
    # in-memory OLTP: sub-millisecond
    "silo": LogNormalProfile("silo", 300e-6, 0.40),
    # search over a 15GB index: low milliseconds
    "xapian": LogNormalProfile("xapian", 1.2e-3, 0.50),
    # handwriting recognition: milliseconds
    "img-dnn": LogNormalProfile("img-dnn", 1.5e-3, 0.35),
    # java business middleware: milliseconds
    "specjbb": LogNormalProfile("specjbb", 1.0e-3, 0.45),
    # disk-based OLTP (SSD): several ms, high variance
    "shore": LogNormalProfile("shore", 4.0e-3, 0.70),
    # statistical MT: tens-hundreds of ms
    "moses": LogNormalProfile("moses", 60e-3, 0.55),
    # speech recognition: seconds
    "sphinx": LogNormalProfile("sphinx", 1.0, 0.50),
}


def tailbench_profile(app: str) -> LogNormalProfile:
    return TAILBENCH_APPS[app]


def arch_profile(arch: str, *, tokens_out: int = 64,
                 step_time: float | None = None,
                 batch: int = 8) -> LogNormalProfile:
    """Serving profile for an assigned architecture.

    step_time = per-decode-step seconds for the whole batch (roofline-derived
    via launch.roofline; a fallback table is used if not supplied).  A
    request's demand ~ tokens_out × step_time / batch with log-normal spread
    over output lengths.
    """
    if step_time is None:
        from repro.launch.roofline import decode_step_time_fallback
        step_time = decode_step_time_fallback(arch)
    median = tokens_out * step_time / batch
    return LogNormalProfile(f"arch:{arch}", median, 0.6)
