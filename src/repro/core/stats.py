"""Latency statistics: percentile recorder + Welch's t-test (no scipy).

The recorder groups completed-request latencies per (client, interval) and
produces the paper's metrics: mean / p95 / p99 per interval and per client,
with 95% confidence intervals across repetitions (Figs. 5-7).
Welch's t-test (Table 4) validates that harness changes don't perturb
application behavior; the t CDF uses the regularized incomplete beta
function (continued fraction, Numerical-Recipes style).
"""
from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Iterable, Optional

import numpy as np


# ---------------------------------------------------------------------------
# Welch's t-test
# ---------------------------------------------------------------------------
def _betacf(a: float, b: float, x: float) -> float:
    MAXIT, EPS, FPMIN = 200, 3e-9, 1e-30
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c, d = 1.0, 1.0 - qab * x / qap
    if abs(d) < FPMIN:
        d = FPMIN
    d = 1.0 / d
    h = d
    for m in range(1, MAXIT + 1):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < FPMIN:
            d = FPMIN
        c = 1.0 + aa / c
        if abs(c) < FPMIN:
            c = FPMIN
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < FPMIN:
            d = FPMIN
        c = 1.0 + aa / c
        if abs(c) < FPMIN:
            c = FPMIN
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < EPS:
            break
    return h


def _betai(a: float, b: float, x: float) -> float:
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    ln_bt = (math.lgamma(a + b) - math.lgamma(a) - math.lgamma(b)
             + a * math.log(x) + b * math.log(1.0 - x))
    bt = math.exp(ln_bt)
    if x < (a + 1.0) / (a + b + 2.0):
        return bt * _betacf(a, b, x) / a
    return 1.0 - bt * _betacf(b, a, 1.0 - x) / b


def t_sf(t: float, df: float) -> float:
    """Two-sided survival P(|T| >= t) for Student's t."""
    x = df / (df + t * t)
    return _betai(df / 2.0, 0.5, x)


@dataclass
class WelchResult:
    t_stat: float
    p_value: float
    df: float

    @property
    def significant(self) -> bool:
        return self.p_value < 0.05


def welch_ttest(a: Iterable[float], b: Iterable[float]) -> WelchResult:
    a, b = np.asarray(list(a), float), np.asarray(list(b), float)
    na, nb = len(a), len(b)
    va, vb = a.var(ddof=1) / na, b.var(ddof=1) / nb
    denom = math.sqrt(max(va + vb, 1e-300))
    t = (a.mean() - b.mean()) / denom
    df = (va + vb) ** 2 / max(va ** 2 / (na - 1) + vb ** 2 / (nb - 1), 1e-300)
    return WelchResult(t, t_sf(abs(t), df), df)


# ---------------------------------------------------------------------------
# Latency recorder
# ---------------------------------------------------------------------------
def pctl(xs, q: float) -> float:
    if len(xs) == 0:
        return float("nan")
    return float(np.percentile(np.asarray(xs, float), q))


@dataclass
class Summary:
    n: int
    mean: float
    p50: float
    p95: float
    p99: float

    @classmethod
    def of(cls, xs) -> "Summary":
        xs = np.asarray(list(xs), float)
        if len(xs) == 0:
            return cls(0, *(float("nan"),) * 4)
        return cls(len(xs), float(xs.mean()), *(float(np.percentile(xs, q))
                                                for q in (50, 95, 99)))


class LatencyRecorder:
    """Streams completed requests into per-client / per-interval buckets."""

    def __init__(self, interval: float = 1.0):
        self.interval = interval
        self.by_client: dict[int, list] = defaultdict(list)
        self.by_cell: dict[tuple, list] = defaultdict(list)   # (client, ivl)
        self.all: list[float] = []
        self.queue_times: list[float] = []
        self.service_times: list[float] = []

    def record(self, req) -> None:
        lat = req.sojourn
        ivl = int(req.completed / self.interval)
        self.by_client[req.client_id].append(lat)
        self.by_cell[(req.client_id, ivl)].append(lat)
        self.all.append(lat)
        self.queue_times.append(req.queue_time)
        self.service_times.append(req.service_time)

    # ------- summaries ------------------------------------------------------
    def overall(self) -> Summary:
        return Summary.of(self.all)

    def client(self, cid: int) -> Summary:
        return Summary.of(self.by_client.get(cid, []))

    def intervals(self, cid: Optional[int] = None) -> dict[int, Summary]:
        out: dict[int, list] = defaultdict(list)
        for (c, ivl), xs in self.by_cell.items():
            if cid is None or c == cid:
                out[ivl].extend(xs)
        return {ivl: Summary.of(xs) for ivl, xs in sorted(out.items())}

    def clients(self) -> list[int]:
        return sorted(self.by_client)


def confidence95(xs) -> tuple[float, float]:
    """Mean and 95% CI half-width across repetitions (paper's error bars)."""
    xs = np.asarray(list(xs), float)
    if len(xs) < 2:
        return float(xs.mean()) if len(xs) else float("nan"), 0.0
    half = 1.96 * xs.std(ddof=1) / math.sqrt(len(xs))
    return float(xs.mean()), float(half)
