"""Latency statistics: percentile recorder + Welch's t-test (no scipy).

The recorder groups completed-request latencies per (client, interval) and
produces the paper's metrics: mean / p95 / p99 per interval and per client,
with 95% confidence intervals across repetitions (Figs. 5-7).
Welch's t-test (Table 4) validates that harness changes don't perturb
application behavior; the t CDF uses the regularized incomplete beta
function (continued fraction, Numerical-Recipes style).

Two recorder modes:

* ``exact`` (default) — keeps every latency sample, percentiles via
  ``np.percentile``.  Bit-compatible with the original recorder; all the
  figure scripts use it.
* ``streaming`` — O(1) memory per stream: P² quantile markers
  (Jain & Chlamtac 1985) for the overall p50/p95/p99 plus bounded
  reservoir samples per client / interval / (client, interval) cell.
  This is the 10k-server / multi-million-request path: memory no longer
  grows with request count ("Sampling in Cloud Benchmarking" — percentiles
  from sound bounded collection instead of unbounded ad-hoc lists).
"""
from __future__ import annotations

import math
from collections import OrderedDict, defaultdict
from dataclasses import dataclass
from typing import Iterable, Optional

import numpy as np


# ---------------------------------------------------------------------------
# Welch's t-test
# ---------------------------------------------------------------------------
def _betacf(a: float, b: float, x: float) -> float:
    MAXIT, EPS, FPMIN = 200, 3e-9, 1e-30
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c, d = 1.0, 1.0 - qab * x / qap
    if abs(d) < FPMIN:
        d = FPMIN
    d = 1.0 / d
    h = d
    for m in range(1, MAXIT + 1):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < FPMIN:
            d = FPMIN
        c = 1.0 + aa / c
        if abs(c) < FPMIN:
            c = FPMIN
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < FPMIN:
            d = FPMIN
        c = 1.0 + aa / c
        if abs(c) < FPMIN:
            c = FPMIN
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < EPS:
            break
    return h


def _betai(a: float, b: float, x: float) -> float:
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    ln_bt = (math.lgamma(a + b) - math.lgamma(a) - math.lgamma(b)
             + a * math.log(x) + b * math.log(1.0 - x))
    bt = math.exp(ln_bt)
    if x < (a + 1.0) / (a + b + 2.0):
        return bt * _betacf(a, b, x) / a
    return 1.0 - bt * _betacf(b, a, 1.0 - x) / b


def t_sf(t: float, df: float) -> float:
    """Two-sided survival P(|T| >= t) for Student's t."""
    if not (df > 0.0) or math.isnan(t):
        return float("nan")
    x = df / (df + t * t)
    return _betai(df / 2.0, 0.5, x)


@dataclass
class WelchResult:
    t_stat: float
    p_value: float
    df: float

    @property
    def significant(self) -> bool:
        return self.p_value < 0.05


def welch_ttest(a: Iterable[float], b: Iterable[float]) -> WelchResult:
    """Welch's unequal-variance t-test.

    Degenerate inputs return NaN statistics instead of raising or
    producing garbage: fewer than two samples on either side leaves the
    variance undefined, and two zero-variance samples make the t statistic
    0 (equal means) or ±inf (different means) with an exact p-value.
    """
    a, b = np.asarray(list(a), float), np.asarray(list(b), float)
    na, nb = len(a), len(b)
    if na < 2 or nb < 2:
        return WelchResult(float("nan"), float("nan"), float("nan"))
    va, vb = a.var(ddof=1) / na, b.var(ddof=1) / nb
    diff = float(a.mean() - b.mean())
    if va + vb == 0.0:
        if diff == 0.0:
            return WelchResult(0.0, 1.0, float(na + nb - 2))
        return WelchResult(math.copysign(float("inf"), diff), 0.0,
                           float(na + nb - 2))
    denom = math.sqrt(va + vb)
    t = diff / denom
    df = (va + vb) ** 2 / max(va ** 2 / (na - 1) + vb ** 2 / (nb - 1), 1e-300)
    return WelchResult(t, t_sf(abs(t), df), df)


# ---------------------------------------------------------------------------
# Streaming estimators (P² + reservoir)
# ---------------------------------------------------------------------------
class P2Quantile:
    """Jain & Chlamtac's P² single-quantile estimator: five markers,
    O(1) memory, piecewise-parabolic height adjustment per observation."""

    __slots__ = ("q", "n", "_h", "_pos", "_want", "_dwant")

    def __init__(self, q: float):
        self.q = q
        self.n = 0
        self._h: list[float] = []            # marker heights
        self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
        self._want = [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0]
        self._dwant = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]

    def add(self, x: float) -> None:
        self.n += 1
        h = self._h
        if self.n <= 5:
            h.append(x)
            if self.n == 5:
                h.sort()
            return
        pos, want, dwant = self._pos, self._want, self._dwant
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while x >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            pos[i] += 1.0
        for i in range(5):
            want[i] += dwant[i]
        for i in (1, 2, 3):
            d = want[i] - pos[i]
            if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or \
               (d <= -1.0 and pos[i - 1] - pos[i] < -1.0):
                d = 1.0 if d > 0 else -1.0
                # piecewise-parabolic prediction
                hp = h[i] + d / (pos[i + 1] - pos[i - 1]) * (
                    (pos[i] - pos[i - 1] + d) * (h[i + 1] - h[i])
                    / (pos[i + 1] - pos[i])
                    + (pos[i + 1] - pos[i] - d) * (h[i] - h[i - 1])
                    / (pos[i] - pos[i - 1]))
                if h[i - 1] < hp < h[i + 1]:
                    h[i] = hp
                else:                         # fall back to linear
                    j = i + (1 if d > 0 else -1)
                    h[i] = h[i] + d * (h[j] - h[i]) / (pos[j] - pos[i])
                pos[i] += d

    def value(self) -> float:
        if self.n == 0:
            return float("nan")
        if self.n <= 5:
            return float(np.percentile(np.asarray(self._h, float),
                                       self.q * 100.0))
        return self._h[2]


class ReservoirSample:
    """Vitter's Algorithm R: uniform fixed-size sample of an unbounded
    stream.  Exact (holds everything) while n <= k.

    ``rand`` lets many reservoirs share one RNG: a private generator per
    reservoir carries its own state block, which dominates memory when a
    recorder holds one reservoir per (client, interval) cell.  The
    default stream is a seeded ``np.random.Generator`` keyed by a
    domain tag so it can never collide with the simulation's own
    ``(seed, entity_id, rep)`` streams."""

    __slots__ = ("k", "n", "data", "_rand")

    def __init__(self, k: int = 256, seed: int = 0x5EED, rand=None):
        self.k = k
        self.n = 0
        self.data: list[float] = []
        self._rand = rand if rand is not None else \
            np.random.default_rng((0x512E, int(seed))).random

    def add(self, x: float) -> None:
        n = self.n = self.n + 1
        if n <= self.k:
            self.data.append(x)
        else:
            j = int(self._rand() * n)
            if j < self.k:
                self.data[j] = x


class StreamingStat:
    """Bounded-memory latency stream: count/mean exactly, percentiles via
    P² (when enabled) with a reservoir fallback that is exact for small n."""

    __slots__ = ("n", "total", "res", "p2")

    def __init__(self, reservoir_k: int = 256, use_p2: bool = False,
                 seed: int = 0x5EED, rand=None):
        self.n = 0
        self.total = 0.0
        self.res = ReservoirSample(reservoir_k, seed, rand=rand)
        self.p2 = (P2Quantile(0.50), P2Quantile(0.95), P2Quantile(0.99)) \
            if use_p2 else None

    def add(self, x: float) -> None:
        self.n += 1
        self.total += x
        self.res.add(x)
        if self.p2 is not None:
            p50, p95, p99 = self.p2
            p50.add(x)
            p95.add(x)
            p99.add(x)

    def summary(self) -> "Summary":
        if self.n == 0:
            return Summary.empty()
        mean = self.total / self.n
        if self.p2 is not None and self.n > self.res.k:
            return Summary(self.n, mean, self.p2[0].value(),
                           self.p2[1].value(), self.p2[2].value())
        xs = np.asarray(self.res.data, float)
        p50, p95, p99 = np.percentile(xs, (50, 95, 99))
        return Summary(self.n, mean, float(p50), float(p95), float(p99))


# ---------------------------------------------------------------------------
# Latency recorder
# ---------------------------------------------------------------------------
def _as_float_array(xs) -> np.ndarray:
    """Float ndarray view of a sample collection.  ndarrays (and lists)
    convert directly; only opaque iterables pay the materializing copy."""
    if not isinstance(xs, (np.ndarray, list, tuple)):
        xs = list(xs)
    return np.asarray(xs, float)


def pctl(xs, q: float) -> float:
    if len(xs) == 0:
        return float("nan")
    return float(np.percentile(_as_float_array(xs), q))


#: (n, qs) -> (kth, lo, hi, t): the order-statistic plan for one sample
#: size.  ``np.unique(np.concatenate([lo, hi]))`` costs more than the
#: partition itself when called once per grid cell x interval, and the
#: vector runtime asks for the same fixed (50, 95, 99) tuple at a small
#: set of sizes — hoist the plan and reuse it.  A capped LRU (oldest
#: entry out, not a wholesale clear): soak-scale sweeps touch an
#: unbounded set of sample sizes, and the plan is a pure function of
#: its key, so eviction can only ever cost a recompute — never a bit.
_QPLAN_CACHE: OrderedDict = OrderedDict()
_QPLAN_CACHE_CAP = 4096


def _quantile_plan(n: int, qs: tuple) -> tuple:
    key = (n, qs)
    plan = _QPLAN_CACHE.get(key)
    if plan is None:
        pos = np.asarray(qs, float) / 100.0 * (n - 1)
        lo = np.floor(pos).astype(np.intp)
        hi = np.ceil(pos).astype(np.intp)
        kth = np.unique(np.concatenate([lo, hi]))
        plan = _QPLAN_CACHE[key] = (kth, lo, hi, pos - lo)
        while len(_QPLAN_CACHE) > _QPLAN_CACHE_CAP:
            _QPLAN_CACHE.popitem(last=False)
    else:
        _QPLAN_CACHE.move_to_end(key)
    return plan


def quantiles_partition(xs, qs) -> np.ndarray:
    """``np.percentile``-style linear-interpolation quantiles via ONE
    ``np.partition`` pass: partially sorts only the floor/ceil order
    statistics of every requested quantile — O(n) instead of the full
    O(n log n) sort, and one pass for all quantiles.  This is the
    vector-runtime extraction path (one call per grid cell)."""
    xs = np.asarray(xs, float)
    n = xs.size
    if n == 0:
        return np.full(np.asarray(qs, float).shape, float("nan"))
    kth, lo, hi, t = _quantile_plan(n, tuple(float(q) for q in qs))
    part = np.partition(xs, kth)
    a, b = part[lo], part[hi]
    # numpy's lerp: anchor on the nearer endpoint for t >= 0.5
    out = a + (b - a) * t
    flip = t >= 0.5
    out[flip] = b[flip] - (b[flip] - a[flip]) * (1.0 - t[flip])
    return out


def quantiles_partition_batched(mat: np.ndarray, counts,
                                qs) -> np.ndarray:
    """Row-wise ``quantiles_partition`` over a padded ``[C, K]`` matrix
    (row ``i`` holds ``counts[i]`` valid samples, padding beyond).  Runs
    the SAME partition + lerp per row, so its output is bit-for-bit the
    scalar path's — the contract the vector runtime's fused extraction
    relies on (and a test asserts)."""
    counts = np.asarray(counts)
    qs = tuple(float(q) for q in qs)
    out = np.full((counts.size, len(qs)), float("nan"))
    for i, n in enumerate(counts):
        if n:
            out[i] = quantiles_partition(mat[i, :int(n)], qs)
    return out


def slo_violation_frac(xs, slo: Optional[float], n_bad: int = 0) -> float:
    """Fraction of requests violating ``slo``.  ``n_bad`` counts
    requests that never produced a latency sample — shed, timed out, or
    failed after retries — every one of which IS a violation: a 100%-
    shed interval must report 1.0, not the 0.0 the served-only math
    used to produce.  The empty contract is the same as
    ``Summary.of``/``pctl``: no SLO, or no samples AND no failures ->
    NaN (one code path — ``IntervalFrame`` math must not special-case
    emptiness on its own)."""
    if slo is None or (len(xs) == 0 and n_bad == 0):
        return float("nan")
    if len(xs) == 0:
        return 1.0
    xs = _as_float_array(xs)
    return (float(np.count_nonzero(xs > slo)) + n_bad) / (xs.size + n_bad)


@dataclass
class Summary:
    n: int
    mean: float
    p50: float
    p95: float
    p99: float

    @classmethod
    def empty(cls) -> "Summary":
        """The one empty-input summary every code path shares."""
        return cls(0, *(float("nan"),) * 4)

    @classmethod
    def of(cls, xs) -> "Summary":
        xs = _as_float_array(xs)
        if xs.size == 0:
            return cls.empty()
        # all three quantiles in one vectorized call — this sits on the
        # per-interval hot path of every figure sweep
        p50, p95, p99 = np.percentile(xs, (50, 95, 99))
        return cls(int(xs.size), float(xs.mean()),
                   float(p50), float(p95), float(p99))


class LatencyRecorder:
    """Streams completed requests into per-client / per-interval buckets.

    ``mode="exact"`` keeps raw samples (bit-compatible with the figure
    scripts — no RNG is ever constructed or drawn in this mode);
    ``mode="streaming"`` keeps bounded P²/reservoir state only, with the
    reservoir RNG keyed by ``(0x5EED, seed, rep)`` so repetitions
    subsample independently instead of replaying one stream.
    """

    def __init__(self, interval: float = 1.0, mode: str = "exact",
                 reservoir_k: int = 256, seed: int = 0, rep: int = 0):
        if mode not in ("exact", "streaming"):
            raise ValueError(f"unknown recorder mode: {mode!r}")
        self.interval = interval
        self.mode = mode
        # disposition accounting (both modes): requests that ended
        # WITHOUT a latency sample — shed at admission, timed out, or
        # destroyed by a failure — are first-class rows here, never
        # silently absent from the statistics.  Plain counters: O(1)
        # memory, zero cost on the record() hot path.
        self.failures = {"shed": 0, "timeout": 0, "failed": 0}
        self.fail_by_ivl: dict[int, dict] = {}
        if mode == "exact":
            # raw-sample storage; deliberately NOT created in streaming mode
            # so stale consumers fail loudly instead of reading empty lists
            self.by_client: dict[int, list] = defaultdict(list)
            self.by_cell: dict[tuple, list] = defaultdict(list)  # (client, ivl)
            self.all: list[float] = []
            self.queue_times: list[float] = []
            self.service_times: list[float] = []
        if mode == "streaming":
            # one shared RNG for every reservoir this recorder owns,
            # domain-tagged and keyed by (seed, rep)
            self._rand = np.random.default_rng(
                (0x5EED, int(seed), int(rep))).random
            self._all = StreamingStat(reservoir_k=4096, use_p2=True,
                                      rand=self._rand)
            self._by_client: dict[int, StreamingStat] = {}
            self._by_ivl: dict[int, StreamingStat] = {}
            self._by_cell: dict[tuple, StreamingStat] = {}
            self._queue = StreamingStat(reservoir_k, rand=self._rand)
            self._service = StreamingStat(reservoir_k, rand=self._rand)
            self._k = reservoir_k
            self.record = self._record_streaming    # hot-path dispatch

    def record(self, req) -> None:                  # exact mode
        # inlined req.sojourn/queue_time/service_time: every recorded
        # request has all timestamps set, and this sits on the hot path
        completed = req.completed
        started = req.started
        lat = completed - req.created
        cid = req.client_id
        self.by_client[cid].append(lat)
        self.by_cell[(cid, int(completed / self.interval))].append(lat)
        self.all.append(lat)
        self.queue_times.append(started - req.enqueued)
        self.service_times.append(completed - started)

    def _record_streaming(self, req) -> None:
        completed = req.completed
        started = req.started
        lat = completed - req.created
        cid = req.client_id
        ivl = int(completed / self.interval)
        self._all.add(lat)
        rand = self._rand
        stat = self._by_client.get(cid)
        if stat is None:
            stat = self._by_client[cid] = StreamingStat(self._k, rand=rand)
        stat.add(lat)
        stat = self._by_ivl.get(ivl)
        if stat is None:
            stat = self._by_ivl[ivl] = StreamingStat(self._k, rand=rand)
        stat.add(lat)
        key = (cid, ivl)
        stat = self._by_cell.get(key)
        if stat is None:
            stat = self._by_cell[key] = StreamingStat(self._k, rand=rand)
        stat.add(lat)
        self._queue.add(started - req.enqueued)
        self._service.add(completed - started)

    # ------- dispositions ---------------------------------------------------
    def record_failure(self, t: float, disposition: str) -> None:
        """Account one request that will never complete: ``"shed"``
        (admission control refused it), ``"timeout"`` (the client gave
        up; retries exhausted or budget-denied), or ``"failed"`` (lost
        to a server failure).  ``t`` is the disposition time — the
        request counts against that interval's SLO fraction."""
        if disposition not in self.failures:
            raise ValueError(f"unknown disposition {disposition!r}; "
                             f"known: {', '.join(self.failures)}")
        self.failures[disposition] += 1
        ivl = int(t / self.interval)
        cell = self.fail_by_ivl.get(ivl)
        if cell is None:
            cell = self.fail_by_ivl[ivl] = \
                {"shed": 0, "timeout": 0, "failed": 0}
        cell[disposition] += 1

    def failed_total(self) -> int:
        return (self.failures["shed"] + self.failures["timeout"]
                + self.failures["failed"])

    # ------- summaries ------------------------------------------------------
    def overall(self) -> Summary:
        if self.mode == "streaming":
            return self._all.summary()
        return Summary.of(self.all)

    def client(self, cid: int) -> Summary:
        if self.mode == "streaming":
            stat = self._by_client.get(cid)
            return stat.summary() if stat else Summary.of([])
        return Summary.of(self.by_client.get(cid, []))

    def intervals(self, cid: Optional[int] = None) -> dict[int, Summary]:
        if self.mode == "streaming":
            if cid is None:
                return {ivl: s.summary()
                        for ivl, s in sorted(self._by_ivl.items())}
            return {ivl: s.summary()
                    for (c, ivl), s in sorted(self._by_cell.items())
                    if c == cid}
        out: dict[int, list] = defaultdict(list)
        for (c, ivl), xs in self.by_cell.items():
            if cid is None or c == cid:
                out[ivl].extend(xs)
        return {ivl: Summary.of(xs) for ivl, xs in sorted(out.items())}

    def clients(self) -> list[int]:
        if self.mode == "streaming":
            return sorted(self._by_client)
        return sorted(self.by_client)


# ---------------------------------------------------------------------------
# Metrics pipeline: per-interval time series over a LatencyRecorder
# ---------------------------------------------------------------------------
@dataclass
class IntervalFrame:
    """One interval of the run's time series ("Tell-Tale Tail Latencies":
    tail numbers are only interpretable next to their per-interval series)."""
    t: int                          # interval index (t*interval .. (t+1)*interval)
    n: int                          # requests completed in the interval
    qps: float                      # served throughput (n / interval)
    mean: float
    p50: float
    p95: float
    p99: float
    slo_violation_frac: float       # fraction of latencies > slo (nan: no SLO)
    # server_id -> fraction of capacity consumed by service work INITIATED
    # this interval (busy_time accrues at request start, clipped to 1.0);
    # exact for service times << interval, leads true occupancy by up to
    # one service time otherwise
    util: dict
    qdepth: dict                    # server_id -> queued requests (sampled)
    # server_id -> resident-batch (or busy-slot) fraction at the sample
    # point — for batched servers this is the continuous-batching
    # occupancy the knee depends on, distinct from the util time-average
    occupancy: dict
    # server_id -> generated tokens/sec over the interval; only servers
    # that count tokens (batched ServiceModels) appear here
    tokens_per_sec: dict
    # disposition counts: requests that ended this interval WITHOUT a
    # latency sample (they count into slo_violation_frac, not into n)
    n_shed: int = 0
    n_timeout: int = 0
    n_failed: int = 0


class MetricsPipeline:
    """Time-series telemetry over a ``LatencyRecorder``.

    Both runtimes (virtual-time ``Simulator`` and wall-clock
    ``EngineRuntime``) publish through this one interface:

    * latency summaries delegate verbatim to the underlying recorder, so
      consumers that switch from ``sim.recorder.X`` to ``sim.telemetry.X``
      see bit-identical numbers (the figure scripts rely on this);
    * per-server gauges (utilization, queue depth) are sampled by the
      runtime at interval boundaries via ``sample_servers``;
    * ``frames()`` joins both into per-interval ``IntervalFrame`` rows
      (served QPS, windowed percentiles, SLO-violation fraction).

    In streaming-recorder mode the per-interval percentiles and SLO
    fractions come from the bounded reservoir samples (approximate); in
    exact mode they are computed from the raw per-cell latency lists.
    """

    def __init__(self, recorder: "LatencyRecorder", interval: float = 1.0,
                 slo: Optional[float] = None):
        self.recorder = recorder
        self.interval = interval
        self.slo = slo
        # ivl -> server_id -> (util, queue_depth, occupancy, tokens/sec),
        # sampled at the *end* of each interval by the owning runtime
        self._gauges: dict[int, dict[int, tuple]] = {}
        self._busy_time: dict[int, float] = {}      # last busy_time reading
        self._tokens: dict[int, float] = {}         # last tokens_done reading
        # memoization: frames()/series()/window() rebuild the full
        # interval aggregation; windowed consumers (fig6/7-style sweeps)
        # call them once per window.  Caches are keyed on a revision —
        # recorded-sample count plus a gauge version — so any record()
        # or sample_servers() invalidates them without touching the
        # recorder's hot path (counts are O(1) reads, not write hooks).
        self._gauge_ver = 0
        self._series_cache: dict = {}               # cid -> (rev, series)
        self._frames_cache: Optional[tuple] = None  # (rev, frames)

    def _rev(self) -> tuple:
        rec = self.recorder
        n = len(rec.all) if rec.mode == "exact" else rec._all.n
        return n, rec.failed_total(), self._gauge_ver

    # ---- runtime-facing ----------------------------------------------------
    def sample_servers(self, t: float, servers) -> None:
        """Record per-server gauges at time ``t`` (an interval boundary).

        ``servers`` is any iterable of objects with ``server_id``,
        ``workers``/``max_batch`` capacity, and busy/queue accounting
        (``SimServer`` and the engine-runtime server handles both fit).
        Servers exposing a cumulative ``busy_time`` get time-averaged
        utilization over the interval; otherwise the instantaneous
        busy-worker fraction at the sample point is used.
        """
        ivl = int(round(t / self.interval)) - 1     # gauge closes interval t-1
        snap = {}
        for s in servers:
            # capacity: ``workers`` when the server declares worker slots
            # (0 is a real answer — zero capacity, not "ask max_batch"),
            # else ``max_batch`` for batch-slot servers, else 1
            cap = getattr(s, "workers", None)
            if cap is None:
                cap = getattr(s, "max_batch", None)
            if cap is None:
                cap = 1
            busy = s.busy if hasattr(s, "busy") else s.load()
            toks = getattr(s, "tokens_done", None)
            bt = getattr(s, "busy_time", None)
            # servers declaring ``serializes_ops`` run one op at a time
            # (the continuous-batching serve loop), so busy_time
            # normalizes per server; otherwise busy_time accrues across
            # ``cap`` parallel slots.  Declared explicitly — a token
            # counter's presence says nothing about scheduling semantics.
            util_cap = 1 if getattr(s, "serializes_ops", False) else cap
            if bt is not None and util_cap:
                delta = bt - self._busy_time.get(s.server_id, 0.0)
                self._busy_time[s.server_id] = bt
                util = min(max(delta / (self.interval * util_cap), 0.0), 1.0)
            else:
                util = min(busy / util_cap, 1.0) if util_cap else 0.0
            occ = min(busy / cap, 1.0) if cap else 0.0
            if toks is None:
                rate = None
            else:
                rate = (toks - self._tokens.get(s.server_id, 0.0)) \
                    / self.interval
                self._tokens[s.server_id] = toks
            snap[s.server_id] = (util, max(s.load() - busy, 0), occ, rate)
        self._gauges[ivl] = snap
        self._gauge_ver += 1

    # ---- latency accessors (bit-compatible with the recorder) --------------
    def overall(self) -> Summary:
        return self.recorder.overall()

    def client(self, cid: int) -> Summary:
        return self.recorder.client(cid)

    def clients(self) -> list:
        return self.recorder.clients()

    def series(self, cid: Optional[int] = None) -> dict:
        """Per-interval latency summaries (delegates to the recorder;
        memoized until the next recorded sample)."""
        rev = self._rev()[0]
        hit = self._series_cache.get(cid)
        if hit is not None and hit[0] == rev:
            return hit[1]
        out = self.recorder.intervals(cid)
        self._series_cache[cid] = (rev, out)
        return out

    def window(self, metric: str, lo: int = 0, hi: Optional[int] = None,
               cid: Optional[int] = None) -> list:
        """Raw per-interval values of ``metric`` over [lo, hi) — the
        building block the figure scripts' window statistics use."""
        return [getattr(s, metric) for t, s in self.series(cid).items()
                if t >= lo and (hi is None or t < hi)]

    # ---- time series -------------------------------------------------------
    def _interval_samples(self) -> dict[int, list]:
        rec = self.recorder
        out: dict[int, list] = defaultdict(list)
        if rec.mode == "exact":
            for (c, ivl), xs in rec.by_cell.items():
                out[ivl].extend(xs)
        else:
            for ivl, stat in rec._by_ivl.items():
                out[ivl] = stat.res.data
        return out

    def frames(self) -> list[IntervalFrame]:
        rev = self._rev()
        if self._frames_cache is not None and self._frames_cache[0] == rev:
            return self._frames_cache[1]
        samples = self._interval_samples()
        series = self.series()
        fails = self.recorder.fail_by_ivl
        ivls = sorted(set(series) | set(self._gauges) | set(fails))
        frames = []
        for ivl in ivls:
            s = series.get(ivl)
            xs = samples.get(ivl, [])
            cell = fails.get(ivl, {})
            n_bad = sum(cell.values())
            viol = slo_violation_frac(xs, self.slo, n_bad=n_bad)
            gauges = self._gauges.get(ivl, {})
            util = {sid: g[0] for sid, g in gauges.items()}
            qdepth = {sid: g[1] for sid, g in gauges.items()}
            occupancy = {sid: g[2] for sid, g in gauges.items()}
            tokens = {sid: g[3] for sid, g in gauges.items()
                      if g[3] is not None}
            if s is None:
                s = Summary.empty()
            frames.append(IntervalFrame(
                t=ivl, n=s.n, qps=s.n / self.interval, mean=s.mean,
                p50=s.p50, p95=s.p95, p99=s.p99, slo_violation_frac=viol,
                util=util, qdepth=qdepth, occupancy=occupancy,
                tokens_per_sec=tokens, n_shed=cell.get("shed", 0),
                n_timeout=cell.get("timeout", 0),
                n_failed=cell.get("failed", 0)))
        self._frames_cache = (rev, frames)
        return frames

    def to_rows(self) -> list[dict]:
        """Flat dict rows (CSV-friendly) of the interval time series."""
        rows = []
        for f in self.frames():
            mean_util = (sum(f.util.values()) / len(f.util)
                         if f.util else float("nan"))
            mean_occ = (sum(f.occupancy.values()) / len(f.occupancy)
                        if f.occupancy else float("nan"))
            rows.append({"t": f.t, "n": f.n, "qps": f.qps,
                         "mean_ms": f.mean * 1e3, "p50_ms": f.p50 * 1e3,
                         "p95_ms": f.p95 * 1e3, "p99_ms": f.p99 * 1e3,
                         "slo_violation_frac": f.slo_violation_frac,
                         "n_shed": f.n_shed, "n_timeout": f.n_timeout,
                         "n_failed": f.n_failed,
                         "mean_util": mean_util,
                         "mean_occupancy": mean_occ,
                         "tokens_per_sec": sum(f.tokens_per_sec.values()),
                         "total_qdepth": sum(f.qdepth.values())
                                         if f.qdepth else 0})
        return rows


def confidence95(xs) -> tuple[float, float]:
    """Mean and 95% CI half-width across repetitions (paper's error bars).

    Degenerate inputs yield NaN rather than a misleading zero-width CI:
    no samples -> (nan, nan); one sample -> (mean, nan).
    """
    xs = np.asarray(list(xs), float)
    if len(xs) == 0:
        return float("nan"), float("nan")
    if len(xs) == 1:
        return float(xs[0]), float("nan")
    half = 1.96 * xs.std(ddof=1) / math.sqrt(len(xs))
    return float(xs.mean()), float(half)
