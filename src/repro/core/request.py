"""Request/response records shared by the simulator and real-engine paths."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass
class Request:
    req_id: int
    client_id: int
    created: float                  # generation time at the client
    service_demand: float           # seconds of server work (profile sample)
    server_id: Optional[int] = None
    enqueued: Optional[float] = None
    started: Optional[float] = None
    completed: Optional[float] = None
    hedged: bool = False

    @property
    def queue_time(self) -> float:
        return (self.started or 0.0) - (self.enqueued or self.created)

    @property
    def service_time(self) -> float:
        return (self.completed or 0.0) - (self.started or 0.0)

    @property
    def sojourn(self) -> float:
        """End-to-end latency (the paper's reported metric)."""
        return (self.completed or 0.0) - self.created
