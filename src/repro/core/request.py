"""Request/response records shared by the simulator and real-engine paths.

``slots=True`` matters at scale: a 10k-server, 1M-request run holds
millions of these; slots halve the per-object footprint and speed up the
attribute access on the simulator hot path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(slots=True)
class Request:
    req_id: int
    client_id: int
    created: float                  # generation time at the client
    service_demand: float           # seconds of server work (profile sample)
    # token-size semantics (batched ServiceModels): sampled client-side
    # from per-app length distributions so both runtime backends consume
    # identical request sizes.  0 = unsized (scalar service path).
    prompt_tokens: int = 0
    max_new_tokens: int = 0
    server_id: Optional[int] = None
    enqueued: Optional[float] = None
    started: Optional[float] = None
    completed: Optional[float] = None
    hedged: bool = False
    # O(1) hedge cancellation: a started twin tombstones its queued copy
    # instead of scanning the server queue (the queue skips it on pop).
    cancelled: bool = False
    _twin: Optional["Request"] = None      # mutual cancellation on start
    _primary: Optional["Request"] = None   # hedge clone credits the primary
    _recorded: bool = False

    @property
    def queue_time(self) -> float:
        return (self.started or 0.0) - (self.enqueued or self.created)

    @property
    def service_time(self) -> float:
        return (self.completed or 0.0) - (self.started or 0.0)

    @property
    def sojourn(self) -> float:
        """End-to-end latency (the paper's reported metric)."""
        return (self.completed or 0.0) - self.created
