"""Deterministic discrete-event simulator for multi-client/multi-server runs.

Implements the TailBench++ server semantics:
  Feature 1 — servers admit new client connections at any time
  Feature 2 — servers persist at zero connected clients
  Feature 3 — request budgets live in the clients
  Feature 4 — clients re-pace themselves from their QPS schedule
plus connection- and request-level load balancing, hedged requests, and
mid-run server add/drain (elastic scaling).  ``legacy_mode`` restores the
original TailBench restrictions (the paper's baseline for Fig. 4/Table 4).

Engine architecture (rebuilt for 10k-server scale):
  * events live in a calendar queue (``repro.core.events.CalendarQueue``)
    — O(1) amortized push/pop with an exact ``(t, seq)`` total order, so
    runs are bit-identical to the original heap engine;
  * the two hot event types (client emit, server finish) are typed tuples
    dispatched inline by ``run()`` — no per-request closure allocation;
  * server queues are deques; hedge cancellation tombstones the queued
    twin in O(1) instead of scanning and splicing the queue;
  * the alive-server list is cached and invalidated only on server
    add/drain, removing the O(n_servers) scan from every routed request;
  * ``Balancer.release()`` is invoked when a client finishes, so stateful
    policies (e.g. load-aware subscription tracking) see churn.

Virtual time, seeded RNG streams: bit-reproducible.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.control import (AdmissionController, CircuitBreaker, ControlLoop,
                           RetryBudget)
from repro.control.resilience import RESILIENCE_STREAM
from repro.core.client import ClientConfig, ClientGenerator
from repro.core.events import CalendarQueue
from repro.core.profiles import BatchScheduler, apply_service_noise
from repro.core.request import Request
from repro.core.stats import LatencyRecorder, MetricsPipeline

# typed event kinds (first payload slot after (t, seq))
_EMIT, _FINISH, _CALL, _BSTEP = 0, 1, 2, 3


# ---------------------------------------------------------------------------
# Server: G/G/c FIFO queue with a service-time profile, or a
# continuous-batching serve loop behind a batched ServiceModel
# ---------------------------------------------------------------------------
class SimServer:
    """Two service disciplines behind one surface:

    * scalar (default): G/G/c FIFO — ``workers`` independent slots, each
      request holds one for its client-sampled ``service_demand``;
    * batched (``service_model.kind == "batched"``): a continuous-batching
      serve loop — admit up to ``max_batch`` resident sequences, ops
      (one prefill OR one batched decode step) are scheduled as calendar
      events, and per-step costs come from the ``BatchedService``.  The
      op sequencing lives in the shared ``BatchScheduler``, which the
      wall-clock ``BatchedStubEngine`` drives too — sim and engine agree
      on batching dynamics by construction.
    """

    def __init__(self, server_id: int, workers: int = 1, speed: float = 1.0,
                 service_noise: float = 0.0,
                 rng_seed: Optional[tuple] = None,
                 service_model=None, max_batch: Optional[int] = None):
        self.server_id = server_id
        self.workers = workers
        self.speed = speed
        # server-side execution variability (interference, GC pauses...):
        # multiplicative log-normal noise drawn per execution.  This is what
        # hedged requests exploit (Dean & Barroso).
        self.service_noise = service_noise
        # rng_seed threads (experiment seed, server_id, rep) through so
        # repetitions draw independent server-noise streams — the bare
        # (9176, server_id) default replayed identical noise across all 13
        # reps, understating confidence intervals.
        self._rng = np.random.default_rng(
            (9176, server_id) if rng_seed is None else rng_seed)
        self.queue: deque = deque()
        self._q_cancelled = 0          # tombstoned entries still in `queue`
        self.busy = 0
        self.connected: set[int] = set()       # client ids
        self.accepting = True
        self.draining = False
        self.failed = False            # fault injection: completions are lost
        self.total_served = 0
        self.busy_time = 0.0
        self.service_model = service_model
        self._batched = (service_model is not None
                         and getattr(service_model, "kind", "scalar")
                         == "batched")
        if self._batched:
            self.max_batch = max_batch or 8
            self.workers = None        # capacity is batch slots, not workers
            self.serializes_ops = True  # one op at a time: util normalizes
                                        # per server, not per slot
            self.batch = BatchScheduler(service_model, self.max_batch)
            self.queue = self.batch.waiting    # shared deque: load()/fail
            self.tokens_done = 0               # cumulative (tokens/s gauge)

    # -- connection management (Features 1 + 2) -----------------------------
    def connect(self, client_id: int) -> bool:
        if not self.accepting:
            return False
        self.connected.add(client_id)
        return True

    def disconnect(self, client_id: int):
        self.connected.discard(client_id)

    # -- request path --------------------------------------------------------
    def enqueue(self, req: Request, now: float, sim: "Simulator"):
        req.server_id = self.server_id
        req.enqueued = now
        if self._batched:
            self.batch.submit(req, req.prompt_tokens, req.max_new_tokens)
            if self.batch.op is None:          # engine idle: start serving
                self._kick(now, sim)
            return
        if self.busy < self.workers:
            self._start(req, now, sim)
        else:
            self.queue.append(req)

    def _tombstone_twin(self, req: Request, sim: "Simulator"):
        """Entering service tombstones the queued hedge twin — O(1),
        skipped on pop.  Shared by the scalar and batched start paths so
        the hedge-cancellation invariant lives in exactly one place."""
        twin = req._twin
        if twin is not None and twin.started is None and not twin.cancelled:
            twin.cancelled = True
            srv = sim.servers.get(twin.server_id)
            if srv is not None:
                srv._q_cancelled += 1

    # -- continuous-batching serve loop (batched ServiceModel) ---------------
    def _skip_cancelled(self, req: Request) -> bool:
        """start_op predicate: drop hedge-cancelled twins at admission."""
        if req.cancelled:
            self._q_cancelled -= 1
            return True
        return False

    def _kick(self, now: float, sim: "Simulator"):
        """Start the next batching op and schedule its finish event."""
        dur = self.batch.start_op(skip=self._skip_cancelled)
        if dur is None:
            self.busy = 0
            return
        op = self.batch.op
        if op[0] == "prefill":
            req = op[1].key
            self._tombstone_twin(req, sim)
            req.started = now
        dur = apply_service_noise(dur / self.speed, self.service_noise,
                                  self._rng)
        self.busy_time += dur
        self.busy = self.batch.occupancy()
        sim._push_batch_step(now + dur, self)

    def _batch_step(self, t: float, sim: "Simulator"):
        """Finish the in-flight op: complete exhausted requests, then
        start the next op (prefill-priority, like the real engine)."""
        if self.failed:
            # the server died mid-op: the whole resident batch is lost
            for req in self.batch.abort():
                if not req.cancelled:
                    sim._lost(req)
                    req.cancelled = True
            self.busy = 0
            return
        for req in self.batch.finish_op():
            req.completed = t
            self.total_served += 1
            sim.on_completion(req)
        self.tokens_done = self.batch.tokens_done
        self._kick(t, sim)

    def queued_requests(self) -> list:
        """Requests waiting for service (fault-injection accounting) —
        the scalar deque holds them directly, the batched scheduler
        wraps them in BatchItems."""
        if self._batched:
            return [it.key for it in self.batch.waiting]
        return list(self.queue)

    def _start(self, req: Request, now: float, sim: "Simulator"):
        self._tombstone_twin(req, sim)
        self.busy += 1
        req.started = now
        dur = apply_service_noise(req.service_demand / self.speed,
                                  self.service_noise, self._rng)
        self.busy_time += dur
        sim._push_finish(now + dur, self, req)

    def _finish(self, req: Request, now: float, sim: "Simulator"):
        self.busy -= 1
        if self.failed:
            # the server died while this request was in flight: the
            # response is lost, and nothing further starts here
            sim._lost(req)
            req.cancelled = True      # block any pending hedge timer
            return
        req.completed = now
        self.total_served += 1
        sim.on_completion(req)
        q = self.queue
        while q:
            nxt = q.popleft()
            if nxt.cancelled:
                self._q_cancelled -= 1
                continue
            self._start(nxt, now, sim)
            return

    def load(self) -> int:
        return self.busy + len(self.queue) - self._q_cancelled


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------
@dataclass
class SimConfig:
    duration: float = 60.0
    interval: float = 1.0                 # stats bucketing
    seed: int = 0
    legacy_mode: bool = False             # original TailBench semantics
    legacy_expected_clients: int = 0      # server waits for this many
    legacy_requests_per_client: Optional[int] = None  # server-owned budget
    hedge_delay: Optional[float] = None   # straggler mitigation (beyond paper)
    rep: int = 0                          # repetition index -> RNG stream
    stats_mode: str = "exact"             # "exact" | "streaming"
    fast_clients: bool = False            # vectorized arrival generation
    slo: Optional[float] = None           # latency SLO for telemetry frames
    gauges: bool = True                   # sample per-server telemetry gauges
                                          # each interval (off: saves the
                                          # O(n_servers) sweep per interval)
    # resilience + closed-loop control (repro.control)
    retry: Optional[object] = None        # RetryPolicy: timeouts + retries
    breaker: Optional[object] = None      # BreakerSpec: per-server breaking
    control: Optional[object] = None      # ControlSpec: reactive controller


class Simulator:
    def __init__(self, cfg: SimConfig, servers: list[SimServer], balancer,
                 profile=None, lengths=None, service_model=None):
        self.cfg = cfg
        self.servers = {s.server_id: s for s in servers}
        self.balancer = balancer
        self.profile = profile
        self.lengths = lengths              # default TokenLengths for clients
        self.service_model = service_model  # applied to injected server joins
        self.recorder = LatencyRecorder(cfg.interval, mode=cfg.stats_mode,
                                        seed=cfg.seed, rep=cfg.rep)
        self.telemetry = MetricsPipeline(self.recorder, cfg.interval,
                                         slo=cfg.slo)
        self._queue = CalendarQueue(cfg.duration)
        self._seq = itertools.count()
        self._req_ids = itertools.count()
        # hot-path bindings: these run once per request
        self._push = self._queue.push
        self._next_seq = self._seq.__next__
        self._next_rid = self._req_ids.__next__
        self._legacy = cfg.legacy_mode
        self._hedge_delay = cfg.hedge_delay
        self._route_fn = balancer.route
        self.now = 0.0
        self.events = 0                           # executed event count
        self.clients: dict[int, ClientGenerator] = {}
        self.assignment: dict[int, int] = {}      # client -> server
        self.dropped = 0
        self.completed_per_client: dict[int, int] = {}
        # alive-server cache: kept valid at all times, rebuilt only on
        # server add/drain (the seed engine rebuilt it per routed request)
        self._alive: list[SimServer] = [s for s in self.servers.values()
                                        if not s.draining]
        # legacy-mode state
        self._legacy_started = cfg.legacy_expected_clients == 0
        self._legacy_initial: set[int] = set()
        self._legacy_hold: list[Request] = []
        self._legacy_terminated = False
        # resilience stack: admission control, circuit breaking, client
        # timeouts/retries.  The jitter/admission RNG is domain-tagged
        # (RESILIENCE_STREAM, seed, rep) and draws nothing unless a
        # policy is active — existing runs stay bit-identical.
        self.shed = 0                             # admission-rejected requests
        self.timeouts = 0                         # failed after all retries
        self.retries = 0                          # retry attempts issued
        self._res_rng = np.random.default_rng(
            (RESILIENCE_STREAM, cfg.seed, cfg.rep))
        self._admission: Optional[AdmissionController] = None
        self._breaker = CircuitBreaker(cfg.breaker) if cfg.breaker else None
        self._retry = cfg.retry
        self._retry_budget = (RetryBudget(cfg.retry.budget_ratio,
                                          cfg.retry.budget_burst)
                              if cfg.retry else None)
        # closed-loop control: one ControlLoop ticking every spec.interval,
        # acting through the same appliers as compiled injections
        self.control_log: list = []               # (t_applied, kind, params)
        self._control = ControlLoop(cfg.control) if cfg.control else None
        if self._control is not None:
            self.schedule(cfg.control.interval, self._control_tick)
        # telemetry: per-server gauges sampled at every interval boundary
        # (read-only callbacks — they never perturb simulation state)
        if cfg.gauges:
            self.schedule(cfg.interval, self._sample_gauges)

    # ------------------------------------------------------------------ core
    def schedule(self, t: float, fn: Callable[[float], None]):
        self._push((t, self._next_seq(), _CALL, fn))

    def _push_finish(self, t: float, server: SimServer, req: Request):
        self._push((t, self._next_seq(), _FINISH, server, req))

    def _push_batch_step(self, t: float, server: SimServer):
        self._push((t, self._next_seq(), _BSTEP, server))

    def run(self):
        pop = self._queue.pop
        horizon = self.cfg.duration
        emit = self._emit
        n = 0
        while True:
            ev = pop()
            if ev is None:
                break
            t = ev[0]
            if t > horizon:
                break
            self.now = t
            kind = ev[2]
            if kind == _EMIT:
                emit(ev[3], ev[4], ev[5], ev[6], t)
            elif kind == _FINISH:
                ev[3]._finish(ev[4], t, self)
            elif kind == _BSTEP:
                ev[3]._batch_step(t, self)
            else:
                ev[3](t)
            n += 1
        self.events += n
        return self.recorder

    # ------------------------------------------------------- client lifecycle
    def add_client(self, ccfg: ClientConfig):
        """Client appears at ccfg.start_time (Feature 1: any time)."""
        from repro.core.client import BatchedClientGenerator, ConstantQPS
        if (self.cfg.fast_clients and isinstance(ccfg.schedule, ConstantQPS)
                and ccfg.schedule.qps > 0):
            gen = BatchedClientGenerator(ccfg, self.profile,
                                         rng_stream=self.cfg.rep,
                                         lengths=self.lengths)
        else:
            gen = ClientGenerator(ccfg, self.profile, rng_stream=self.cfg.rep,
                                  lengths=self.lengths)
        self.clients[ccfg.client_id] = gen
        self.schedule(ccfg.start_time, lambda t, c=ccfg: self._connect(c, t))

    def _connect(self, ccfg: ClientConfig, t: float):
        cid = ccfg.client_id
        if self.cfg.legacy_mode:
            if self._legacy_started and cid not in self._legacy_initial:
                self.dropped += 1          # original: no connects after start
                return
            self._legacy_initial.add(cid)
        server = self.balancer.assign(self.clients[cid], self._alive)
        if server is None or not server.connect(cid):
            self.balancer.release(cid)     # undo any subscription bookkeeping
            self.dropped += 1
            return
        self.assignment[cid] = server.server_id
        if self.cfg.legacy_mode and not self._legacy_started:
            if len(self._legacy_initial) >= self.cfg.legacy_expected_clients:
                self._legacy_started = True
                for req in self._legacy_hold:    # release held requests
                    self._route(req, self.now)
                self._legacy_hold.clear()
        self._pump(cid)

    def _pump(self, cid: int):
        gen = self.clients[cid]
        if self._legacy and self.cfg.legacy_requests_per_client is not None:
            if gen.sent >= self.cfg.legacy_requests_per_client:
                self._client_done(cid)
                return
        nxt = gen.next_arrival()
        if nxt is None:
            self._client_done(cid)
            return
        t, demand = nxt
        ptoks, mnew = gen.last_sizes
        self._push((t, self._next_seq(), _EMIT, cid, demand, ptoks, mnew))

    def _emit(self, cid: int, demand: float, ptoks: int, mnew: int, t: float):
        req = Request(self._next_rid(), cid, t, demand, ptoks, mnew)
        if self._legacy:
            if not self._legacy_started:
                self._legacy_hold.append(req)  # original: server not started
            elif self._legacy_terminated:
                self.dropped += 1
            else:
                self._route(req, t)
        else:
            self._route(req, t)
        self._pump(cid)

    def _route(self, req: Request, t: float, attempt: int = 0,
               prev_delay: float = 0.0):
        adm = self._admission
        if adm is not None and not adm.allow(t, self._res_rng):
            # load shedding is an explicit disposition, never a silent
            # drop: the request lands in the recorder's failure ledger
            self.shed += 1
            self.dropped += 1
            self.recorder.record_failure(t, "shed")
            return
        sid = self.assignment.get(req.client_id)
        pref = self.servers.get(sid) if sid is not None else None
        alive = self._alive
        brk = self._breaker
        if brk is not None:
            allowed = {s.server_id: brk.allow(s.server_id, t) for s in alive}
            ok = [s for s in alive if allowed[s.server_id]]
            if ok:                    # all-open: fail open, keep full fleet
                alive = ok
                if pref is not None and not allowed.get(pref.server_id, True):
                    pref = None       # broken preferred server: re-route
        server = self._route_fn(req, alive, pref)
        if server is None:
            self.dropped += 1
            self.recorder.record_failure(t, "failed")
            return
        server.enqueue(req, t, self)
        rp = self._retry
        if rp is not None:
            if attempt == 0 and self._retry_budget is not None:
                self._retry_budget.note_primary()
            self.schedule(t + rp.timeout,
                          lambda tt, r=req, a=attempt, p=prev_delay:
                          self._check_timeout(r, a, p, tt))
        hedge = self._hedge_delay
        if hedge is not None:
            self.schedule(t + hedge,
                          lambda tt, r=req: self._maybe_hedge(r, tt))

    def _maybe_hedge(self, req: Request, t: float):
        """Tail-at-scale hedging: re-issue if still incomplete."""
        if req.completed is not None or req.hedged or req.cancelled:
            return            # done, already hedged, or destroyed by a failure
        others = [s for s in self._alive
                  if s.server_id != req.server_id]
        if not others:
            return
        req.hedged = True
        clone = Request(req.req_id, req.client_id, req.created,
                        req.service_demand, req.prompt_tokens,
                        req.max_new_tokens, hedged=True)
        clone._primary = req          # first completion wins
        clone._twin = req             # mutual cancellation on start
        req._twin = clone
        target = min(others, key=lambda s: s.load())
        target.enqueue(clone, t, self)

    def _check_timeout(self, req: Request, attempt: int, prev_delay: float,
                       t: float):
        """Client-side timeout: the client abandons this attempt.  The
        server-side copy is NOT cancelled — it keeps burning capacity
        (wasted work), which is exactly what makes naive retry storms
        metastable.  The eventual completion is discarded by
        ``on_completion``'s ``_recorded`` guard (zombie semantics, same
        as the wall-clock engine)."""
        if req.completed is not None or req._recorded or req.cancelled:
            return
        rp = self._retry
        if rp is None:                 # policy removed mid-flight: no-op
            return
        req._recorded = True           # zombie: completion won't be recorded
        if self._breaker is not None and req.server_id is not None:
            self._breaker.record(req.server_id, False, t)
        budget = self._retry_budget
        if (attempt < rp.max_retries and budget is not None
                and budget.allow()):
            budget.note_retry()
            self.retries += 1
            delay = rp.delay(attempt + 1, prev_delay, self._res_rng)
            self.schedule(t + delay,
                          lambda tt, r=req, a=attempt + 1, d=delay:
                          self._retry_emit(r, a, d, tt))
        else:
            # retries exhausted (or budget says no): explicit disposition
            self.timeouts += 1
            self.dropped += 1
            self.recorder.record_failure(t, "timeout")

    def _retry_emit(self, orig: Request, attempt: int, prev_delay: float,
                    t: float):
        """Re-issue a timed-out request.  The fresh attempt keeps the
        ORIGINAL creation time, so a retried request's recorded latency
        honestly spans queueing + backoff across all attempts.  Retries
        re-enter ``_route``, so they pass admission control again."""
        req = Request(self._next_rid(), orig.client_id, orig.created,
                      orig.service_demand, orig.prompt_tokens,
                      orig.max_new_tokens)
        self._route(req, t, attempt=attempt, prev_delay=prev_delay)

    def _client_done(self, cid: int):
        sid = self.assignment.pop(cid, None)
        if sid is not None:
            self.servers[sid].disconnect(cid)
        self.clients.pop(cid, None)
        self.balancer.release(cid)     # stateful policies drop ghost load
        if self.cfg.legacy_mode and not self.clients:
            self._legacy_terminated = True     # original: server exits
        self.completed_per_client[cid] = self.completed_per_client.get(cid, 0)

    # ------------------------------------------------------------ completions
    def on_completion(self, req: Request):
        primary = req._primary
        if primary is not None:               # hedge clone: credit the primary
            if primary._recorded:
                return
            primary.started = req.started
            primary.completed = req.completed
            primary.server_id = req.server_id
            req = primary
        if req._recorded:                     # primary served first, or the
            return                            # client timed out (zombie work)
        req._recorded = True
        self.recorder.record(req)
        if self._breaker is not None and req.server_id is not None:
            self._breaker.record(req.server_id, True, req.completed)
        c = self.completed_per_client
        c[req.client_id] = c.get(req.client_id, 0) + 1

    # ------------------------------------------------------- elastic servers
    def _alive_servers(self) -> list[SimServer]:
        return self._alive

    def _rebuild_alive(self):
        self._alive = [s for s in self.servers.values() if not s.draining]

    def add_server(self, server: SimServer, at: float):
        def _add(t):
            self.servers[server.server_id] = server
            self._rebuild_alive()
        self.schedule(at, _add)

    def drain_server(self, server_id: int, at: float):
        def _drain(t):
            self.servers[server_id].draining = True
            self.servers[server_id].accepting = False
            self._rebuild_alive()
        self.schedule(at, _drain)

    # ------------------------------------------------------------- telemetry
    def _sample_gauges(self, t: float):
        self.telemetry.sample_servers(t, self.servers.values())
        nxt = t + self.cfg.interval
        if nxt <= self.cfg.duration:
            self.schedule(nxt, self._sample_gauges)

    # ------------------------------------------------------------ injections
    def fail_server(self, server_id: int, at: float):
        """Fault injection: at ``at`` the server dies — queued requests and
        in-flight responses are lost, connected clients rebalance."""
        def _fail(t):
            srv = self.servers.get(server_id)
            if srv is None or srv.failed:
                return
            srv.failed = True
            srv.accepting = False
            srv.draining = True
            # queued work is lost now; a batched server's resident batch
            # is lost when its in-flight op event fires (_batch_step)
            for req in srv.queued_requests():
                if not req.cancelled:
                    self._lost(req)
                    req.cancelled = True   # pending hedge timers must not
            srv.queue.clear()              # resurrect a destroyed request
            srv._q_cancelled = 0
            self._rebuild_alive()
            for cid in list(srv.connected):
                srv.disconnect(cid)
                self._reassign(cid, t)
        self.schedule(at, _fail)

    def _lost(self, req: Request):
        """A copy of ``req`` was destroyed by a server failure.  Count a
        drop only when no other copy can still deliver it — a hedged
        request with a live twin elsewhere is not lost, and counting it
        would double-book the request as both dropped and served."""
        primary = req._primary or req
        if primary._recorded:
            return
        twin = req._twin
        if twin is not None and not twin.cancelled and twin.completed is None:
            srv = self.servers.get(twin.server_id)
            if srv is not None and not srv.failed:
                return                # twin survives on a healthy server
        # no copy can deliver it: account the drop exactly once (a hedge
        # pair destroyed by the same failure reaches here for both copies)
        primary._recorded = True
        self.dropped += 1
        self.recorder.record_failure(self.now, "failed")
        if self._breaker is not None and req.server_id is not None:
            self._breaker.record(req.server_id, False, self.now)

    def _reassign(self, cid: int, t: float):
        """Re-home a live client after its server vanished."""
        self.balancer.release(cid)
        self.assignment.pop(cid, None)
        gen = self.clients.get(cid)
        if gen is None:
            return
        server = self.balancer.assign(gen, self._alive)
        if server is None or not server.connect(cid):
            self.balancer.release(cid)
            return               # unassigned: requests fall back to route()
        self.assignment[cid] = server.server_id

    def set_server_speed(self, server_id: int, at: float, factor: float):
        """Slowdown/speedup injection: scale the server's speed at ``at``."""
        def _set(t):
            srv = self.servers.get(server_id)
            if srv is not None:
                srv.speed *= factor
        self.schedule(at, _set)

    def set_policy(self, policy, at: float):
        """Swap the balancing policy mid-run: new assignments and
        request-level routing use it from ``at`` onward."""
        def _set(t):
            from repro.core.balancer import POLICIES
            b = POLICIES[policy]() if isinstance(policy, str) else policy
            self.balancer = b
            self._route_fn = b.route
        self.schedule(at, _set)

    def set_hedge(self, delay: Optional[float], at: float):
        """Enable/retune/disable request hedging mid-run."""
        def _set(t):
            self._hedge_delay = delay
        self.schedule(at, _set)

    # ------------------------------------------------ resilience + control
    def set_admission(self, at: float, params: dict):
        """Install/replace/disable admission control at ``at``."""
        def _set(t):
            admit = params.get("admit")
            rate = params.get("rate")
            if rate is None and (admit is None or admit >= 1.0):
                self._admission = None     # fully open: no draws, no state
            else:
                self._admission = AdmissionController(
                    admit=admit, rate=rate, burst=params.get("burst", 1.0))
        self.schedule(at, _set)

    def set_retry(self, policy, at: float):
        """Install (policy) or remove (None) the client retry policy."""
        def _set(t):
            self._retry = policy
            self._retry_budget = (RetryBudget(policy.budget_ratio,
                                              policy.budget_burst)
                                  if policy is not None else None)
        self.schedule(at, _set)

    def set_breaker(self, spec, at: float):
        """Install (spec) or remove (None) per-server circuit breaking."""
        def _set(t):
            self._breaker = CircuitBreaker(spec) if spec is not None else None
        self.schedule(at, _set)

    def scale_to(self, n: int, at: float):
        """Elastic scale: activate the first ``n`` non-failed servers (in
        server-id order, drawing standbys out of drain) and drain the
        rest.  Draining servers finish residual work; their connected
        clients stay until the client-side lifecycle moves them."""
        def _scale(t):
            pool = [s for s in sorted(self.servers.values(),
                                      key=lambda s: s.server_id)
                    if not s.failed]
            for s in pool[:n]:
                if s.draining:
                    s.draining = False
                    s.accepting = True
            for s in pool[n:]:
                if not s.draining:
                    s.draining = True
                    s.accepting = False
                    for cid in list(s.connected):
                        s.disconnect(cid)
                        self._reassign(cid, t)
            self._rebuild_alive()
        self.schedule(at, _scale)

    def _control_tick(self, t: float):
        """One closed-loop controller step: observe the window, let the
        policy act, apply actions after the actuation lag through the
        same appliers compiled injections use.  Applied actions land in
        ``control_log`` for cost accounting and determinism checks."""
        loop = self._control
        admit = self._admission.level if self._admission is not None else 1.0
        obs = loop.observe(self.recorder, self._alive, t, self.cfg.slo,
                           admit)
        for kind, params in loop.tick(obs, t):
            at = t + loop.spec.lag
            self.control_log.append((at, kind, dict(params)))
            self.apply_injection(kind, at, params)
        nxt = t + loop.spec.interval
        if nxt <= self.cfg.duration:
            self.schedule(nxt, self._control_tick)

    def apply_injection(self, kind: str, at: float, params: dict):
        """Apply one compiled ``Scenario`` injection (see core/scenario.py)."""
        if kind == "server_fail":
            self.fail_server(params["server_id"], at)
        elif kind == "server_speed":
            self.set_server_speed(params["server_id"], at, params["factor"])
        elif kind == "server_join":
            sid = params["server_id"]
            # same (seed, server_id, rep) noise-stream layout as
            # build_simulator: injected joins must not replay identical
            # noise across repetitions either
            rng_seed = params.get("rng_seed") or (9176, self.cfg.seed, sid,
                                                  self.cfg.rep)
            self.add_server(
                SimServer(sid, params.get("workers", 1),
                          params.get("speed", 1.0),
                          params.get("service_noise", 0.0),
                          rng_seed=rng_seed,
                          service_model=self.service_model,
                          max_batch=params.get("max_batch")), at)
        elif kind == "server_drain":
            self.drain_server(params["server_id"], at)
        elif kind == "set_policy":
            self.set_policy(params["policy"], at)
        elif kind == "set_hedge":
            self.set_hedge(params["delay"], at)
        elif kind == "set_admission":
            self.set_admission(at, params)
        elif kind == "set_scale":
            self.scale_to(int(params["n"]), at)
        elif kind == "set_retry":
            self.set_retry(params["policy"], at)
        elif kind == "set_breaker":
            self.set_breaker(params["spec"], at)
        else:
            raise ValueError(f"unknown injection kind: {kind!r}")
