"""Runtime layer: one scenario, two execution backends.

``Runtime`` is the common surface over the two ways a compiled
``Experiment`` can execute:

* ``SimulatorRuntime`` — the virtual-time discrete-event ``Simulator``
  (deterministic, bit-reproducible, millions of requests per second);
* ``EngineRuntime`` — a wall-clock loop driving real step-based
  inference engines (``repro.serving.engine``) with the *same*
  ``ClientGenerator`` arrival processes, the same ``Balancer``
  assign/route/release lifecycle, and the same ``LatencyRecorder`` /
  ``MetricsPipeline`` telemetry.

Because both backends consume identical client configs and seeds, the
engine path replays bit-identical arrival timelines to the simulator —
the sim-vs-engine parity path the paper's validation methodology needs.

``EngineRuntime`` accepts anything engine-shaped: an object with
``submit(prompt, max_new_tokens, req_id)``, ``step() -> [Completion]``,
``pending()``, ``n_active()`` and ``idle()`` (``InferenceEngine`` and
``StubEngine`` both qualify).  Clocks are injectable; ``VirtualClock``
lets the wall-clock loop run in accelerated virtual time for tests and
stub-backed scenario runs.
"""
from __future__ import annotations

import heapq
import itertools
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.balancer import POLICIES
from repro.core.client import ClientConfig, ClientGenerator
from repro.core.harness import Experiment, build_simulator
from repro.core.profiles import FixedProfile
from repro.core.request import Request
from repro.core.stats import LatencyRecorder, MetricsPipeline

# injection kinds the wall-clock backend can honor (speed scaling and
# hedging need simulator control over service execution)
_ENGINE_INJECTIONS = ("server_join", "server_drain", "server_fail",
                      "set_policy")


class Runtime:
    """A scenario execution backend: run once, expose telemetry."""

    recorder: LatencyRecorder
    telemetry: MetricsPipeline

    def run(self) -> MetricsPipeline:
        raise NotImplementedError


class SimulatorRuntime(Runtime):
    """Virtual-time backend — thin adapter over ``build_simulator``."""

    def __init__(self, experiment: Experiment, rep: int = 0):
        self.sim = build_simulator(experiment, rep=rep)
        self.recorder = self.sim.recorder
        self.telemetry = self.sim.telemetry

    @property
    def dropped(self) -> int:
        return self.sim.dropped

    def run(self) -> MetricsPipeline:
        self.sim.run()
        return self.telemetry


# ---------------------------------------------------------------------------
# Virtual clock (accelerated wall-clock for stub engines and tests)
# ---------------------------------------------------------------------------
class VirtualClock:
    """A manually-advanced monotonic clock.

    ``sleep`` advances time instead of blocking; ``advance_to`` jumps
    forward but never past ``limit`` (the runtime parks the next arrival
    deadline there so an engine skipping ahead to its next completion
    cannot leap over a due admission).
    """

    def __init__(self, t: float = 0.0):
        self.t = t
        self.limit: Optional[float] = None

    def __call__(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        self.t += dt

    def advance_to(self, t: float) -> None:
        if self.limit is not None:
            t = min(t, self.limit)
        if t > self.t:
            self.t = t


# ---------------------------------------------------------------------------
# Engine-backed wall-clock runtime
# ---------------------------------------------------------------------------
class EngineServerHandle:
    """Balancer-compatible view of one engine replica (the same surface
    ``SimServer`` offers: server_id/connected/accepting/load/connect)."""

    def __init__(self, server_id: int, engine):
        self.server_id = server_id
        self.engine = engine
        self.connected: set[int] = set()
        self.accepting = True
        self.draining = False
        self.failed = False
        # capacity semantics: an engine replica's concurrency is its batch
        # slots, not worker threads — expose max_batch as itself and leave
        # workers unset so telemetry resolves capacity honestly (the old
        # ``workers = max_batch`` alias hid which model the server ran)
        self.workers = None
        self.max_batch = getattr(engine, "max_batch", 1)
        # forwarded so telemetry normalizes utilization by the engine's
        # declared scheduling semantics, not by inference from counters
        self.serializes_ops = getattr(engine, "serializes_ops", False)
        self.outstanding: set[int] = set()     # req_ids submitted, not done
        self.total_served = 0

    @property
    def tokens_done(self):
        """Cumulative generated tokens, when the engine counts them
        (batched engines do; telemetry skips the gauge otherwise)."""
        return getattr(self.engine, "tokens_done", None)

    @property
    def busy(self) -> int:
        return self.engine.n_active()

    @property
    def busy_time(self):
        """Cumulative service seconds, when the engine accounts for them
        (StubEngine does; telemetry falls back to instantaneous busy)."""
        return getattr(self.engine, "busy_time", None)

    def load(self) -> int:
        return self.engine.pending() + self.engine.n_active()

    def connect(self, client_id: int) -> bool:
        if not self.accepting:
            return False
        self.connected.add(client_id)
        return True

    def disconnect(self, client_id: int) -> None:
        self.connected.discard(client_id)


class EngineRuntime(Runtime):
    """Drive real engines with the harness's open-loop client machinery.

    Replaces the old ``run_engine_experiment`` ad-hoc loop: arrivals come
    lazily from ``ClientGenerator`` (same RNG streams as the simulator),
    connection assignment / request routing / departure go through the
    full ``Balancer`` assign/route/release lifecycle, completions are
    recorded by a verbatim ``LatencyRecorder``, and per-interval gauges
    feed the shared ``MetricsPipeline``.
    """

    def __init__(self, engines, clients: Sequence[ClientConfig], *,
                 policy: str = "round_robin", duration: float = 10.0,
                 prompt_len: int = 16, max_new_tokens: int = 4,
                 vocab: int = 256, seed: int = 0, time_scale: float = 1.0,
                 interval: float = 1.0, slo: Optional[float] = None,
                 injections: Sequence = (), rep: int = 0,
                 profile=None, lengths=None, stats_mode: str = "exact",
                 engine_factory: Optional[Callable[[int], object]] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        if isinstance(engines, dict):
            handle_map = {sid: EngineServerHandle(sid, e)
                          for sid, e in engines.items()}
        else:
            handle_map = {i: EngineServerHandle(i, e)
                          for i, e in enumerate(engines)}
        self.handles: dict[int, EngineServerHandle] = handle_map
        self.balancer = POLICIES[policy]() if isinstance(policy, str) else policy
        self.duration = duration
        self.interval = interval
        self.time_scale = time_scale
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.vocab = vocab
        self.engine_factory = engine_factory
        # timestamps are recorded in wall seconds; with a stretched clock
        # (time_scale != 1) the recorder's bucket width scales with them so
        # interval indices stay in *virtual* time, aligned with the gauge
        # samples and the scenario's QPS schedule
        self.recorder = LatencyRecorder(interval * time_scale,
                                        mode=stats_mode, seed=seed, rep=rep)
        self.telemetry = MetricsPipeline(self.recorder, interval, slo=slo)
        self.dropped = 0
        self._clock = clock
        self._sleep = sleep
        self._rng = np.random.default_rng(seed)
        self._rid = itertools.count()
        prof = profile if profile is not None else FixedProfile("tok", 0.0)
        self.lengths = lengths
        # O(1) per-arrival lookups (the old loop re-scanned the client
        # list on every first-arrival: O(n_clients) per admission)
        self.client_cfgs: dict[int, ClientConfig] = {c.client_id: c
                                                     for c in clients}
        self._gens: dict[int, ClientGenerator] = {
            c.client_id: ClientGenerator(c, prof, rng_stream=rep,
                                         lengths=lengths)
            for c in clients}
        self.assignment: dict[int, EngineServerHandle] = {}
        self._meta: dict[int, tuple] = {}       # req_id -> (cid, t_arr)
        # only injections the wall-clock backend can honor; the rest are
        # surfaced instead of silently dropped
        self._injections = sorted((i for i in injections
                                   if i.kind in _ENGINE_INJECTIONS),
                                  key=lambda i: i.at)
        self.unsupported = [i for i in injections
                            if i.kind not in _ENGINE_INJECTIONS]
        self._alive: list[EngineServerHandle] = [
            h for h in self.handles.values() if not h.draining and not h.failed]
        # pre-build engines for scheduled joins NOW, outside the measured
        # loop — a real engine's factory JIT-compiles and warms for
        # seconds, which would otherwise stall serving at the join instant
        self._prepared: dict[int, object] = {}
        if engine_factory is not None:
            for inj in self._injections:
                if inj.kind == "server_join":
                    sid = inj.params["server_id"]
                    self._prepared[sid] = engine_factory(sid)

    # ------------------------------------------------------------ assembly
    @classmethod
    def from_experiment(cls, exp: Experiment, engines, *,
                        engine_factory=None, rep: int = 0,
                        prompt_len: int = 16, max_new_tokens: int = 4,
                        vocab: int = 256, time_scale: float = 1.0,
                        clock: Callable[[], float] = time.monotonic,
                        sleep: Callable[[float], None] = time.sleep
                        ) -> "EngineRuntime":
        """Build the wall-clock runtime from a compiled scenario.

        ``engines`` supplies one engine per initial server spec (list, in
        spec order, or dict keyed by server_id); servers that join later
        are built on demand via ``engine_factory(server_id)``.  Uses the
        experiment's app profile for the client generators, so arrival
        timelines are bit-identical to ``build_simulator``'s.
        """
        from dataclasses import replace as _replace

        from repro.core.scenario import Injection

        base = [s for s in exp.servers if s.join_at == 0.0]
        if not isinstance(engines, dict):
            engines = list(engines)
            if len(engines) < len(base):
                raise ValueError(f"need {len(base)} engines for the initial "
                                 f"fleet, got {len(engines)}")
            engines = {s.server_id: e for s, e in zip(base, engines)}
        else:
            # an engine pre-registered for a server that only joins later
            # would be replaced mid-run, orphaning its in-flight requests
            joining = {s.server_id for s in exp.servers if s.join_at > 0.0}
            early = joining & engines.keys()
            if early:
                raise ValueError(f"servers {sorted(early)} join mid-run; "
                                 f"supply them via engine_factory, not the "
                                 f"initial engines dict")
        injections = list(exp.injections)
        if exp.hedge_delay is not None:
            # hedging is simulator-only; surface it via the unsupported
            # list instead of silently running the scenario un-hedged
            injections.append(Injection(0.0, "set_hedge",
                                        {"delay": exp.hedge_delay}))
        for s in exp.servers:
            if s.join_at > 0.0:
                injections.append(Injection(s.join_at, "server_join",
                                            {"server_id": s.server_id,
                                             "workers": s.workers,
                                             "speed": s.speed,
                                             "service_noise": s.service_noise,
                                             "max_batch": s.max_batch}))
            if s.drain_at is not None:
                injections.append(Injection(s.drain_at, "server_drain",
                                            {"server_id": s.server_id}))
        clients = [_replace(c, seed=c.seed if c.seed else exp.seed)
                   for c in exp.clients]
        return cls(engines, clients, policy=exp.policy,
                   duration=exp.duration, interval=exp.interval,
                   vocab=vocab, prompt_len=prompt_len,
                   max_new_tokens=max_new_tokens, seed=exp.seed,
                   time_scale=time_scale, slo=exp.slo, injections=injections,
                   rep=rep, profile=exp.resolved_profile(),
                   lengths=exp.resolved_lengths(), stats_mode=exp.stats_mode,
                   engine_factory=engine_factory, clock=clock, sleep=sleep)

    # ------------------------------------------------------------ internals
    def _rebuild_alive(self) -> None:
        self._alive = [h for h in self.handles.values()
                       if not h.draining and not h.failed]

    def _push_next(self, heap: list, cid: int) -> None:
        gen = self._gens.get(cid)
        if gen is None:
            return
        nxt = gen.next_arrival()
        if nxt is None or nxt[0] > self.duration:
            self._client_done(cid)
            return
        ptoks, mnew = gen.last_sizes       # sampled with the arrival
        heapq.heappush(heap, (nxt[0] * self.time_scale, cid, ptoks, mnew))

    def _client_done(self, cid: int) -> None:
        handle = self.assignment.pop(cid, None)
        if handle is not None:
            handle.disconnect(cid)
        self._gens.pop(cid, None)
        self.balancer.release(cid)

    def _admit(self, cid: int, t_arr: float, ptoks: int = 0,
               mnew: int = 0) -> bool:
        """Admit one arrival; False means the client was terminated
        (connection refused — mirrors Simulator._connect semantics, where
        a refused client never generates traffic).  ``ptoks``/``mnew``
        are the client-sampled token sizes (0 = unsized: fall back to the
        runtime's fixed prompt_len/max_new_tokens)."""
        gen = self._gens[cid]
        if cid not in self.assignment:
            handle = self.balancer.assign(gen, self._alive)
            if handle is None or not handle.connect(cid):
                self.balancer.release(cid)
                self._gens.pop(cid, None)
                self.dropped += 1
                return False
            self.assignment[cid] = handle
        handle = self.balancer.route(None, self._alive,
                                     self.assignment.get(cid))
        if handle is None or handle.failed:
            self.dropped += 1
            return True
        rid = next(self._rid)
        n_prompt = ptoks if ptoks > 0 else self.prompt_len
        n_new = mnew if mnew > 0 else self.max_new_tokens
        prompt = self._rng.integers(0, self.vocab, size=n_prompt)
        self._meta[rid] = (cid, t_arr)
        handle.outstanding.add(rid)
        handle.engine.submit(prompt, n_new, rid)
        return True

    def _complete(self, handle: EngineServerHandle, comp, wall: float) -> None:
        meta = self._meta.pop(comp.req_id, None)
        handle.outstanding.discard(comp.req_id)
        if meta is None:
            return                      # request of a failed server: dropped
        cid, t_arr = meta
        rec = Request(comp.req_id, cid, t_arr, 0.0)
        rec.enqueued = t_arr
        rec.started = wall - comp.latency
        rec.completed = wall
        rec.server_id = handle.server_id
        self.recorder.record(rec)
        handle.total_served += 1

    def _apply_injection(self, inj) -> None:
        kind, p = inj.kind, inj.params
        if kind == "server_join":
            sid = p["server_id"]
            existing = self.handles.get(sid)
            if existing is not None and not existing.failed:
                raise ValueError(f"server_join for live server {sid}: "
                                 f"replacing it would orphan its in-flight "
                                 f"requests")
            engine = self._prepared.pop(sid, None)
            if engine is None:
                if self.engine_factory is None:
                    raise ValueError("server_join injection needs "
                                     "engine_factory")
                engine = self.engine_factory(sid)
            self.handles[sid] = EngineServerHandle(sid, engine)
            self._rebuild_alive()
        elif kind == "server_drain":
            h = self.handles.get(p["server_id"])
            if h is not None:
                h.accepting = False
                h.draining = True
                self._rebuild_alive()
        elif kind == "server_fail":
            h = self.handles.get(p["server_id"])
            if h is not None and not h.failed:
                h.failed = True
                h.accepting = False
                for rid in h.outstanding:
                    if self._meta.pop(rid, None) is not None:
                        self.dropped += 1
                h.outstanding.clear()
                self._rebuild_alive()
                for cid in list(h.connected):
                    h.disconnect(cid)
                    self._reassign(cid)
        elif kind == "set_policy":
            pol = p["policy"]
            self.balancer = POLICIES[pol]() if isinstance(pol, str) else pol
        else:                                   # pre-filtered in __init__
            raise ValueError(f"unsupported engine injection: {kind!r}")

    def _reassign(self, cid: int) -> None:
        self.balancer.release(cid)
        self.assignment.pop(cid, None)
        gen = self._gens.get(cid)
        if gen is None:
            return
        handle = self.balancer.assign(gen, self._alive)
        if handle is None or not handle.connect(cid):
            self.balancer.release(cid)
            return
        self.assignment[cid] = handle

    def _drain_gauges(self, now: float) -> None:
        """Sample per-server gauges for every interval boundary that has
        elapsed (boundaries are wall instants; labels are virtual time)."""
        while self._next_sample <= now and \
                self._next_sample <= self.duration * self.time_scale:
            self.telemetry.sample_servers(
                self._next_sample / self.time_scale, self.handles.values())
            self._next_sample += self.interval * self.time_scale

    # ---------------------------------------------------------------- run
    def run(self) -> MetricsPipeline:
        heap: list = []
        for cid in list(self._gens):
            self._push_next(heap, cid)
        injections = list(self._injections)
        inj_idx = 0
        self._next_sample = self.interval * self.time_scale
        end_wall = self.duration * self.time_scale
        t0 = self._clock()
        while True:
            now = self._clock() - t0
            while inj_idx < len(injections) and \
                    injections[inj_idx].at * self.time_scale <= now:
                self._apply_injection(injections[inj_idx])
                inj_idx += 1
            self._drain_gauges(now)
            admitted = False
            while heap and heap[0][0] <= now:
                t_arr, cid, ptoks, mnew = heapq.heappop(heap)
                if self._admit(cid, t_arr, ptoks, mnew):
                    self._push_next(heap, cid)
                admitted = True
            # parity with the simulator's horizon: pending injections keep
            # the loop alive (sleeping toward them) even after the last
            # request drains; the idle gauge tail after the final event is
            # fast-forwarded by the closing _drain_gauges below, where
            # nothing can change the readings anymore
            if not heap and not self._meta and inj_idx >= len(injections):
                break
            # park the next deadline (arrival, injection, or gauge
            # boundary) on the clock so engines skipping ahead in virtual
            # time cannot leap over a due event — e.g. completing requests
            # a server_fail injection should have destroyed.  Only events
            # that clear themselves belong here (the horizon does not —
            # clamping on it would wedge a completion due just past it).
            if hasattr(self._clock, "limit"):
                targets = []
                if heap:
                    targets.append(heap[0][0])
                if inj_idx < len(injections):
                    targets.append(injections[inj_idx].at * self.time_scale)
                if self._next_sample <= end_wall:
                    targets.append(self._next_sample)
                self._clock.limit = t0 + min(targets) if targets else None
            stepped = False
            for handle in list(self.handles.values()):
                if handle.failed or handle.engine.idle():
                    continue
                completions = handle.engine.step()
                stepped = True
                if completions:
                    wall = self._clock() - t0
                    for comp in completions:
                        self._complete(handle, comp, wall)
            if not admitted and not stepped:
                # nothing in flight: sleep the whole gap to the next due
                # event (arrival, injection, gauge, or the horizon)
                # instead of 1ms-spinning; with work outstanding poll at 1ms
                now = self._clock() - t0
                targets = [end_wall]
                if heap:
                    targets.append(heap[0][0])
                if inj_idx < len(injections):
                    targets.append(injections[inj_idx].at * self.time_scale)
                if self._next_sample <= end_wall:
                    targets.append(self._next_sample)
                wait = min(targets) - now
                if self._meta:
                    wait = min(wait, 0.001)
                self._sleep(max(wait, 1e-6))
        # close out the idle tail: sample every remaining interval up to
        # the scenario horizon (the fleet is quiescent, so these read the
        # same as they would have in real time)
        self._drain_gauges(end_wall)
        return self.telemetry


# ---------------------------------------------------------------------------
# One entry point, either backend
# ---------------------------------------------------------------------------
def run_scenario(scenario, backend: str = "sim", *, rep: int = 0,
                 engines=None, engine_factory=None, vector_config=None,
                 cache=None, **engine_kw) -> Runtime:
    """Compile a ``Scenario`` and execute it on the chosen backend.

    ``backend="sim"`` runs the deterministic virtual-time simulator;
    ``backend="engine"`` drives the supplied engines wall-clock;
    ``backend="vector"`` runs the batched array backend (statistically
    equivalent to ``sim``, not bit-identical — see ``repro.vector``;
    ``vector_config`` tunes its impl / device / bucketing knobs, all
    bit-preserving).  Returns the finished ``Runtime`` (telemetry under
    ``.telemetry``).
    """
    exp = scenario.compile()
    if backend == "sim":
        rt: Runtime = SimulatorRuntime(exp, rep=rep)
    elif backend == "vector":
        from repro.vector import VectorRuntime
        rt = VectorRuntime(exp, rep=rep, config=vector_config, cache=cache)
    elif backend == "engine":
        if engines is None:
            raise ValueError("backend='engine' needs engines=")
        rt = EngineRuntime.from_experiment(exp, engines, rep=rep,
                                           engine_factory=engine_factory,
                                           **engine_kw)
    else:
        raise ValueError(f"unknown backend: {backend!r}")
    rt.run()
    return rt
