"""Runtime layer: one scenario, two execution backends.

``Runtime`` is the common surface over the two ways a compiled
``Experiment`` can execute:

* ``SimulatorRuntime`` — the virtual-time discrete-event ``Simulator``
  (deterministic, bit-reproducible, millions of requests per second);
* ``EngineRuntime`` — a wall-clock loop driving real step-based
  inference engines (``repro.serving.engine``) with the *same*
  ``ClientGenerator`` arrival processes, the same ``Balancer``
  assign/route/release lifecycle, and the same ``LatencyRecorder`` /
  ``MetricsPipeline`` telemetry.

Because both backends consume identical client configs and seeds, the
engine path replays bit-identical arrival timelines to the simulator —
the sim-vs-engine parity path the paper's validation methodology needs.

``EngineRuntime`` accepts anything engine-shaped: an object with
``submit(prompt, max_new_tokens, req_id)``, ``step() -> [Completion]``,
``pending()``, ``n_active()`` and ``idle()`` (``InferenceEngine`` and
``StubEngine`` both qualify).  Clocks are injectable; ``VirtualClock``
lets the wall-clock loop run in accelerated virtual time for tests and
stub-backed scenario runs.
"""
from __future__ import annotations

import heapq
import itertools
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.control import (AdmissionController, CircuitBreaker, ControlLoop,
                           RetryBudget)
from repro.control.resilience import RESILIENCE_STREAM
from repro.core.balancer import POLICIES
from repro.core.client import ClientConfig, ClientGenerator
from repro.core.harness import Experiment, build_simulator
from repro.core.profiles import FixedProfile
from repro.core.request import Request
from repro.core.stats import LatencyRecorder, MetricsPipeline

# injection kinds the wall-clock backend can honor (speed scaling and
# hedging need simulator control over service execution)
_ENGINE_INJECTIONS = ("server_join", "server_drain", "server_fail",
                      "set_policy", "set_admission", "set_scale",
                      "set_retry", "set_breaker")


class Runtime:
    """A scenario execution backend: run once, expose telemetry."""

    recorder: LatencyRecorder
    telemetry: MetricsPipeline

    def run(self) -> MetricsPipeline:
        raise NotImplementedError


class SimulatorRuntime(Runtime):
    """Virtual-time backend — thin adapter over ``build_simulator``."""

    def __init__(self, experiment: Experiment, rep: int = 0):
        self.sim = build_simulator(experiment, rep=rep)
        self.recorder = self.sim.recorder
        self.telemetry = self.sim.telemetry

    @property
    def dropped(self) -> int:
        return self.sim.dropped

    @property
    def shed(self) -> int:
        return self.sim.shed

    @property
    def timeouts(self) -> int:
        return self.sim.timeouts

    @property
    def retries(self) -> int:
        return self.sim.retries

    @property
    def control_log(self) -> list:
        return self.sim.control_log

    def run(self) -> MetricsPipeline:
        self.sim.run()
        return self.telemetry


# ---------------------------------------------------------------------------
# Virtual clock (accelerated wall-clock for stub engines and tests)
# ---------------------------------------------------------------------------
class VirtualClock:
    """A manually-advanced monotonic clock.

    ``sleep`` advances time instead of blocking; ``advance_to`` jumps
    forward but never past ``limit`` (the runtime parks the next arrival
    deadline there so an engine skipping ahead to its next completion
    cannot leap over a due admission).
    """

    def __init__(self, t: float = 0.0):
        self.t = t
        self.limit: Optional[float] = None

    def __call__(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        self.t += dt

    def advance_to(self, t: float) -> None:
        if self.limit is not None:
            t = min(t, self.limit)
        if t > self.t:
            self.t = t


# ---------------------------------------------------------------------------
# Engine-backed wall-clock runtime
# ---------------------------------------------------------------------------
class EngineServerHandle:
    """Balancer-compatible view of one engine replica (the same surface
    ``SimServer`` offers: server_id/connected/accepting/load/connect)."""

    def __init__(self, server_id: int, engine):
        self.server_id = server_id
        self.engine = engine
        self.connected: set[int] = set()
        self.accepting = True
        self.draining = False
        self.failed = False
        # capacity semantics: an engine replica's concurrency is its batch
        # slots, not worker threads — expose max_batch as itself and leave
        # workers unset so telemetry resolves capacity honestly (the old
        # ``workers = max_batch`` alias hid which model the server ran)
        self.workers = None
        self.max_batch = getattr(engine, "max_batch", 1)
        # forwarded so telemetry normalizes utilization by the engine's
        # declared scheduling semantics, not by inference from counters
        self.serializes_ops = getattr(engine, "serializes_ops", False)
        self.outstanding: set[int] = set()     # req_ids submitted, not done
        self.total_served = 0

    @property
    def tokens_done(self):
        """Cumulative generated tokens, when the engine counts them
        (batched engines do; telemetry skips the gauge otherwise)."""
        return getattr(self.engine, "tokens_done", None)

    @property
    def busy(self) -> int:
        return self.engine.n_active()

    @property
    def busy_time(self):
        """Cumulative service seconds, when the engine accounts for them
        (StubEngine does; telemetry falls back to instantaneous busy)."""
        return getattr(self.engine, "busy_time", None)

    def load(self) -> int:
        return self.engine.pending() + self.engine.n_active()

    def connect(self, client_id: int) -> bool:
        if not self.accepting:
            return False
        self.connected.add(client_id)
        return True

    def disconnect(self, client_id: int) -> None:
        self.connected.discard(client_id)


class EngineRuntime(Runtime):
    """Drive real engines with the harness's open-loop client machinery.

    Replaces the old ``run_engine_experiment`` ad-hoc loop: arrivals come
    lazily from ``ClientGenerator`` (same RNG streams as the simulator),
    connection assignment / request routing / departure go through the
    full ``Balancer`` assign/route/release lifecycle, completions are
    recorded by a verbatim ``LatencyRecorder``, and per-interval gauges
    feed the shared ``MetricsPipeline``.
    """

    def __init__(self, engines, clients: Sequence[ClientConfig], *,
                 policy: str = "round_robin", duration: float = 10.0,
                 prompt_len: int = 16, max_new_tokens: int = 4,
                 vocab: int = 256, seed: int = 0, time_scale: float = 1.0,
                 interval: float = 1.0, slo: Optional[float] = None,
                 injections: Sequence = (), rep: int = 0,
                 profile=None, lengths=None, stats_mode: str = "exact",
                 engine_factory: Optional[Callable[[int], object]] = None,
                 retry=None, breaker=None, control=None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        if isinstance(engines, dict):
            handle_map = {sid: EngineServerHandle(sid, e)
                          for sid, e in engines.items()}
        else:
            handle_map = {i: EngineServerHandle(i, e)
                          for i, e in enumerate(engines)}
        self.handles: dict[int, EngineServerHandle] = handle_map
        self.balancer = POLICIES[policy]() if isinstance(policy, str) else policy
        self.duration = duration
        self.interval = interval
        self.time_scale = time_scale
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.vocab = vocab
        self.engine_factory = engine_factory
        # timestamps are recorded in wall seconds; with a stretched clock
        # (time_scale != 1) the recorder's bucket width scales with them so
        # interval indices stay in *virtual* time, aligned with the gauge
        # samples and the scenario's QPS schedule
        self.recorder = LatencyRecorder(interval * time_scale,
                                        mode=stats_mode, seed=seed, rep=rep)
        self.telemetry = MetricsPipeline(self.recorder, interval, slo=slo)
        self.dropped = 0
        self._clock = clock
        self._sleep = sleep
        self._rng = np.random.default_rng(seed)
        self._rid = itertools.count()
        prof = profile if profile is not None else FixedProfile("tok", 0.0)
        self.lengths = lengths
        # O(1) per-arrival lookups (the old loop re-scanned the client
        # list on every first-arrival: O(n_clients) per admission)
        self.client_cfgs: dict[int, ClientConfig] = {c.client_id: c
                                                     for c in clients}
        self._gens: dict[int, ClientGenerator] = {
            c.client_id: ClientGenerator(c, prof, rng_stream=rep,
                                         lengths=lengths)
            for c in clients}
        self.assignment: dict[int, EngineServerHandle] = {}
        # req_id -> (cid, t_created_wall, attempt, prev_delay, ptoks,
        #            mnew, server_id)
        self._meta: dict[int, tuple] = {}
        self.slo = slo
        # resilience stack (mirrors Simulator: same policies, same
        # domain-tagged RNG stream, wall-clock actuation)
        self.shed = 0
        self.timeouts = 0
        self.retries = 0
        self._res_rng = np.random.default_rng((RESILIENCE_STREAM, seed, rep))
        self._admission: Optional[AdmissionController] = None
        self._breaker = CircuitBreaker(breaker) if breaker else None
        self._retry = retry
        self._retry_budget = (RetryBudget(retry.budget_ratio,
                                          retry.budget_burst)
                              if retry else None)
        self._deadlines: list = []     # (deadline_wall, req_id)
        self._retry_q: list = []       # (due_wall, seq, cid, t_created_wall,
                                       #  attempt, prev_delay, ptoks, mnew)
        self._rseq = itertools.count()
        # closed-loop control: tick boundaries are wall instants, actions
        # apply after the actuation lag through the same dispatch as
        # compiled injections
        self.control_log: list = []    # (t_virtual_applied, kind, params)
        self._control = ControlLoop(control) if control else None
        self._pending_actions: list = []   # (due_wall, seq, kind, params)
        # only injections the wall-clock backend can honor; the rest are
        # surfaced instead of silently dropped.  (at, seq) order: ties at
        # identical timestamps apply in declaration order, matching the
        # simulator's calendar-queue total order
        self._injections = sorted((i for i in injections
                                   if i.kind in _ENGINE_INJECTIONS),
                                  key=lambda i: (i.at, i.seq))
        self.unsupported = [i for i in injections
                            if i.kind not in _ENGINE_INJECTIONS]
        self._alive: list[EngineServerHandle] = [
            h for h in self.handles.values() if not h.draining and not h.failed]
        # pre-build engines for scheduled joins NOW, outside the measured
        # loop — a real engine's factory JIT-compiles and warms for
        # seconds, which would otherwise stall serving at the join instant
        self._prepared: dict[int, object] = {}
        if engine_factory is not None:
            for inj in self._injections:
                if inj.kind == "server_join":
                    sid = inj.params["server_id"]
                    self._prepared[sid] = engine_factory(sid)

    # ------------------------------------------------------------ assembly
    @classmethod
    def from_experiment(cls, exp: Experiment, engines, *,
                        engine_factory=None, rep: int = 0,
                        prompt_len: int = 16, max_new_tokens: int = 4,
                        vocab: int = 256, time_scale: float = 1.0,
                        clock: Callable[[], float] = time.monotonic,
                        sleep: Callable[[float], None] = time.sleep
                        ) -> "EngineRuntime":
        """Build the wall-clock runtime from a compiled scenario.

        ``engines`` supplies one engine per initial server spec (list, in
        spec order, or dict keyed by server_id); servers that join later
        are built on demand via ``engine_factory(server_id)``.  Uses the
        experiment's app profile for the client generators, so arrival
        timelines are bit-identical to ``build_simulator``'s.
        """
        from dataclasses import replace as _replace

        from repro.core.scenario import Injection

        base = [s for s in exp.servers if s.join_at == 0.0]
        if not isinstance(engines, dict):
            engines = list(engines)
            if len(engines) < len(base):
                raise ValueError(f"need {len(base)} engines for the initial "
                                 f"fleet, got {len(engines)}")
            engines = {s.server_id: e for s, e in zip(base, engines)}
        else:
            # an engine pre-registered for a server that only joins later
            # would be replaced mid-run, orphaning its in-flight requests
            joining = {s.server_id for s in exp.servers if s.join_at > 0.0}
            early = joining & engines.keys()
            if early:
                raise ValueError(f"servers {sorted(early)} join mid-run; "
                                 f"supply them via engine_factory, not the "
                                 f"initial engines dict")
        injections = list(exp.injections)
        if exp.hedge_delay is not None:
            # hedging is simulator-only; surface it via the unsupported
            # list instead of silently running the scenario un-hedged
            injections.append(Injection(0.0, "set_hedge",
                                        {"delay": exp.hedge_delay}))
        # spec-derived joins/drains get seq=-1: the simulator schedules
        # them BEFORE the compiled injection list at equal timestamps, so
        # the stable (at, seq) sort must put them first here too
        for s in exp.servers:
            if s.join_at > 0.0:
                injections.append(Injection(s.join_at, "server_join",
                                            {"server_id": s.server_id,
                                             "workers": s.workers,
                                             "speed": s.speed,
                                             "service_noise": s.service_noise,
                                             "max_batch": s.max_batch},
                                            seq=-1))
            if s.drain_at is not None:
                injections.append(Injection(s.drain_at, "server_drain",
                                            {"server_id": s.server_id},
                                            seq=-1))
        clients = [_replace(c, seed=c.seed if c.seed else exp.seed)
                   for c in exp.clients]
        rt = cls(engines, clients, policy=exp.policy,
                 duration=exp.duration, interval=exp.interval,
                 vocab=vocab, prompt_len=prompt_len,
                 max_new_tokens=max_new_tokens, seed=exp.seed,
                 time_scale=time_scale, slo=exp.slo, injections=injections,
                 rep=rep, profile=exp.resolved_profile(),
                 lengths=exp.resolved_lengths(), stats_mode=exp.stats_mode,
                 engine_factory=engine_factory, retry=exp.retry,
                 breaker=exp.breaker, control=exp.control,
                 clock=clock, sleep=sleep)
        # standby pool: engines exist (built and warm) but start drained
        # until a scale action activates them — mirror build_simulator
        for s in exp.servers:
            if s.standby:
                h = rt.handles.get(s.server_id)
                if h is not None:
                    h.draining = True
                    h.accepting = False
        rt._rebuild_alive()
        return rt

    # ------------------------------------------------------------ internals
    def _rebuild_alive(self) -> None:
        self._alive = [h for h in self.handles.values()
                       if not h.draining and not h.failed]

    def _push_next(self, heap: list, cid: int) -> None:
        gen = self._gens.get(cid)
        if gen is None:
            return
        nxt = gen.next_arrival()
        if nxt is None or nxt[0] > self.duration:
            self._client_done(cid)
            return
        ptoks, mnew = gen.last_sizes       # sampled with the arrival
        heapq.heappush(heap, (nxt[0] * self.time_scale, cid, ptoks, mnew))

    def _client_done(self, cid: int) -> None:
        handle = self.assignment.pop(cid, None)
        if handle is not None:
            handle.disconnect(cid)
        self._gens.pop(cid, None)
        self.balancer.release(cid)

    def _admit(self, cid: int, t_arr: float, ptoks: int = 0,
               mnew: int = 0) -> bool:
        """Admit one arrival; False means the client was terminated
        (connection refused — mirrors Simulator._connect semantics, where
        a refused client never generates traffic).  ``ptoks``/``mnew``
        are the client-sampled token sizes (0 = unsized: fall back to the
        runtime's fixed prompt_len/max_new_tokens)."""
        gen = self._gens[cid]
        if cid not in self.assignment:
            handle = self.balancer.assign(gen, self._alive)
            if handle is None or not handle.connect(cid):
                self.balancer.release(cid)
                self._gens.pop(cid, None)
                self.dropped += 1
                return False
            self.assignment[cid] = handle
        self._submit(cid, t_arr, t_arr, ptoks, mnew, 0, 0.0)
        return True

    def _submit(self, cid: int, t_sub: float, t_created: float, ptoks: int,
                mnew: int, attempt: int, prev_delay: float) -> None:
        """Route + submit one attempt (primary or retry) at wall instant
        ``t_sub``.  Mirrors ``Simulator._route``: admission control
        first (sheds are an explicit disposition), then breaker-filtered
        routing, then the per-attempt timeout deadline."""
        t_virt = t_sub / self.time_scale
        adm = self._admission
        if adm is not None and not adm.allow(t_virt, self._res_rng):
            self.shed += 1
            self.dropped += 1
            self.recorder.record_failure(t_sub, "shed")
            return
        pref = self.assignment.get(cid)
        alive = self._alive
        brk = self._breaker
        if brk is not None:
            allowed = {h.server_id: brk.allow(h.server_id, t_virt)
                       for h in alive}
            ok = [h for h in alive if allowed[h.server_id]]
            if ok:
                alive = ok
                if pref is not None and not allowed.get(pref.server_id, True):
                    pref = None
        handle = self.balancer.route(None, alive, pref)
        if handle is None or handle.failed:
            self.dropped += 1
            self.recorder.record_failure(t_sub, "failed")
            return
        rid = next(self._rid)
        n_prompt = ptoks if ptoks > 0 else self.prompt_len
        n_new = mnew if mnew > 0 else self.max_new_tokens
        prompt = self._rng.integers(0, self.vocab, size=n_prompt)
        self._meta[rid] = (cid, t_created, attempt, prev_delay, ptoks, mnew,
                           handle.server_id)
        handle.outstanding.add(rid)
        handle.engine.submit(prompt, n_new, rid)
        rp = self._retry
        if rp is not None:
            if attempt == 0 and self._retry_budget is not None:
                self._retry_budget.note_primary()
            heapq.heappush(self._deadlines,
                           (t_sub + rp.timeout * self.time_scale, rid))

    def _complete(self, handle: EngineServerHandle, comp, wall: float) -> None:
        meta = self._meta.pop(comp.req_id, None)
        handle.outstanding.discard(comp.req_id)
        if meta is None:
            return     # failed-server request, or a timed-out zombie: the
                       # wasted server work is real, the response is not
        cid, t_arr = meta[0], meta[1]
        rec = Request(comp.req_id, cid, t_arr, 0.0)
        rec.enqueued = t_arr
        rec.started = wall - comp.latency
        rec.completed = wall
        rec.server_id = handle.server_id
        self.recorder.record(rec)
        if self._breaker is not None:
            self._breaker.record(handle.server_id, True,
                                 wall / self.time_scale)
        handle.total_served += 1

    def _apply_injection(self, inj, now: float = 0.0) -> None:
        kind, p = inj.kind, inj.params
        if kind == "server_join":
            sid = p["server_id"]
            existing = self.handles.get(sid)
            if existing is not None and not existing.failed:
                raise ValueError(f"server_join for live server {sid}: "
                                 f"replacing it would orphan its in-flight "
                                 f"requests")
            engine = self._prepared.pop(sid, None)
            if engine is None:
                if self.engine_factory is None:
                    raise ValueError("server_join injection needs "
                                     "engine_factory")
                engine = self.engine_factory(sid)
            self.handles[sid] = EngineServerHandle(sid, engine)
            self._rebuild_alive()
        elif kind == "server_drain":
            h = self.handles.get(p["server_id"])
            if h is not None:
                h.accepting = False
                h.draining = True
                self._rebuild_alive()
        elif kind == "server_fail":
            h = self.handles.get(p["server_id"])
            if h is not None and not h.failed:
                h.failed = True
                h.accepting = False
                for rid in h.outstanding:
                    if self._meta.pop(rid, None) is not None:
                        self.dropped += 1
                        self.recorder.record_failure(now, "failed")
                        if self._breaker is not None:
                            self._breaker.record(h.server_id, False,
                                                 now / self.time_scale)
                h.outstanding.clear()
                self._rebuild_alive()
                for cid in list(h.connected):
                    h.disconnect(cid)
                    self._reassign(cid)
        elif kind == "set_policy":
            pol = p["policy"]
            self.balancer = POLICIES[pol]() if isinstance(pol, str) else pol
        elif kind == "set_admission":
            admit, rate = p.get("admit"), p.get("rate")
            if rate is None and (admit is None or admit >= 1.0):
                self._admission = None
            else:
                self._admission = AdmissionController(
                    admit=admit, rate=rate, burst=p.get("burst", 1.0))
        elif kind == "set_scale":
            self.scale_to(int(p["n"]))
        elif kind == "set_retry":
            pol = p["policy"]
            self._retry = pol
            self._retry_budget = (RetryBudget(pol.budget_ratio,
                                              pol.budget_burst)
                                  if pol is not None else None)
        elif kind == "set_breaker":
            spec = p["spec"]
            self._breaker = CircuitBreaker(spec) if spec is not None else None
        else:                                   # pre-filtered in __init__
            raise ValueError(f"unsupported engine injection: {kind!r}")

    def scale_to(self, n: int) -> None:
        """Elastic scale, mirroring ``Simulator.scale_to``: activate the
        first ``n`` non-failed handles in server-id order, drain the
        rest (in-flight work completes, clients re-home)."""
        pool = [h for h in sorted(self.handles.values(),
                                  key=lambda h: h.server_id)
                if not h.failed]
        for h in pool[:n]:
            if h.draining:
                h.draining = False
                h.accepting = True
        for h in pool[n:]:
            if not h.draining:
                h.draining = True
                h.accepting = False
                for cid in list(h.connected):
                    h.disconnect(cid)
                    self._reassign(cid)
        self._rebuild_alive()

    def _check_deadlines(self, now: float) -> None:
        """Expire per-attempt timeouts due by ``now``.  The engine-side
        request is NOT cancelled — it keeps burning batch slots until
        completion, which ``_complete`` then discards (zombie work,
        matching the simulator's wasted-work semantics)."""
        while self._deadlines and self._deadlines[0][0] <= now:
            deadline, rid = heapq.heappop(self._deadlines)
            meta = self._meta.pop(rid, None)
            if meta is None:
                continue               # completed (or destroyed) in time
            cid, t_created, attempt, prev_delay, ptoks, mnew, sid = meta
            rp = self._retry
            if rp is None:
                continue               # policy removed mid-flight
            if self._breaker is not None:
                self._breaker.record(sid, False, deadline / self.time_scale)
            budget = self._retry_budget
            if (attempt < rp.max_retries and budget is not None
                    and budget.allow()):
                budget.note_retry()
                self.retries += 1
                delay = rp.delay(attempt + 1, prev_delay, self._res_rng)
                heapq.heappush(self._retry_q,
                               (deadline + delay * self.time_scale,
                                next(self._rseq), cid, t_created,
                                attempt + 1, delay, ptoks, mnew))
            else:
                self.timeouts += 1
                self.dropped += 1
                self.recorder.record_failure(deadline, "timeout")

    def _drain_retries(self, now: float) -> None:
        """Re-issue backed-off retries due by ``now`` (they re-enter
        ``_submit``, so they pass admission control again)."""
        while self._retry_q and self._retry_q[0][0] <= now:
            due, _, cid, t_created, attempt, prev_delay, ptoks, mnew = \
                heapq.heappop(self._retry_q)
            self._submit(cid, due, t_created, ptoks, mnew, attempt,
                         prev_delay)

    def _control_step(self, now: float) -> None:
        """Closed-loop controller: tick at each control boundary due by
        ``now``, queue actions for ``now + lag``, apply due actions."""
        loop = self._control
        spec = loop.spec
        scale = self.time_scale
        while (self._next_control <= now
               and self._next_control <= self.duration * scale):
            t_virt = self._next_control / scale
            admit = (self._admission.level
                     if self._admission is not None else 1.0)
            slo_wall = self.slo * scale if self.slo is not None else None
            # observe in the recorder's (wall) time base — its interval
            # indices are wall instants; gate the cooldown in virtual
            # time, like the simulator
            obs = loop.observe(self.recorder, self._alive,
                               self._next_control, slo_wall, admit)
            for kind, params in loop.tick(obs, t_virt):
                due = self._next_control + spec.lag * scale
                self.control_log.append((t_virt + spec.lag, kind,
                                         dict(params)))
                heapq.heappush(self._pending_actions,
                               (due, next(self._rseq), kind, dict(params)))
            self._next_control += spec.interval * scale
        from repro.core.scenario import Injection
        while self._pending_actions and self._pending_actions[0][0] <= now:
            due, _, kind, params = heapq.heappop(self._pending_actions)
            self._apply_injection(Injection(due, kind, params), now=due)

    def _reassign(self, cid: int) -> None:
        self.balancer.release(cid)
        self.assignment.pop(cid, None)
        gen = self._gens.get(cid)
        if gen is None:
            return
        handle = self.balancer.assign(gen, self._alive)
        if handle is None or not handle.connect(cid):
            self.balancer.release(cid)
            return
        self.assignment[cid] = handle

    def _drain_gauges(self, now: float) -> None:
        """Sample per-server gauges for every interval boundary that has
        elapsed (boundaries are wall instants; labels are virtual time)."""
        while self._next_sample <= now and \
                self._next_sample <= self.duration * self.time_scale:
            self.telemetry.sample_servers(
                self._next_sample / self.time_scale, self.handles.values())
            self._next_sample += self.interval * self.time_scale

    # ---------------------------------------------------------------- run
    def run(self) -> MetricsPipeline:
        heap: list = []
        for cid in list(self._gens):
            self._push_next(heap, cid)
        injections = list(self._injections)
        inj_idx = 0
        self._next_sample = self.interval * self.time_scale
        self._next_control = (self._control.spec.interval * self.time_scale
                              if self._control is not None else None)
        end_wall = self.duration * self.time_scale
        t0 = self._clock()
        while True:
            now = self._clock() - t0
            while inj_idx < len(injections) and \
                    injections[inj_idx].at * self.time_scale <= now:
                self._apply_injection(injections[inj_idx],
                                      now=injections[inj_idx].at
                                      * self.time_scale)
                inj_idx += 1
            self._drain_gauges(now)
            if self._control is not None:
                self._control_step(now)
            self._check_deadlines(now)
            self._drain_retries(now)
            admitted = False
            while heap and heap[0][0] <= now:
                t_arr, cid, ptoks, mnew = heapq.heappop(heap)
                if self._admit(cid, t_arr, ptoks, mnew):
                    self._push_next(heap, cid)
                admitted = True
            # parity with the simulator's horizon: pending injections keep
            # the loop alive (sleeping toward them) even after the last
            # request drains; the idle gauge tail after the final event is
            # fast-forwarded by the closing _drain_gauges below, where
            # nothing can change the readings anymore
            if (not heap and not self._meta and not self._retry_q
                    and not self._pending_actions
                    and inj_idx >= len(injections)):
                break
            # park the next deadline (arrival, injection, or gauge
            # boundary) on the clock so engines skipping ahead in virtual
            # time cannot leap over a due event — e.g. completing requests
            # a server_fail injection should have destroyed.  Only events
            # that clear themselves belong here (the horizon does not —
            # clamping on it would wedge a completion due just past it).
            if hasattr(self._clock, "limit"):
                targets = []
                if heap:
                    targets.append(heap[0][0])
                if inj_idx < len(injections):
                    targets.append(injections[inj_idx].at * self.time_scale)
                if self._next_sample <= end_wall:
                    targets.append(self._next_sample)
                if self._deadlines:
                    targets.append(self._deadlines[0][0])
                if self._retry_q:
                    targets.append(self._retry_q[0][0])
                if self._pending_actions:
                    targets.append(self._pending_actions[0][0])
                if (self._next_control is not None
                        and self._next_control <= end_wall):
                    targets.append(self._next_control)
                self._clock.limit = t0 + min(targets) if targets else None
            stepped = False
            for handle in list(self.handles.values()):
                if handle.failed or handle.engine.idle():
                    continue
                completions = handle.engine.step()
                stepped = True
                if completions:
                    wall = self._clock() - t0
                    for comp in completions:
                        self._complete(handle, comp, wall)
            if not admitted and not stepped:
                # nothing in flight: sleep the whole gap to the next due
                # event (arrival, injection, gauge, or the horizon)
                # instead of 1ms-spinning; with work outstanding poll at 1ms
                now = self._clock() - t0
                targets = [end_wall]
                if heap:
                    targets.append(heap[0][0])
                if inj_idx < len(injections):
                    targets.append(injections[inj_idx].at * self.time_scale)
                if self._next_sample <= end_wall:
                    targets.append(self._next_sample)
                if self._deadlines:
                    targets.append(self._deadlines[0][0])
                if self._retry_q:
                    targets.append(self._retry_q[0][0])
                if self._pending_actions:
                    targets.append(self._pending_actions[0][0])
                if (self._next_control is not None
                        and self._next_control <= end_wall):
                    targets.append(self._next_control)
                wait = min(targets) - now
                if self._meta:
                    wait = min(wait, 0.001)
                self._sleep(max(wait, 1e-6))
        # close out the idle tail: sample every remaining interval up to
        # the scenario horizon (the fleet is quiescent, so these read the
        # same as they would have in real time)
        self._drain_gauges(end_wall)
        return self.telemetry


# ---------------------------------------------------------------------------
# One entry point, either backend
# ---------------------------------------------------------------------------
def run_scenario(scenario, backend: str = "sim", *, rep: int = 0,
                 engines=None, engine_factory=None, vector_config=None,
                 cache=None, **engine_kw) -> Runtime:
    """Compile a ``Scenario`` and execute it on the chosen backend.

    ``backend="sim"`` runs the deterministic virtual-time simulator;
    ``backend="engine"`` drives the supplied engines wall-clock;
    ``backend="vector"`` runs the batched array backend (statistically
    equivalent to ``sim``, not bit-identical — see ``repro.vector``;
    ``vector_config`` tunes its impl / device / bucketing knobs, all
    bit-preserving).  Returns the finished ``Runtime`` (telemetry under
    ``.telemetry``).
    """
    exp = scenario.compile()
    if backend == "sim":
        rt: Runtime = SimulatorRuntime(exp, rep=rep)
    elif backend == "vector":
        from repro.vector import VectorRuntime
        rt = VectorRuntime(exp, rep=rep, config=vector_config, cache=cache)
    elif backend == "engine":
        if engines is None:
            raise ValueError("backend='engine' needs engines=")
        rt = EngineRuntime.from_experiment(exp, engines, rep=rep,
                                           engine_factory=engine_factory,
                                           **engine_kw)
    else:
        raise ValueError(f"unknown backend: {backend!r}")
    rt.run()
    return rt
