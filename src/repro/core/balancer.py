"""Load balancing policies (the paper's LVS director, generalized).

The paper balances at *connection* granularity (LVS assigns each client to
a server): ``assign(client, servers)``.  Round-robin and the load-aware
policy of Fig. 8 are connection-level.  Beyond the paper we add
request-level policies (``route``): join-shortest-queue and
power-of-two-choices, plus hedging in the simulator.
"""
from __future__ import annotations

import itertools
from typing import Optional

import numpy as np


class Balancer:
    """Default: honor the connection assignment for every request.

    Lifecycle: the simulator calls ``assign`` when a client connects and
    ``release`` when it finishes (or its connection attempt fails), so
    stateful policies can drop per-client bookkeeping under churn.

    A request can reach ``route`` with no assignment — its client joined
    while the fleet was empty, or its server failed and every re-home
    attempt was refused.  That fallback goes through ``choose``, the
    policy's own per-request pick; the old ``servers[0]`` fallback
    silently hot-spotted the first alive server under exactly the churn
    conditions a balancer exists for.
    """

    def assign(self, client, servers) -> Optional[object]:
        raise NotImplementedError

    def release(self, client_id: int) -> None:
        """Client departed — forget any per-client state.  No-op by default."""

    def choose(self, req, servers):
        """Policy choice for an unassigned request (least-loaded unless
        the policy has a sharper criterion)."""
        if not servers:
            return None
        return min(servers, key=lambda s: s.load())

    def route(self, req, servers, assigned):
        if assigned is not None:
            return assigned
        return self.choose(req, servers)


class RoundRobin(Balancer):
    """LVS default: clients assigned to servers in arrival order."""

    def __init__(self):
        self._n = itertools.count()

    def assign(self, client, servers):
        if not servers:
            return None
        return servers[next(self._n) % len(servers)]

    def choose(self, req, servers):
        """Unassigned requests keep rotating instead of pinning the
        first alive server."""
        if not servers:
            return None
        return servers[next(self._n) % len(servers)]


class LoadAware(Balancer):
    """Paper Fig. 8: balance the *offered QPS* across servers — assign each
    arriving client to the server with the least total subscribed rate.

    Subscriptions are released when the client departs (``release``), so
    under churn new clients are not steered by the ghost load of clients
    that finished long ago."""

    def __init__(self):
        self.subscribed: dict[int, float] = {}
        self._client_sub: dict[int, tuple[int, float]] = {}  # cid -> (sid, qps)

    def assign(self, client, servers):
        if not servers:
            return None
        qps = client.cfg.schedule.rate(client.cfg.start_time)
        best = min(servers, key=lambda s: self.subscribed.get(s.server_id, 0.0))
        self.subscribed[best.server_id] = self.subscribed.get(best.server_id, 0.0) + qps
        self._client_sub[client.cfg.client_id] = (best.server_id, qps)
        return best

    def release(self, client_id: int) -> None:
        sub = self._client_sub.pop(client_id, None)
        if sub is None:
            return
        sid, qps = sub
        cur = self.subscribed.get(sid)
        if cur is not None:
            self.subscribed[sid] = max(0.0, cur - qps)

    def choose(self, req, servers):
        """Unassigned requests follow the least-subscribed criterion
        (no subscription is booked — the client never connected), with
        live queue load as the tie-break: a fresh fleet has every
        subscription at zero, and without the tie-break min() would pin
        the first server — the exact hot-spot this fallback replaces."""
        if not servers:
            return None
        return min(servers, key=lambda s: (self.subscribed.get(s.server_id,
                                                               0.0),
                                           s.load()))


class LeastConnections(Balancer):
    def assign(self, client, servers):
        if not servers:
            return None
        return min(servers, key=lambda s: len(s.connected))

    def choose(self, req, servers):
        if not servers:
            return None
        return min(servers, key=lambda s: len(s.connected))


class JoinShortestQueue(Balancer):
    """Request-level: ignore the connection, pick the least-loaded server."""

    def assign(self, client, servers):
        return servers[0] if servers else None

    def route(self, req, servers, assigned):
        if not servers:
            return None
        return min(servers, key=lambda s: s.load())


class PowerOfTwo(Balancer):
    """Request-level: sample two servers, take the less loaded (Mitzenmacher)."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def assign(self, client, servers):
        return servers[0] if servers else None

    def route(self, req, servers, assigned):
        if not servers:
            return None
        if len(servers) == 1:
            return servers[0]
        i, j = self.rng.choice(len(servers), size=2, replace=False)
        a, b = servers[int(i)], servers[int(j)]
        return a if a.load() <= b.load() else b


POLICIES = {
    "round_robin": RoundRobin,
    "load_aware": LoadAware,
    "least_connections": LeastConnections,
    "jsq": JoinShortestQueue,
    "p2c": PowerOfTwo,
}
