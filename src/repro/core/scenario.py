"""Declarative dynamic scenarios — the TailBench++ scenario layer.

A ``Scenario`` is a timed, declarative description of everything dynamic
the paper's harness exists to reproduce: clients arriving and leaving
(churn processes, flash crowds), load shapes changing mid-run, servers
joining, draining, failing or slowing down, and mid-run policy or hedging
changes.  It *compiles down* to the existing ``Experiment``/``Simulator``
primitives — client configs with start/end times and QPS schedules,
server specs with ``join_at``/``drain_at``, plus a list of ``Injection``
records for the behaviors those primitives cannot express (failure,
slowdown, policy/hedge swaps).

One compiled scenario runs unchanged on either runtime backend (the
virtual-time ``Simulator`` or the wall-clock ``EngineRuntime``); see
``repro.core.runtime.run_scenario``.  The canonical named scenarios live
in ``repro.scenarios``.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.client import ClientConfig, ConstantQPS, QPSSchedule
from repro.core.harness import Experiment, ServerSpec


# ---------------------------------------------------------------------------
# Compiled injection record (consumed by Simulator.apply_injection and
# EngineRuntime._apply_injection)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Injection:
    at: float
    kind: str           # server_fail | server_speed | server_join |
                        # server_drain | set_policy | set_hedge |
                        # set_admission | set_scale | set_retry | set_breaker
    params: dict
    # declaration-order tie-break: injections at identical timestamps
    # apply in ``(at, seq)`` order on EVERY backend, mirroring the
    # calendar queue's total order.  ``Scenario.compile`` stamps this;
    # runtime-synthesized injections (spec joins/drains) use negative
    # seqs because the simulator schedules them before the compiled
    # injection list at equal timestamps.
    seq: int = 0


# ---------------------------------------------------------------------------
# Declarative scenario events
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ClientArrival:
    """``count`` clients appear at ``at`` with the given load shape and
    optionally leave (``leave_at``) or stop after ``requests``."""
    at: float
    qps: Union[float, QPSSchedule]
    count: int = 1
    requests: Optional[int] = None
    leave_at: Optional[float] = None


@dataclass(frozen=True)
class FlashCrowd:
    """A burst of ``clients`` extra clients between ``at`` and
    ``at + duration``, together offering ``peak_qps``."""
    at: float
    duration: float
    peak_qps: float
    clients: int = 5


@dataclass(frozen=True)
class ClientChurn:
    """A Poisson churn process: short-lived clients arrive at
    ``arrival_rate`` per second over [start, stop), each holding a
    connection for ~Exp(hold_mean) seconds at ``qps``.  Expanded
    deterministically from the scenario seed at compile time."""
    start: float
    stop: float
    arrival_rate: float
    hold_mean: float
    qps: float
    salt: int = 0


@dataclass(frozen=True)
class ServerJoin:
    at: float
    server_id: int
    workers: int = 1
    speed: float = 1.0
    service_noise: float = 0.0
    max_batch: Optional[int] = None    # batch slots (batched ServiceModels)


@dataclass(frozen=True)
class ServerDrain:
    at: float
    server_id: int


@dataclass(frozen=True)
class ServerFail:
    at: float
    server_id: int


@dataclass(frozen=True)
class ServerSlowdown:
    """Server runs ``factor``x slower from ``at`` (until ``until``)."""
    at: float
    server_id: int
    factor: float
    until: Optional[float] = None


@dataclass(frozen=True)
class SetPolicy:
    at: float
    policy: str


@dataclass(frozen=True)
class SetHedge:
    at: float
    delay: Optional[float]


@dataclass(frozen=True)
class SetAdmission:
    """Admission control from ``at``: probabilistic (``admit`` fraction)
    or token-bucket (``rate`` req/s, ``burst`` capacity).  ``admit=1.0``
    with no rate disables shedding."""
    at: float
    admit: Optional[float] = None
    rate: Optional[float] = None
    burst: float = 1.0


@dataclass(frozen=True)
class SetScale:
    """Scale the fleet to ``n`` active servers at ``at``, drawing from
    the standby pool (``ServerSpec.standby=True``) in server-id order;
    surplus servers drain (residual work completes)."""
    at: float
    n: int


@dataclass(frozen=True)
class SetRetry:
    """Install (or, with ``policy=None``, remove) the client-side
    timeout/retry policy (a ``repro.control.RetryPolicy``) at ``at``."""
    at: float
    policy: Optional[object]


@dataclass(frozen=True)
class SetBreaker:
    """Install (or remove) per-server circuit breaking (a
    ``repro.control.BreakerSpec``) at ``at``."""
    at: float
    spec: Optional[object]


@dataclass(frozen=True)
class CorrelatedFailure:
    """Several servers die at the SAME instant (shared rack/AZ failure).
    Lowers to one ``server_fail`` injection per server at identical
    timestamps — their application order is the declaration order of
    ``server_ids`` (the ``(at, seq)`` tie-break)."""
    at: float
    server_ids: tuple


ScenarioEvent = Union[ClientArrival, FlashCrowd, ClientChurn, ServerJoin,
                      ServerDrain, ServerFail, ServerSlowdown, SetPolicy,
                      SetHedge, SetAdmission, SetScale, SetRetry,
                      SetBreaker, CorrelatedFailure]


# ---------------------------------------------------------------------------
# Scenario
# ---------------------------------------------------------------------------
@dataclass
class Scenario:
    name: str
    duration: float
    events: Sequence[ScenarioEvent] = ()
    servers: Sequence[ServerSpec] = (ServerSpec(0),)   # initial fleet
    app: str = "xapian"
    policy: str = "round_robin"
    seed: int = 0
    interval: float = 1.0
    slo: Optional[float] = None
    hedge_delay: Optional[float] = None
    stats_mode: str = "exact"
    # pluggable service layer: a BatchedService switches every server to
    # the continuous-batching serve loop; lengths gives every client a
    # per-request token-size distribution (identical on both backends)
    service_model: Optional[object] = None
    lengths: Optional[object] = None
    # resilience + closed-loop control (repro.control): a RetryPolicy
    # gives clients timeouts/bounded retries from t=0, a BreakerSpec
    # enables per-server circuit breaking, a ControlSpec runs a reactive
    # controller over the run's telemetry
    retry: Optional[object] = None
    breaker: Optional[object] = None
    control: Optional[object] = None

    # ------------------------------------------------------------- compile
    def compile(self) -> Experiment:
        """Lower the declarative events onto ``Experiment`` primitives.

        Client events become ``ClientConfig``s (ids allocated in event
        order, deterministically); server join/drain map to
        ``ServerSpec.join_at``/``drain_at``; everything else becomes an
        ``Injection`` the runtime applies at the scheduled time.
        """
        clients: list[ClientConfig] = []
        servers: dict[int, ServerSpec] = {s.server_id: s for s in self.servers}
        injections: list[Injection] = []
        next_cid = 0

        def add_client(at, schedule, requests=None, leave_at=None):
            nonlocal next_cid
            clients.append(ClientConfig(
                client_id=next_cid, schedule=schedule, start_time=at,
                total_requests=requests,
                end_time=min(leave_at, self.duration)
                         if leave_at is not None else None))
            next_cid += 1

        for ev in self.events:
            if isinstance(ev, ClientArrival):
                sched = (ConstantQPS(float(ev.qps))
                         if not isinstance(ev.qps, QPSSchedule) else ev.qps)
                for _ in range(ev.count):
                    add_client(ev.at, sched, ev.requests, ev.leave_at)
            elif isinstance(ev, FlashCrowd):
                per = ev.peak_qps / max(ev.clients, 1)
                for _ in range(ev.clients):
                    add_client(ev.at, ConstantQPS(per),
                               leave_at=ev.at + ev.duration)
            elif isinstance(ev, ClientChurn):
                rng = np.random.default_rng((self.seed, 0xC4, ev.salt))
                t = ev.start
                while True:
                    t += float(rng.exponential(1.0 / ev.arrival_rate))
                    if t >= ev.stop:
                        break
                    hold = float(rng.exponential(ev.hold_mean))
                    add_client(t, ConstantQPS(ev.qps), leave_at=t + hold)
            elif isinstance(ev, ServerJoin):
                if ev.server_id in servers:
                    raise ValueError(f"server {ev.server_id} already exists")
                servers[ev.server_id] = ServerSpec(
                    ev.server_id, workers=ev.workers, speed=ev.speed,
                    service_noise=ev.service_noise, join_at=ev.at,
                    max_batch=ev.max_batch)
            elif isinstance(ev, ServerDrain):
                spec = servers.get(ev.server_id)
                if spec is None:
                    raise ValueError(f"unknown server {ev.server_id}")
                servers[ev.server_id] = replace(spec, drain_at=ev.at)
            elif isinstance(ev, ServerFail):
                if ev.server_id not in servers:
                    raise ValueError(f"unknown server {ev.server_id}")
                injections.append(Injection(ev.at, "server_fail",
                                            {"server_id": ev.server_id}))
            elif isinstance(ev, ServerSlowdown):
                injections.append(Injection(
                    ev.at, "server_speed",
                    {"server_id": ev.server_id, "factor": 1.0 / ev.factor}))
                if ev.until is not None:
                    injections.append(Injection(
                        ev.until, "server_speed",
                        {"server_id": ev.server_id, "factor": ev.factor}))
            elif isinstance(ev, SetPolicy):
                injections.append(Injection(ev.at, "set_policy",
                                            {"policy": ev.policy}))
            elif isinstance(ev, SetHedge):
                injections.append(Injection(ev.at, "set_hedge",
                                            {"delay": ev.delay}))
            elif isinstance(ev, SetAdmission):
                injections.append(Injection(ev.at, "set_admission",
                                            {"admit": ev.admit,
                                             "rate": ev.rate,
                                             "burst": ev.burst}))
            elif isinstance(ev, SetScale):
                injections.append(Injection(ev.at, "set_scale",
                                            {"n": int(ev.n)}))
            elif isinstance(ev, SetRetry):
                injections.append(Injection(ev.at, "set_retry",
                                            {"policy": ev.policy}))
            elif isinstance(ev, SetBreaker):
                injections.append(Injection(ev.at, "set_breaker",
                                            {"spec": ev.spec}))
            elif isinstance(ev, CorrelatedFailure):
                for sid in ev.server_ids:
                    if sid not in servers:
                        raise ValueError(f"unknown server {sid}")
                    injections.append(Injection(ev.at, "server_fail",
                                                {"server_id": sid}))
            else:
                raise TypeError(f"unknown scenario event: {ev!r}")

        # declaration-order seq stamp + (at, seq) sort: identical-time
        # injections apply in declaration order on every backend
        injections = [replace(inj, seq=k)
                      for k, inj in enumerate(injections)]
        injections.sort(key=lambda i: (i.at, i.seq))
        return Experiment(
            clients=tuple(clients),
            servers=tuple(servers.values()),
            app=self.app, policy=self.policy, duration=self.duration,
            interval=self.interval, seed=self.seed,
            hedge_delay=self.hedge_delay, stats_mode=self.stats_mode,
            slo=self.slo, injections=tuple(injections),
            service_model=self.service_model, lengths=self.lengths,
            retry=self.retry, breaker=self.breaker, control=self.control)
