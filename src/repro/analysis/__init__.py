"""Static analysis for the repro codebase: determinism lint + spec checks.

Two layers, one CLI (``python -m repro.analysis``):

``repro.analysis.lint``
    An AST rule engine over the repo's own source.  Each rule targets a
    bug class this project has actually shipped and later fixed by hand
    (process-dependent ``hash()`` seeding, collapsed per-repetition RNG
    streams, wall-clock reads inside simulated time, stripped
    ``assert`` invariants, silent broad excepts, jax purity hazards in
    traced bodies).  Findings are suppressible inline with
    ``# repro: noqa[RULE]``.

``repro.analysis.check``
    Static validators over ``Scenario``/``Sweep``/``Experiment``
    declarations: a backend capability matrix (unsupported injections
    fail at check time, not mid-run), seed-collision detection across
    sweep axes, and schedule sanity (offered load, horizon coverage).
"""
from repro.analysis.check import (  # noqa: F401
    CheckFinding,
    check_scenario,
    check_sweep,
)
from repro.analysis.lint.engine import (  # noqa: F401
    Finding,
    Rule,
    SourceFile,
    lint_paths,
    lint_text,
)
