"""Static spec validators over Scenario/Sweep/Experiment declarations.

``check_scenario`` compiles one declaration (cheap — no simulation)
and runs the capability matrix against a target backend plus schedule
sanity.  ``check_sweep`` additionally enumerates the sweep's derived
seeds for collisions and validates every point against the backend it
would actually run on (a per-point ``runtime`` axis overrides the
sweep default).
"""
from __future__ import annotations

from typing import Optional

from repro.analysis.check.capability import (  # noqa: F401
    BACKENDS,
    CAPABILITIES,
    INJECTION_KINDS,
    format_matrix,
    required_features,
    support_matrix,
    unsupported_on,
)
from repro.analysis.check.findings import CheckFinding  # noqa: F401
from repro.analysis.check.schedule import check_schedule  # noqa: F401
from repro.analysis.check.seeds import check_sweep_seeds  # noqa: F401


def _compile(obj):
    """Scenario -> Experiment; an Experiment passes through."""
    if hasattr(obj, "compile"):
        return obj.compile()
    return obj


def capability_findings(exp, backend: str, target: str) -> list:
    """Unsupported-feature errors, with the capability matrix."""
    missing = unsupported_on(exp, backend)
    if not missing:
        return []
    details = "\n".join(f"  {d}: {f} not supported on {backend!r}"
                        for f, d in missing)
    return [CheckFinding(
        rule="capability", severity="error", target=target,
        message=(f"cannot run on backend {backend!r}:\n{details}\n"
                 f"{format_matrix(exp)}"))]


def check_scenario(scenario, backend: Optional[str] = None,
                   dt: float = 0.05) -> list:
    """-> [CheckFinding] for one Scenario/Experiment declaration.

    With ``backend``, unsupported features are errors; without, only
    scenario-internal problems (compile failures, schedule sanity)
    are reported — a declaration may legitimately target one backend.
    """
    target = getattr(scenario, "name", None) or \
        type(scenario).__name__
    try:
        exp = _compile(scenario)
    except (ValueError, TypeError, KeyError) as e:
        return [CheckFinding(rule="compile", severity="error",
                             target=target,
                             message=f"declaration does not compile: "
                                     f"{e}")]
    findings = []
    if backend is not None:
        findings.extend(capability_findings(exp, backend, target))
    findings.extend(check_schedule(exp, target, dt=dt))
    return findings


def check_sweep(sweep, dt: float = 0.05,
                schedule_points: int = 8) -> list:
    """-> [CheckFinding] for one Sweep declaration.

    Seed collisions over the full task list; capability + schedule
    per point (schedule checks capped at ``schedule_points`` points —
    the load model is per-point work)."""
    from repro.sweep.spec import PointCtx

    target = sweep.name
    findings = list(check_sweep_seeds(sweep, target=target))
    for index, params in enumerate(sweep.point_dicts()):
        seed, stream = sweep.seed_for(index, 0)
        ctx = PointCtx(params=dict(params), index=index, rep=0,
                       seed=seed, stream=stream)
        point_target = f"{target}[{index}]"
        try:
            exp = _compile(sweep.factory(ctx))
        except (ValueError, TypeError, KeyError) as e:
            findings.append(CheckFinding(
                rule="compile", severity="error", target=point_target,
                message=f"point {params} does not compile: {e}"))
            continue
        backend = params.get("runtime", sweep.runtime)
        findings.extend(capability_findings(exp, backend, point_target))
        if index < schedule_points:
            findings.extend(check_schedule(exp, point_target, dt=dt))
    return findings


def has_errors(findings) -> bool:
    return any(f.severity == "error" for f in findings)
