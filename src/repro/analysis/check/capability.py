"""Backend capability matrix for experiment features.

One place that states which backend supports which feature, derived
from the runtimes themselves: the simulator applies every injection
kind (``Simulator.apply_injection``), the engine runtime whitelists
``_ENGINE_INJECTIONS`` and has no hedging or legacy path, and the
vector compiler lowers speed/fail/drain/policy but surfaces hedging
and injection-time joins through ``VectorProgram.unsupported`` and
refuses ``legacy_mode`` outright.  ``python -m repro.analysis check``
uses this to reject a declaration at check time instead of mid-run
(PR 5 only got this to a runtime warning).

Features are strings: ``injection:<kind>`` for each injection kind,
plus the ``hedge_delay``/``legacy_mode`` experiment flags and the
resilience/control fields (``retry``, ``breaker``, ``control``).
"""
from __future__ import annotations

from typing import Optional

BACKENDS = ("sim", "engine", "vector")

INJECTION_KINDS = ("server_fail", "server_speed", "server_join",
                   "server_drain", "set_policy", "set_hedge",
                   "set_admission", "set_scale", "set_retry",
                   "set_breaker")

_ALL = frozenset([f"injection:{k}" for k in INJECTION_KINDS] +
                 ["hedge_delay", "legacy_mode", "retry", "breaker",
                  "control"])

#: feature -> backends supporting it (mirrors the runtime contracts)
CAPABILITIES = {
    "sim": frozenset(_ALL),
    # core/runtime.py _ENGINE_INJECTIONS: join/drain/fail/policy plus the
    # resilience kinds; hedging and legacy mode stay simulator-only
    "engine": frozenset({"injection:server_join",
                         "injection:server_drain",
                         "injection:server_fail",
                         "injection:set_policy",
                         "injection:set_admission",
                         "injection:set_scale",
                         "injection:set_retry",
                         "injection:set_breaker",
                         "retry", "breaker", "control"}),
    # vector/compile.py: hedging, injection-time joins, and per-request
    # retry/breaker mechanics -> unsupported (no fluid analogue);
    # admission/scale lower as thinning + capacity schedules, and the
    # controller replays through the fluid pre-pass
    "vector": frozenset({"injection:server_fail",
                         "injection:server_speed",
                         "injection:server_drain",
                         "injection:set_policy",
                         "injection:set_admission",
                         "injection:set_scale",
                         "control"}),
}


def required_features(exp) -> list:
    """-> [(feature, human detail)] the experiment needs at runtime."""
    feats = []
    if getattr(exp, "legacy_mode", False):
        feats.append(("legacy_mode", "legacy_mode=True"))
    if getattr(exp, "hedge_delay", None) is not None:
        feats.append(("hedge_delay",
                      f"hedge_delay={exp.hedge_delay:g}s"))
    if getattr(exp, "retry", None) is not None:
        feats.append(("retry", f"retry={exp.retry!r}"))
    if getattr(exp, "breaker", None) is not None:
        feats.append(("breaker", f"breaker={exp.breaker!r}"))
    ctrl = getattr(exp, "control", None)
    if ctrl is not None:
        feats.append(("control",
                      f"control={getattr(ctrl, 'name', ctrl)!s}"))
    for inj in getattr(exp, "injections", ()):
        feats.append((f"injection:{inj.kind}",
                      f"{inj.kind}@{inj.at:g}s"))
    return feats


def unsupported_on(exp, backend: str) -> list:
    """-> [(feature, detail)] the named backend cannot honor."""
    if backend not in CAPABILITIES:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"known: {', '.join(BACKENDS)}")
    caps = CAPABILITIES[backend]
    return [(f, d) for f, d in required_features(exp) if f not in caps]


def support_matrix(exp) -> dict:
    """-> {backend: [(feature, detail) it cannot honor]}."""
    return {b: unsupported_on(exp, b) for b in BACKENDS}


def format_matrix(exp, features: Optional[list] = None) -> str:
    """Render the capability matrix for the experiment's features."""
    feats = features if features is not None else \
        [f for f, _ in required_features(exp)]
    seen: list = []
    for f in feats:
        if f not in seen:
            seen.append(f)
    if not seen:
        return "  (no backend-gated features)"
    width = max(len(f) for f in seen)
    lines = ["  capability matrix (x = supported):",
             f"    {'feature':<{width}}  " +
             "  ".join(f"{b:>6}" for b in BACKENDS)]
    for f in seen:
        marks = "  ".join(
            f"{'x' if f in CAPABILITIES[b] else '.':>6}"
            for b in BACKENDS)
        lines.append(f"    {f:<{width}}  {marks}")
    return "\n".join(lines)
