"""Schedule sanity: offered load, horizon coverage, dead intervals.

Reuses the vector compiler's static lowering (``compile_experiment``)
as a load model — per-slot offered request rates, per-slot capacity
after joins/drains/failures/speed changes — WITHOUT running anything.
From that it derives the per-slot utilization ρ and warns when the
declared schedule saturates (ρ≥1 sustained: the queue grows without
bound, so tail percentiles measure the horizon, not the system),
when clients or injections start after the horizon ends, and when
long stretches of the horizon carry zero offered load.
"""
from __future__ import annotations

import math

import numpy as np

from repro.analysis.check.findings import CheckFinding

#: sustained-overload threshold: consecutive seconds at rho >= 1
OVERLOAD_SECONDS = 2.0
#: fraction of the horizon with zero offered load that draws a warning
ZERO_RATE_FRAC = 0.5


def _longest_run(mask: np.ndarray) -> int:
    """Length (slots) of the longest consecutive True run."""
    best = cur = 0
    for v in mask:
        cur = cur + 1 if v else 0
        best = max(best, cur)
    return best


def offered_rho(prog) -> tuple:
    """-> (rho[T], offered[T] work-seconds/s, capacity[T]) from a
    ``VectorProgram``."""
    if prog.batched:
        per_req = prog.prefill_mean
        if prog.max_batch > 0 and prog.service is not None:
            per_req = per_req + prog.new_mean * \
                prog.service.step_time(prog.max_batch) / prog.max_batch
        offered = (prog.rate_conn.sum(axis=1) + prog.rate_free) * per_req
        capacity = (prog.speed * prog.active).sum(axis=1)
    else:
        offered = (prog.rate_conn * prog.work_mean[None, :]).sum(axis=1)
        if prog.rate_free.any():
            offered = offered + prog.rate_free * \
                float(prog.work_mean.mean())
        capacity = (prog.workers[None, :] * prog.speed *
                    prog.active).sum(axis=1)
    with np.errstate(divide="ignore", invalid="ignore"):
        rho = np.where(capacity > 0.0, offered / np.maximum(capacity,
                                                            1e-300),
                       np.where(offered > 0.0, np.inf, 0.0))
    return rho, offered, capacity


def check_schedule(exp, target: str, dt: float = 0.05) -> list:
    """-> [CheckFinding] for one compiled ``Experiment``."""
    findings = []
    dur = float(exp.duration)
    if dur <= 0.0 or not math.isfinite(dur):
        findings.append(CheckFinding(
            rule="schedule", severity="error", target=target,
            message=(f"duration={dur:g} leaves no finite measurement "
                     f"horizon")))
        return findings
    for c in exp.clients:
        if c.start_time >= dur:
            findings.append(CheckFinding(
                rule="schedule", severity="warning", target=target,
                message=(f"client {c.client_id!r} starts at "
                         f"{c.start_time:g}s, at/after the {dur:g}s "
                         f"horizon — it never sends")))
    for inj in exp.injections:
        if inj.at >= dur:
            findings.append(CheckFinding(
                rule="schedule", severity="warning", target=target,
                message=(f"injection {inj.kind}@{inj.at:g}s fires "
                         f"at/after the {dur:g}s horizon — it never "
                         f"happens")))

    from repro.vector.compile import VectorCompileError, \
        compile_experiment
    try:
        prog = compile_experiment(exp, dt=min(dt, dur / 4.0))
    except VectorCompileError:
        # legacy-mode experiments have no static load model; the
        # horizon checks above still ran
        return findings
    rho, offered, capacity = offered_rho(prog)

    if not np.any(offered > 0.0):
        findings.append(CheckFinding(
            rule="schedule", severity="error", target=target,
            message="no client offers any load inside the horizon"))
        return findings

    # overload is only unbounded when nothing manages it: client
    # timeouts bound queue residence, admission control sheds the
    # excess, and a closed-loop controller reacts to it — a scenario
    # carrying any of those is *supposed* to offer rho>=1
    managed = (getattr(exp, "retry", None) is not None
               or getattr(exp, "control", None) is not None
               or any(inj.kind == "set_admission"
                      for inj in exp.injections))
    over = rho >= 1.0
    run_s = _longest_run(over) * prog.dt
    if not managed and run_s >= min(OVERLOAD_SECONDS, 0.5 * dur):
        frac = float(over.mean())
        peak = float(np.max(rho[np.isfinite(rho)], initial=0.0))
        peak_s = "inf" if np.isinf(rho).any() else f"{peak:.2f}"
        findings.append(CheckFinding(
            rule="schedule", severity="warning", target=target,
            message=(f"offered load sustains rho>=1 for {run_s:.1f}s "
                     f"({frac:.0%} of the horizon, peak rho="
                     f"{peak_s}) — queues grow without bound, tail "
                     f"percentiles measure the horizon length")))

    zero_frac = float((offered <= 0.0).mean())
    if zero_frac >= ZERO_RATE_FRAC:
        findings.append(CheckFinding(
            rule="schedule", severity="warning", target=target,
            message=(f"{zero_frac:.0%} of the horizon carries zero "
                     f"offered load — shrink the horizon or the "
                     f"gaps dominate every mean")))

    warmup = getattr(exp, "interval", 0.0) or 0.0
    if warmup >= dur:
        findings.append(CheckFinding(
            rule="schedule", severity="warning", target=target,
            message=(f"reporting interval {warmup:g}s >= horizon "
                     f"{dur:g}s — at most one interval sample")))
    return findings
