"""Seed-collision detection across sweep axes.

Enumerates the exact ``(seed, rng_stream)`` pair every (point, rep)
task of a ``Sweep`` would receive — the same ``seed_for`` the executor
calls — and reports collisions.  This is how ad-hoc seeders go wrong:
``base + 1000*(rep+1)`` makes point-0/rep-1 replay point-1/rep-0, so
supposedly independent repetitions are correlated and every CI is
quietly too narrow ("Tell-Tale Tail Latencies").

The ``"fixed"`` seeder is exempt by contract: it hands every task the
same seed on purpose and the factory owns per-rep variation.  For the
``"spawn"`` seeder the spawn keys ``(point, rep)`` are unique by
construction, so the derived 32-bit seeds are additionally checked for
the (astronomically unlikely, but cheap to verify) hash collision.
"""
from __future__ import annotations

from repro.analysis.check.findings import CheckFinding

#: refuse to enumerate grids beyond this many tasks
MAX_TASKS = 200_000


def check_sweep_seeds(sweep, target: str = "") -> list:
    """-> [CheckFinding] for duplicate (seed, stream) pairs."""
    target = target or getattr(sweep, "name", "sweep")
    findings = []
    if isinstance(sweep.seeder, str) and sweep.seeder == "fixed":
        return findings
    tasks = sweep.tasks()
    if len(tasks) > MAX_TASKS:
        findings.append(CheckFinding(
            rule="seed-collision", severity="warning", target=target,
            message=(f"grid has {len(tasks)} tasks; seed enumeration "
                     f"skipped beyond {MAX_TASKS}")))
        tasks = tasks[:MAX_TASKS]
    seen: dict = {}
    for index, _params, rep in tasks:
        key = sweep.seed_for(index, rep)
        prior = seen.get(key)
        if prior is not None:
            pi, pr = prior
            seeder = sweep.seeder if isinstance(sweep.seeder, str) \
                else getattr(sweep.seeder, "__name__", "custom")
            findings.append(CheckFinding(
                rule="seed-collision", severity="error", target=target,
                message=(f"seeder {seeder!r}: point {index} rep {rep} "
                         f"and point {pi} rep {pr} derive the same "
                         f"(seed, stream)={key} — repetitions are "
                         f"correlated, not independent (use the "
                         f"'spawn' seeder)")))
        else:
            seen[key] = (index, rep)
    return findings
