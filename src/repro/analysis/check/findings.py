"""Shared finding type for the spec validators."""
from __future__ import annotations

from dataclasses import asdict, dataclass


@dataclass(frozen=True)
class CheckFinding:
    """One spec-validation problem on one declaration."""
    rule: str           # capability | seed-collision | schedule | compile
    severity: str       # "error" | "warning"
    target: str         # scenario/sweep name (plus point, when relevant)
    message: str

    def format(self) -> str:
        return f"{self.target}: {self.severity}: {self.rule}: " \
               f"{self.message}"

    def to_dict(self) -> dict:
        return asdict(self)
