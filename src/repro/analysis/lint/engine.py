"""Rule engine for the determinism/purity linter.

The engine owns everything that is not rule logic: walking files,
parsing, inline ``# repro: noqa[RULE]`` suppressions, severity
accounting, and human/JSON rendering.  Rules are small classes with a
``check(source_file)`` generator yielding ``(node, message)`` pairs —
see ``rules.py`` and ``jaxrules.py`` for the catalogue.

Scoping: every rule declares the repo-relative path prefixes it
applies to (``scope=None`` means all files).  The relative path is the
portion after the last ``repro/`` segment of the file path, so the
engine works from any checkout location; fixture files may override it
with a ``# lint-path: core/whatever.py`` directive on any line, which
lets the golden-file tests exercise path-scoped rules from ``tests/``.

Suppression: ``# repro: noqa[rule-a,rule-b]`` on the finding's line
suppresses those rules there; ``# repro: noqa`` (no bracket) blankets
the line.  Suppressed findings stay visible with ``--show-suppressed``
and in the JSON output — they are audit trail, not deletion.
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import asdict, dataclass
from typing import Iterable, Iterator, Optional, Sequence

SEVERITIES = ("warning", "error")

_NOQA_RE = re.compile(r"#\s*repro:\s*noqa(?:\[([^\]]*)\])?")
_PATH_RE = re.compile(r"^#\s*lint-path:\s*(\S+)", re.MULTILINE)


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""
    rule: str
    severity: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False

    def format(self) -> str:
        tail = "  [suppressed]" if self.suppressed else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.severity}: {self.rule}: {self.message}{tail}")

    def to_dict(self) -> dict:
        return asdict(self)


class Rule:
    """Base class for lint rules.

    Subclasses set ``name`` (the kebab-case id used in ``noqa[...]``),
    ``severity`` (``"error"`` or ``"warning"``), ``description`` (one
    line, shown by ``--list-rules``) and ``scope`` (tuple of rel-path
    prefixes, or ``None`` for every file), and implement ``check``.
    """
    name: str = ""
    severity: str = "error"
    description: str = ""
    scope: Optional[tuple] = None

    def applies_to(self, sf: "SourceFile") -> bool:
        if self.scope is None:
            return True
        return sf.rel.startswith(tuple(self.scope))

    def check(self, sf: "SourceFile") -> Iterator[tuple]:
        """Yield ``(node, message)`` pairs for each violation."""
        raise NotImplementedError


class SourceFile:
    """A parsed source file plus its suppression and scoping metadata."""

    def __init__(self, path: str, text: str, rel: Optional[str] = None):
        self.path = path
        self.text = text
        self.tree = ast.parse(text, filename=path)
        self.rel = rel if rel is not None else self._infer_rel(path, text)
        # line -> None (blanket) | frozenset of rule names
        self.noqa: dict = {}
        for lineno, line in enumerate(text.splitlines(), start=1):
            m = _NOQA_RE.search(line)
            if not m:
                continue
            names = m.group(1)
            if names is None:
                self.noqa[lineno] = None
            else:
                self.noqa[lineno] = frozenset(
                    n.strip() for n in names.split(",") if n.strip())

    @staticmethod
    def _infer_rel(path: str, text: str) -> str:
        m = _PATH_RE.search(text)
        if m:
            return m.group(1)
        parts = os.path.abspath(path).replace(os.sep, "/").split("/")
        if "repro" in parts:
            idx = len(parts) - 1 - parts[::-1].index("repro")
            rel = "/".join(parts[idx + 1:])
            if rel:
                return rel
        return parts[-1]

    def suppresses(self, rule_name: str, line: int) -> bool:
        if line not in self.noqa:
            return False
        names = self.noqa[line]
        return names is None or rule_name in names


def default_rules() -> list:
    """The full rule catalogue (lazy import: rules depend on Rule)."""
    from repro.analysis.lint import jaxrules, rules
    return list(rules.RULES) + list(jaxrules.RULES)


def iter_python_files(paths: Iterable[str]) -> list:
    out = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        out.append(os.path.join(root, f))
        elif p.endswith(".py"):
            out.append(p)
    return out


def lint_source(sf: SourceFile, rules: Optional[Sequence[Rule]] = None,
                ) -> list:
    """All findings (suppressed ones included, marked) for one file."""
    if rules is None:
        rules = default_rules()
    findings = []
    for rule in rules:
        if not rule.applies_to(sf):
            continue
        for node, message in rule.check(sf):
            line = getattr(node, "lineno", 1)
            col = getattr(node, "col_offset", 0)
            findings.append(Finding(
                rule=rule.name, severity=rule.severity, path=sf.path,
                line=line, col=col, message=message,
                suppressed=sf.suppresses(rule.name, line)))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def lint_text(text: str, rel: Optional[str] = None, path: str = "<text>",
              rules: Optional[Sequence[Rule]] = None) -> list:
    return lint_source(SourceFile(path, text, rel=rel), rules=rules)


def lint_paths(paths: Iterable[str],
               rules: Optional[Sequence[Rule]] = None) -> list:
    if rules is None:
        rules = default_rules()
    findings = []
    for path in iter_python_files(paths):
        with open(path, encoding="utf-8") as f:
            text = f.read()
        try:
            sf = SourceFile(path, text)
        except SyntaxError as e:
            findings.append(Finding(
                rule="syntax-error", severity="error", path=path,
                line=e.lineno or 1, col=e.offset or 0,
                message=f"cannot parse: {e.msg}"))
            continue
        findings.extend(lint_source(sf, rules=rules))
    return findings


def summarize(findings: Sequence[Finding]) -> dict:
    active = [f for f in findings if not f.suppressed]
    return {
        "errors": sum(1 for f in active if f.severity == "error"),
        "warnings": sum(1 for f in active if f.severity == "warning"),
        "suppressed": sum(1 for f in findings if f.suppressed),
    }


def render_human(findings: Sequence[Finding],
                 show_suppressed: bool = False) -> str:
    lines = [f.format() for f in findings
             if show_suppressed or not f.suppressed]
    s = summarize(findings)
    lines.append(f"{s['errors']} error(s), {s['warnings']} warning(s), "
                 f"{s['suppressed']} suppressed")
    return "\n".join(lines)


def render_json(findings: Sequence[Finding]) -> str:
    return json.dumps({"findings": [f.to_dict() for f in findings],
                       "summary": summarize(findings)}, indent=2)


def exit_code(findings: Sequence[Finding], strict: bool = False) -> int:
    s = summarize(findings)
    if s["errors"] or (strict and s["warnings"]):
        return 1
    return 0
