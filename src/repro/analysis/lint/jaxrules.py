"""jax purity rules for traced bodies in ``vector/``, ``plan/`` and the
vector Pallas kernels.

A function body is considered *traced* when any of these hold:

* it is decorated with ``jit`` / ``jax.jit`` (or a ``partial`` of it);
* it is passed syntactically to ``lax.scan`` / ``jax.lax.scan`` /
  ``jax.jit`` / ``pl.pallas_call`` / ``jax.grad`` /
  ``jax.value_and_grad`` at a call site in the same file — a body
  handed to the autodiff tracers is traced exactly like a jitted one,
  which is how the planner's loss closures get covered;
* it follows the repo's scan-body convention: a (possibly nested)
  function whose parameters are exactly ``(carry, xs)`` — the shape
  ``_scalar_step``/``_batched_step`` build and hand to ``lax.scan``.

Inside a traced body the rules track a taint set seeded from the
traced parameters and propagated through simple assignments: Python
control flow on a traced value retraces or crashes under jit
(``jit-python-branch``), ``.item()``/``float()``/``int()``/``bool()``
forces concretization (``jit-concretize``), and writes to captured
state escape the trace and silently desynchronize
(``jit-captured-mutation``).
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional, Set

from repro.analysis.lint.engine import Rule, SourceFile
from repro.analysis.lint.rules import dotted_name

VECTOR_SCOPE = ("vector/", "plan/", "kernels/vector_step.py",
                "kernels/vector_quantiles.py")

SCAN_CALLS = ("lax.scan", "jax.lax.scan")
JIT_CALLS = ("jit", "jax.jit")
#: a Pallas kernel body is a traced function too — same purity rules
PALLAS_CALLS = ("pl.pallas_call", "pallas_call", "pallas.pallas_call")
#: ...and so is anything handed to the autodiff tracers
GRAD_CALLS = ("jax.grad", "grad", "jax.value_and_grad", "value_and_grad")
CONCRETIZE_BUILTINS = ("float", "int", "bool")


def _param_names(fn: ast.AST) -> list:
    args = fn.args
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return names


def _is_jit_decorated(fn: ast.AST) -> bool:
    for dec in fn.decorator_list:
        name = dotted_name(dec)
        if name in JIT_CALLS:
            return True
        if isinstance(dec, ast.Call):
            cname = dotted_name(dec.func)
            if cname in JIT_CALLS:
                return True
            if cname in ("partial", "functools.partial") and dec.args:
                if dotted_name(dec.args[0]) in JIT_CALLS:
                    return True
    return False


def _traced_callee_names(tree: ast.AST) -> Set[str]:
    """Function names passed as the body argument of scan/jit calls."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or not node.args:
            continue
        name = dotted_name(node.func)
        if name in SCAN_CALLS + JIT_CALLS + PALLAS_CALLS + GRAD_CALLS:
            first = dotted_name(node.args[0])
            if first is not None:
                out.add(first.split(".")[-1])
    return out


def iter_traced_functions(sf: SourceFile) -> Iterator[ast.AST]:
    by_call = _traced_callee_names(sf.tree)
    for node in ast.walk(sf.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        params = _param_names(node)
        if _is_jit_decorated(node) or node.name in by_call or \
                params[:2] == ["carry", "xs"]:
            yield node


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _assigned_names(target: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(target):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
            out.add(n.id)
    return out


def taint_set(fn: ast.AST) -> Set[str]:
    """Traced parameters plus names assigned from tainted values,
    propagated to a fixpoint (flow-insensitive, per function)."""
    tainted: Set[str] = set(_param_names(fn))
    changed = True
    while changed:
        changed = False
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                value = node.value
                if value is None:
                    continue
                if _names_in(value) & tainted:
                    targets = node.targets if isinstance(node, ast.Assign) \
                        else [node.target]
                    for t in targets:
                        new = _assigned_names(t) - tainted
                        if new:
                            tainted |= new
                            changed = True
    return tainted


def _local_names(fn: ast.AST) -> Set[str]:
    out = set(_param_names(fn))
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                out |= _assigned_names(t)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            out |= _assigned_names(node.target)
        elif isinstance(node, ast.withitem) and node.optional_vars:
            out |= _assigned_names(node.optional_vars)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.add(node.name)
    return out


class _TracedRule(Rule):
    scope = VECTOR_SCOPE

    def check(self, sf: SourceFile) -> Iterator[tuple]:
        for fn in iter_traced_functions(sf):
            tainted = taint_set(fn)
            yield from self.check_traced(fn, tainted)

    def check_traced(self, fn: ast.AST, tainted: Set[str],
                     ) -> Iterator[tuple]:
        raise NotImplementedError


class JitPythonBranch(_TracedRule):
    """Python ``if``/``while`` on a traced value inside a jit/scan
    body: the branch is resolved at trace time, so every execution
    replays one arm (or jit raises a ConcretizationTypeError).  Use
    ``jnp.where`` / ``lax.cond`` / ``lax.select``."""
    name = "jit-python-branch"
    severity = "error"
    description = ("Python control flow on a traced value in a "
                   "jit/scan body (use jnp.where/lax.cond)")

    def check_traced(self, fn: ast.AST, tainted: Set[str],
                     ) -> Iterator[tuple]:
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                if _names_in(node.test) & tainted:
                    kind = {ast.If: "if", ast.While: "while",
                            ast.IfExp: "conditional expression"}[
                                type(node)]
                    yield node, (f"Python {kind} on a traced value "
                                 f"inside a traced body — use "
                                 f"jnp.where or lax.cond")
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                if _names_in(node.iter) & tainted:
                    yield node, ("Python loop over a traced value "
                                 "inside a traced body — use "
                                 "lax.scan/fori_loop")


class JitConcretize(_TracedRule):
    """``.item()`` / ``float()`` / ``int()`` / ``bool()`` on a traced
    value forces host concretization — a tracer error under jit, a
    silent recompile outside it."""
    name = "jit-concretize"
    severity = "error"
    description = (".item()/float()/int()/bool() on a traced value "
                   "in a jit/scan body")

    def check_traced(self, fn: ast.AST, tainted: Set[str],
                     ) -> Iterator[tuple]:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and \
                    _names_in(node.func.value) & tainted:
                yield node, (".item() concretizes a traced value — "
                             "keep it an array")
            elif isinstance(node.func, ast.Name) and \
                    node.func.id in CONCRETIZE_BUILTINS and node.args and \
                    _names_in(node.args[0]) & tainted:
                yield node, (f"{node.func.id}() concretizes a traced "
                             f"value — keep it an array")


class JitCapturedMutation(_TracedRule):
    """Writes to state captured from an enclosing scope inside a
    traced body: the mutation happens once at trace time, then never
    again — the classic silent-desync hazard."""
    name = "jit-captured-mutation"
    severity = "error"
    description = ("mutation of captured state inside a jit/scan "
                   "body (thread it through the carry)")

    MUTATORS = ("append", "extend", "insert", "add", "update", "pop",
                "remove", "clear", "setdefault")

    def check_traced(self, fn: ast.AST, tainted: Set[str],
                     ) -> Iterator[tuple]:
        local = _local_names(fn)
        for node in ast.walk(fn):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                kind = "global" if isinstance(node, ast.Global) \
                    else "nonlocal"
                yield node, (f"{kind} write inside a traced body "
                             f"mutates captured state — thread it "
                             f"through the carry")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    base = self._base_name(t)
                    if base is not None and base not in local:
                        yield node, (f"write to captured "
                                     f"{base!r} inside a traced body "
                                     f"— thread it through the carry")
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in self.MUTATORS:
                base = dotted_name(node.func.value)
                if base is not None and \
                        base.split(".")[0] not in local:
                    yield node, (f"{base}.{node.func.attr}() mutates "
                                 f"captured state inside a traced "
                                 f"body")

    @staticmethod
    def _base_name(target: ast.AST) -> Optional[str]:
        node = target
        while isinstance(node, (ast.Attribute, ast.Subscript)):
            node = node.value
        if isinstance(node, ast.Name) and node is not target:
            return node.id
        return None


RULES = (JitPythonBranch(), JitConcretize(), JitCapturedMutation())
