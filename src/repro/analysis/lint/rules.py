"""General determinism rules: seeding, randomness, clocks, invariants.

Each rule mirrors a bug class this repo shipped and later fixed by
hand (see CHANGES.md): process-dependent ``hash(app)`` seeding (PR 4),
per-repetition RNG streams collapsing confidence intervals (PR 1),
silent backend divergence behind broad excepts (PR 3/5).  The scope of
the measurement-path rules is the set of packages whose code runs
inside an experiment: ``core``, ``vector``, ``sweep``, ``scenarios``,
``serving`` — plus ``analysis`` itself, so the linter eats its own
dogfood.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.lint.engine import Rule, SourceFile

#: packages whose code executes inside a measurement
MEASUREMENT_SCOPE = ("core/", "vector/", "sweep/", "scenarios/",
                     "serving/", "analysis/", "plan/", "cache/")

#: call suffixes that consume a seed as their first positional argument
SEED_SINK_SUFFIXES = ("default_rng", "SeedSequence", "RandomState",
                      "PRNGKey", "Random")

#: draws on numpy's hidden module-level global RNG
NP_GLOBAL_DRAWS = ("rand", "randn", "randint", "random", "choice",
                   "shuffle", "permutation", "uniform", "normal",
                   "exponential", "lognormal", "poisson")

WALLCLOCK_CALLS = ("time.time", "time.monotonic", "time.perf_counter",
                   "time.time_ns", "time.monotonic_ns",
                   "time.perf_counter_ns", "datetime.now",
                   "datetime.utcnow", "datetime.datetime.now",
                   "datetime.datetime.utcnow")


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _call_name(node: ast.Call) -> Optional[str]:
    return dotted_name(node.func)


def _is_seed_sink(name: Optional[str]) -> bool:
    return bool(name) and name.split(".")[-1] in SEED_SINK_SUFFIXES


def _contains_hash_call(node: ast.AST) -> Optional[ast.Call]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and \
                isinstance(sub.func, ast.Name) and \
                sub.func.id in ("hash", "id"):
            return sub
    return None


class SeedFromHash(Rule):
    """``hash()``/``id()`` feeding a seed — both are process-dependent
    (PYTHONHASHSEED / allocator), so 'seeded' runs silently diverge
    across processes.  The shipped instance was ``hash(app)`` in the
    client-seed derivation, fixed in PR 4 with ``zlib.crc32``."""
    name = "seed-from-hash"
    severity = "error"
    description = ("hash()/id() used in seed derivation "
                   "(process-dependent; use zlib.crc32 or SeedSequence)")
    scope = None

    def check(self, sf: SourceFile) -> Iterator[tuple]:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                name = _call_name(node)
                seedish = _is_seed_sink(name) or \
                    (name is not None and "seed" in name.split(".")[-1]
                     .lower())
                if seedish:
                    for arg in list(node.args) + \
                            [kw.value for kw in node.keywords]:
                        bad = _contains_hash_call(arg)
                        if bad is not None:
                            fn = bad.func.id  # type: ignore[union-attr]
                            yield bad, (f"{fn}() result feeds "
                                        f"{name}() — process-dependent "
                                        f"seeding (use zlib.crc32 or a "
                                        f"SeedSequence spawn key)")
                else:
                    for kw in node.keywords:
                        if kw.arg and "seed" in kw.arg.lower():
                            bad = _contains_hash_call(kw.value)
                            if bad is not None:
                                fn = bad.func.id  # type: ignore
                                yield bad, (f"{fn}() result feeds "
                                            f"{kw.arg}= — process-"
                                            f"dependent seeding")
            elif isinstance(node, (ast.Assign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                named_seed = any(
                    isinstance(t, ast.Name) and "seed" in t.id.lower()
                    for t in targets)
                if named_seed and node.value is not None:
                    bad = _contains_hash_call(node.value)
                    if bad is not None:
                        fn = bad.func.id  # type: ignore[union-attr]
                        yield bad, (f"{fn}() assigned to a seed "
                                    f"variable — process-dependent")


class StdlibRandom(Rule):
    """stdlib ``random`` in a measurement path.  Its global state leaks
    across components and it cannot thread the repo's
    ``(seed, entity_id, rep)`` tuple convention; use a
    ``np.random.Generator`` keyed by that tuple instead."""
    name = "stdlib-random"
    severity = "error"
    description = ("stdlib random in measurement code "
                   "(use seeded np.random.Generator)")
    scope = MEASUREMENT_SCOPE

    def check(self, sf: SourceFile) -> Iterator[tuple]:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "random":
                        yield node, ("stdlib 'random' imported in a "
                                     "measurement path — use a seeded "
                                     "np.random.Generator")
            elif isinstance(node, ast.ImportFrom):
                if node.module == "random" and node.level == 0:
                    yield node, ("stdlib 'random' imported in a "
                                 "measurement path — use a seeded "
                                 "np.random.Generator")


class UnseededRng(Rule):
    """RNG constructed from OS entropy (or the hidden numpy global
    stream) inside measurement code: repetitions become unreproducible
    and statistically untrackable.  The shipped instance was fresh
    ``default_rng()`` per repetition collapsing CIs, fixed in PR 1."""
    name = "unseeded-rng"
    severity = "error"
    description = ("unseeded RNG / numpy global-stream draw in "
                   "measurement code")
    scope = MEASUREMENT_SCOPE

    def check(self, sf: SourceFile) -> Iterator[tuple]:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if name is None:
                continue
            leaf = name.split(".")[-1]
            if leaf in ("default_rng", "SeedSequence") and \
                    not node.args and not node.keywords:
                yield node, (f"{leaf}() without a seed draws OS "
                             f"entropy — thread the (seed, entity_id, "
                             f"rep) tuple")
            elif name in ("np.random.seed", "numpy.random.seed",
                          "random.seed"):
                yield node, (f"{name}() mutates a hidden global "
                             f"stream — construct a Generator instead")
            elif name.startswith(("np.random.", "numpy.random.")) and \
                    leaf in NP_GLOBAL_DRAWS:
                yield node, (f"{name}() draws from numpy's global "
                             f"RNG — draw from a seeded Generator")


class SeedConvention(Rule):
    """Seed sinks taking a bare integer literal or ad-hoc arithmetic.
    Arithmetic like ``seed + 1000*(rep+1)`` collides across sweep
    points; constants silently share one stream between entities.  The
    repo's convention is a tuple ``(domain_tag, seed, entity_id, rep)``
    or a ``SeedSequence`` spawn key."""
    name = "seed-convention"
    severity = "warning"
    description = ("seed sink fed a bare literal or seed arithmetic "
                   "instead of the (seed, entity_id, rep) tuple")
    scope = MEASUREMENT_SCOPE

    def check(self, sf: SourceFile) -> Iterator[tuple]:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if not _is_seed_sink(name) or not node.args:
                continue
            arg = node.args[0]
            if isinstance(arg, ast.Constant) and \
                    isinstance(arg.value, int) and \
                    not isinstance(arg.value, bool):
                yield arg, (f"{name}({arg.value!r}): constant seed "
                            f"shares one stream across entities/reps — "
                            f"key by (seed, entity_id, rep)")
            elif isinstance(arg, ast.BinOp):
                yield arg, (f"{name}(...): ad-hoc seed arithmetic "
                            f"collides across sweep points — use a "
                            f"tuple seed or SeedSequence spawn key")


class WallclockInSim(Rule):
    """Wall-clock reads inside simulated-time code: latencies become a
    function of host load, not of the model.  Real-time backends must
    take an injectable ``clock`` callable (the engine runtime does)."""
    name = "wallclock-in-sim"
    severity = "error"
    description = ("wall-clock call in a simulated path "
                   "(inject a clock callable)")
    scope = ("core/", "vector/", "sweep/", "scenarios/", "analysis/",
             "plan/", "cache/")

    def check(self, sf: SourceFile) -> Iterator[tuple]:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                name = _call_name(node)
                if name in WALLCLOCK_CALLS:
                    yield node, (f"{name}() reads the wall clock in "
                                 f"simulated code — time must come "
                                 f"from the virtual clock")


class AssertInvariant(Rule):
    """``assert`` guarding a runtime invariant in non-test code:
    ``python -O`` strips it, so the guard silently vanishes exactly
    when someone optimizes a long sweep.  Raise ``RuntimeError`` /
    ``ValueError`` instead."""
    name = "assert-invariant"
    severity = "error"
    description = ("assert as runtime invariant in non-test code "
                   "(stripped under python -O)")
    scope = MEASUREMENT_SCOPE

    def check(self, sf: SourceFile) -> Iterator[tuple]:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Assert):
                yield node, ("assert is stripped under python -O — "
                             "raise RuntimeError/ValueError for "
                             "runtime invariants")


class BroadExcept(Rule):
    """Bare ``except`` / ``except Exception`` outside the documented
    error-row contract.  PR 3's silently noise-free engine backend hid
    behind exactly this shape; the sweep executor's error-row sites
    are the sanctioned exception and carry explicit suppressions."""
    name = "broad-except"
    severity = "error"
    description = ("bare/broad except outside the error-row contract "
                   "(catch the specific exception)")
    scope = MEASUREMENT_SCOPE

    def check(self, sf: SourceFile) -> Iterator[tuple]:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                yield node, ("bare except swallows everything "
                             "(including KeyboardInterrupt) — name "
                             "the exception")
                continue
            types = node.type.elts if isinstance(node.type, ast.Tuple) \
                else [node.type]
            for t in types:
                name = dotted_name(t)
                if name in ("Exception", "BaseException"):
                    yield node, (f"except {name} hides unrelated "
                                 f"failures — catch the specific "
                                 f"exception (error-row sites carry "
                                 f"an explicit noqa)")
                    break


RULES = (SeedFromHash(), StdlibRandom(), UnseededRng(), SeedConvention(),
         WallclockInSim(), AssertInvariant(), BroadExcept())
