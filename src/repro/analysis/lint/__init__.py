"""AST determinism/purity linter: engine + rule catalogue."""
from repro.analysis.lint.engine import (  # noqa: F401
    Finding,
    Rule,
    SourceFile,
    default_rules,
    lint_paths,
    lint_text,
)
