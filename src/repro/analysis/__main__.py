"""Static analysis CLI: determinism lint + spec checks.

    PYTHONPATH=src python -m repro.analysis src/              # lint
    PYTHONPATH=src python -m repro.analysis --strict src/     # CI gate
    PYTHONPATH=src python -m repro.analysis lint --list-rules
    PYTHONPATH=src python -m repro.analysis lint --json src/repro/core
    PYTHONPATH=src python -m repro.analysis check             # all specs
    PYTHONPATH=src python -m repro.analysis check \
        --scenario churn-storm --backend vector               # rejects
    PYTHONPATH=src python -m repro.analysis check --sweep-file s.json

``lint`` (the default subcommand) runs the AST rule catalogue over the
given paths and exits 1 on unsuppressed errors (``--strict`` also
fails warnings).  ``check`` validates declarations without running
them: all registered canonical scenarios and the built-in named sweep
by default, or one scenario against one backend with ``--scenario``/
``--backend`` — where an unsupported injection is a check-time error
with the full capability matrix.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.lint.engine import (
    default_rules,
    exit_code,
    lint_paths,
    render_human,
    render_json,
)


def _lint_main(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis lint",
        description="AST determinism/purity linter")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to lint (default: src)")
    ap.add_argument("--strict", action="store_true",
                    help="warnings also fail the exit code")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="print suppressed findings too")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in default_rules():
            scope = ",".join(rule.scope) if rule.scope else "all files"
            print(f"{rule.name:<24} {rule.severity:<8} [{scope}]")
            print(f"{'':<24} {rule.description}")
        return 0

    paths = args.paths or ["src"]
    findings = lint_paths(paths)
    if args.as_json:
        print(render_json(findings))
    else:
        print(render_human(findings,
                           show_suppressed=args.show_suppressed))
    return exit_code(findings, strict=args.strict)


def _iter_default_sweeps():
    """The repo's named sweeps: today, the built-in CI smoke grid."""
    from repro.sweep.__main__ import SMOKE, _sweep_from_decl
    yield _sweep_from_decl(dict(SMOKE))


def _check_main(argv) -> int:
    from repro.analysis.check import (
        check_scenario,
        check_sweep,
        has_errors,
    )

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis check",
        description="static spec validation (no simulation runs)")
    ap.add_argument("--scenario", action="append", default=[],
                    metavar="NAME",
                    help="canonical scenario to validate (repeatable; "
                         "default: all registered)")
    ap.add_argument("--backend", default=None,
                    choices=["sim", "engine", "vector"],
                    help="target backend: unsupported features become "
                         "check-time errors")
    ap.add_argument("--sweep-file", action="append", default=[],
                    metavar="FILE",
                    help="JSON/YAML sweep declaration to validate")
    ap.add_argument("--strict", action="store_true",
                    help="warnings also fail the exit code")
    ap.add_argument("--json", action="store_true", dest="as_json")
    args = ap.parse_args(argv)

    from repro import scenarios

    findings = []
    names = args.scenario or list(scenarios.names())
    for name in names:
        try:
            scn = scenarios.get(name)
        except KeyError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        findings.extend(check_scenario(scn, backend=args.backend))

    if args.sweep_file:
        from repro.sweep.__main__ import _load_file, _sweep_from_decl
        for path in args.sweep_file:
            findings.extend(check_sweep(_sweep_from_decl(
                _load_file(path))))
    elif not args.scenario:
        # default mode also validates the repo's named sweeps
        for sweep in _iter_default_sweeps():
            findings.extend(check_sweep(sweep))

    errors = sum(1 for f in findings if f.severity == "error")
    warnings = sum(1 for f in findings if f.severity == "warning")
    if args.as_json:
        print(json.dumps({"findings": [f.to_dict() for f in findings],
                          "summary": {"errors": errors,
                                      "warnings": warnings}},
                         indent=2))
    else:
        for f in findings:
            print(f.format())
        checked = len(names) + (len(args.sweep_file) or
                                (0 if args.scenario else 1))
        print(f"checked {checked} declaration(s): {errors} error(s), "
              f"{warnings} warning(s)")
    if has_errors(findings) or (args.strict and warnings):
        return 1
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "check":
        return _check_main(argv[1:])
    if argv and argv[0] == "lint":
        argv = argv[1:]
    return _lint_main(argv)


if __name__ == "__main__":
    sys.exit(main())
