"""Pallas slot-advance kernels for the vector runtime.

Each ``lax.scan`` slot of the vector runtime becomes ONE
``pl.pallas_call`` over ``[cell, server]`` tiles — the water-fill /
Erlang-C-wait scalar family and the roofline batched family each get a
fused kernel instead of a chain of generic XLA ops.

The kernel bodies do not reimplement the queueing math: they call the
runtime's own ``_scalar_step`` / ``_batched_step`` (instantiated with
``jnp``) on their tiles.  Every reduction in that math runs over the
server axis only, so tiling the cell axis cannot change bits — in
interpret mode the kernels are bit-equal to the jnp reference path,
which is what the determinism tests pin.

The batched family's roofline constants (t_memory, t_compute/seq, mean
decode tokens) are staged into a VMEM scratch tile once per kernel
instance and broadcast from there against every server lane.  The
Erlang-C ``lgamma`` table never enters the kernels at all: by design
the stationary-wait law is precomputed host-side from the
deterministic offered load (see ``runtime._erlang_c``) — only the
fluid state advance runs in the scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.vector.runtime import _batched_step, _scalar_step

#: cells per kernel instance (f32 sublane tile)
CELL_TILE = 8


def _scalar_kernel(t_ref, dt_ref, c_ref, fail_ref,
                   Nc_ref, Wc_ref, Nf_ref, Wf_ref, act_ref, acc_ref,
                   spd_ref, U_ref, Q_ref, drops_ref,
                   U_out, Q_out, drops_out, waitU_out, waitf_out,
                   served_out, drained_out, Qs_out):
    consts = {"c": c_ref[...], "fail_slot": fail_ref[...],
              "dt": dt_ref[0, 0]}
    carry = (U_ref[...], Q_ref[...], drops_ref[:, 0])
    xs = (t_ref[0, 0], Nc_ref[...], Wc_ref[...], Nf_ref[:, 0],
          Wf_ref[:, 0], act_ref[...], acc_ref[...], spd_ref[...])
    (U, Q, drops), ys = _scalar_step(jnp, consts)(carry, xs)
    U_out[...] = U
    Q_out[...] = Q
    drops_out[...] = drops[:, None]
    waitU_out[...] = ys[0]
    waitf_out[...] = ys[1][:, None]
    served_out[...] = ys[2]
    drained_out[...] = ys[3]
    Qs_out[...] = ys[4]


def _batched_kernel(t_ref, dt_ref, c_ref, fail_ref, tm_ref, tc_ref,
                    nm_ref, Nc_ref, Wpc_ref, Wtc_ref, Nf_ref, Wpf_ref,
                    Wtf_ref, act_ref, acc_ref, spd_ref,
                    P_ref, T_ref, L_ref, drops_ref,
                    P_out, T_out, L_out, drops_out, wadm_out, sth_out,
                    narr_out, served_out, busy_out, Ls_out, tok_out,
                    roof_ref):
    # stage the roofline constants into scratch once per tile; the step
    # math broadcasts them against every server lane
    roof_ref[...] = jnp.concatenate(
        [tm_ref[...], tc_ref[...], nm_ref[...]], axis=-1)
    roof = roof_ref[...]
    consts = {"c": c_ref[...], "fail_slot": fail_ref[...],
              "dt": dt_ref[0, 0], "tm": roof[:, 0:1], "tc": roof[:, 1:2],
              "new_mean": roof[:, 2:3]}
    carry = (P_ref[...], T_ref[...], L_ref[...], drops_ref[:, 0])
    xs = (t_ref[0, 0], Nc_ref[...], Wpc_ref[...], Wtc_ref[...],
          Nf_ref[:, 0], Wpf_ref[:, 0], Wtf_ref[:, 0], act_ref[...],
          acc_ref[...], spd_ref[...])
    (P, T, L, drops), ys = _batched_step(jnp, consts)(carry, xs)
    P_out[...] = P
    T_out[...] = T
    L_out[...] = L
    drops_out[...] = drops[:, None]
    wadm_out[...] = ys[0]
    sth_out[...] = ys[1]
    narr_out[...] = ys[2]
    served_out[...] = ys[3]
    busy_out[...] = ys[4]
    Ls_out[...] = ys[5]
    tok_out[...] = ys[6]


def _block(cell_tile: int, width: int):
    return pl.BlockSpec((cell_tile, width), lambda i: (i, 0))


def _scalar_block():
    return pl.BlockSpec((1, 1), lambda i: (0, 0))


def scalar_slot_advance(consts: dict, carry, xs, *,
                        interpret: bool = False,
                        cell_tile: int = CELL_TILE):
    """One scalar-family slot advance as a fused kernel.  Shapes follow
    the scan: carry ``(U[C,S], Q[C,S], drops[C])``, xs ``(t, Nc, Wc,
    Nf[C], Wf[C], act, acc, spd)``."""
    U, Q, drops = carry
    t, Nc, Wc, Nf, Wf, act, acc, spd = xs
    C, S = U.shape
    if C % cell_tile:
        raise ValueError(f"cell axis {C} not a multiple of {cell_tile}")
    f32 = jnp.float32
    row, col, one = (lambda: _block(cell_tile, S),
                     lambda: _block(cell_tile, 1), _scalar_block)
    sds = jax.ShapeDtypeStruct
    outs = pl.pallas_call(
        _scalar_kernel,
        grid=(C // cell_tile,),
        in_specs=[one(), one(), row(), row(), row(), row(), col(),
                  col(), row(), row(), row(), row(), row(), col()],
        out_specs=[row(), row(), col(), row(), col(), row(), row(),
                   row()],
        out_shape=[sds((C, S), f32), sds((C, S), f32), sds((C, 1), f32),
                   sds((C, S), f32), sds((C, 1), f32), sds((C, S), f32),
                   sds((C, S), f32), sds((C, S), f32)],
        interpret=interpret,
    )(jnp.reshape(jnp.asarray(t, jnp.int32), (1, 1)),
      jnp.reshape(jnp.asarray(consts["dt"], f32), (1, 1)),
      consts["c"], consts["fail_slot"], Nc, Wc, Nf[:, None],
      Wf[:, None], act, acc, spd, U, Q, drops[:, None])
    U2, Q2, d2, waitU, waitf, served, drained, Qs = outs
    return (U2, Q2, d2[:, 0]), (waitU, waitf[:, 0], served, drained, Qs)


def batched_slot_advance(consts: dict, carry, xs, *,
                         interpret: bool = False,
                         cell_tile: int = CELL_TILE):
    """One batched-family (roofline) slot advance as a fused kernel.
    carry ``(P, T, L [C,S], drops[C])``, xs ``(t, Nc, Wpc, Wtc, Nf[C],
    Wpf[C], Wtf[C], act, acc, spd)``."""
    P, T, L, drops = carry
    t, Nc, Wpc, Wtc, Nf, Wpf, Wtf, act, acc, spd = xs
    C, S = P.shape
    if C % cell_tile:
        raise ValueError(f"cell axis {C} not a multiple of {cell_tile}")
    f32 = jnp.float32
    row, col, one = (lambda: _block(cell_tile, S),
                     lambda: _block(cell_tile, 1), _scalar_block)
    sds = jax.ShapeDtypeStruct
    outs = pl.pallas_call(
        _batched_kernel,
        grid=(C // cell_tile,),
        in_specs=[one(), one(), row(), row(), col(), col(), col(),
                  row(), row(), row(), col(), col(), col(), row(),
                  row(), row(), row(), row(), row(), col()],
        out_specs=[row(), row(), row(), col(), row(), row(), row(),
                   row(), row(), row(), row()],
        out_shape=[sds((C, S), f32), sds((C, S), f32), sds((C, S), f32),
                   sds((C, 1), f32), sds((C, S), f32), sds((C, S), f32),
                   sds((C, S), f32), sds((C, S), f32), sds((C, S), f32),
                   sds((C, S), f32), sds((C, S), f32)],
        scratch_shapes=[pltpu.VMEM((cell_tile, 3), f32)],
        interpret=interpret,
    )(jnp.reshape(jnp.asarray(t, jnp.int32), (1, 1)),
      jnp.reshape(jnp.asarray(consts["dt"], f32), (1, 1)),
      consts["c"], consts["fail_slot"], consts["tm"], consts["tc"],
      consts["new_mean"], Nc, Wpc, Wtc, Nf[:, None], Wpf[:, None],
      Wtf[:, None], act, acc, spd, P, T, L, drops[:, None])
    P2, T2, L2, d2, wadm, sth, narr, served, busy, Ls, tok = outs
    return ((P2, T2, L2, d2[:, 0]),
            (wadm, sth, narr, served, busy, Ls, tok))
