"""FlashAttention-2-style prefill kernel (Pallas, TPU).

Grid (B, H, nQ, nKV) — KV innermost so the (m, l, acc) online-softmax state
lives in VMEM scratch across KV steps.  GQA is handled in the K/V BlockSpec
index map (kv_head = q_head // group).  Causal and sliding-window masks are
computed from block-local iotas; fully-masked KV blocks are skipped with
``pl.when`` (the TPU grid is sequential, so skipping saves real MXU time).

Block sizes default to (128, 512): q-tile 128×hd + kv-tile 512×hd + scratch
acc 128×hd fp32 — well under VMEM for hd ≤ 256 and MXU-aligned.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: Optional[int], block_q: int, block_k: int,
            n_kv: int, scale: float):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # skip blocks that are entirely masked out
    relevant = None
    if causal:
        relevant = k_start <= q_start + block_q - 1
    if window is not None:
        win_ok = k_start + block_k - 1 > q_start - window
        relevant = win_ok if relevant is None else jnp.logical_and(relevant, win_ok)

    def _step():
        q = q_ref[0, :, 0, :].astype(F32) * scale          # (BQ, hd)
        k = k_ref[0, :, 0, :].astype(F32)                  # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32)  # (BQ, BK)
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = jnp.ones_like(s, bool)
        if causal:
            ok &= kp <= qp
        if window is not None:
            ok &= kp > qp - window
        s = jnp.where(ok, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0, :].astype(F32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=F32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    if relevant is None:
        _step()
    else:
        pl.when(relevant)(_step)

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 512, interpret: bool = False):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd) -> (B,S,H,hd)."""
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    n_q, n_kv = s // block_q, t // block_k
    grid = (b, h, n_q, n_kv)

    kernel = functools.partial(_kernel, causal=causal, window=window,
                               block_q=block_q, block_k=block_k, n_kv=n_kv,
                               scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b_, h_, qi, ki: (b_, ki, h_ // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b_, h_, qi, ki: (b_, ki, h_ // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), F32),       # m
            pltpu.VMEM((block_q,), F32),       # l
            pltpu.VMEM((block_q, hd), F32),    # acc
        ],
        interpret=interpret,
    )(q, k, v)
