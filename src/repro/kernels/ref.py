"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth for kernel allclose tests AND the path the
multi-pod dry-run lowers (so cost_analysis reflects true FLOPs/bytes).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int],
               k_valid=None) -> jax.Array:
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    if window is not None:
        ok &= diff < window
    if k_valid is not None:
        ok &= k_valid[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(F32)


def naive_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                    q_offset=0) -> jax.Array:
    """Materializing oracle. q: (B,S,H,hd); k,v: (B,T,KV,hd)."""
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qq = q.reshape(b, s, kv, g, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qq.astype(F32), k.astype(F32))
    logits = logits * (hd ** -0.5)
    q_pos = jnp.arange(s) + q_offset
    logits = logits + _mask_bias(q_pos, jnp.arange(t), causal=causal, window=window)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, hd)


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                      chunk: int = 512) -> jax.Array:
    """Query-chunked attention (scan + remat): the memory-safe reference."""
    from repro.util import cost_mode, opt_flags
    b, s, h, hd = q.shape
    # perf opt: under sequence parallelism q is already seq-sharded; the
    # q-chunk scan would re-gather it every chunk.  Materialize instead
    # (logits stay seq-sharded; ~1 GB/chip transient, remat'd in bwd).
    if cost_mode() or s <= chunk or "sp_naive_attn" in opt_flags():
        return naive_attention(q, k, v, causal=causal, window=window)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    qs = q.reshape(b, n, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def body(carry, args):
        i, qc = args
        return carry, naive_attention(qc, k, v, causal=causal, window=window,
                                      q_offset=i * chunk)

    _, outs = jax.lax.scan(body, 0, (jnp.arange(n), qs))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def decode_attention(q, k, v, *, lengths, window: Optional[int] = None,
                     key_positions=None, q_pos=None) -> jax.Array:
    """Single-token decode. q: (B,H,hd); k,v: (B,T,KV,hd); lengths: (B,)."""
    b, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qq = q.reshape(b, kvh, g, hd)
    logits = jnp.einsum("bkgd,btkd->bkgt", qq.astype(F32), k.astype(F32)) * (hd ** -0.5)
    if key_positions is None:
        key_positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    valid = (key_positions < lengths[:, None]) & (key_positions >= 0)
    if window is not None:
        if q_pos is None:
            q_pos = jnp.maximum(lengths - 1, 0)
        valid &= key_positions > (q_pos[:, None] - window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v)
    return out.reshape(b, h, hd)


# ---------------------------------------------------------------------------
# Vector runtime: slot advance + fused streaming quantiles
# ---------------------------------------------------------------------------
def vector_slot_advance(family: str, consts: dict, carry, xs):
    """One vector-runtime scan step on plain jnp ops.

    The oracle IS the runtime's step math (``_scalar_step`` /
    ``_batched_step`` instantiated with ``jnp``): the Pallas kernel body
    calls the same functions on its tiles, so in interpret mode the two
    paths execute identical op sequences — bit-equal, not just close.
    """
    from repro.vector.runtime import _batched_step, _scalar_step
    builder = _scalar_step if family == "scalar" else _batched_step
    return builder(jnp, consts)(carry, xs)


#: the fixed quantile tuple the vector runtime extracts
VECTOR_QS = (50.0, 95.0, 99.0)


def quantile_ranks(n, qs=VECTOR_QS):
    """np.percentile's floor/ceil order statistics for each quantile of
    a ``[C]`` batch of sample counts -> (pos, lo, hi), each ``[C, Q]``
    f32/int32.  Shared verbatim by the sort oracle and the radix-select
    kernel body so both interpolate between the SAME ranks."""
    nf = n.astype(F32)
    pos = jnp.stack([q / 100.0 * (nf - 1.0) for q in qs], axis=-1)
    lo = jnp.floor(pos)
    hi = jnp.ceil(pos)
    return pos, lo.astype(jnp.int32), hi.astype(jnp.int32)


def quantile_lerp(a, b, t):
    """numpy's percentile lerp: anchor on the nearer endpoint for
    t >= 0.5 (identical to ``quantiles_partition``'s flip)."""
    return jnp.where(t >= 0.5, b - (b - a) * (1.0 - t), a + (b - a) * t)


def fused_quantiles(lat, counts, qs=VECTOR_QS):
    """Sort-based oracle for the fused streaming-quantile kernel.

    ``lat``: ``[C, K]`` f32, row ``i`` holds ``counts[i]`` valid
    samples then ``+inf`` padding (order-preserving under the kernel's
    uint32 bitcast).  Returns ``[C, len(qs)]`` exact-order-statistic
    quantiles, NaN where ``counts == 0``.  Bit-equal to the Pallas
    radix-select kernel: both select true array elements at the same
    ranks and share ``quantile_ranks``/``quantile_lerp``.
    """
    x = jnp.sort(lat.astype(F32), axis=-1)
    pos, lo, hi = quantile_ranks(counts, qs)
    safe_lo = jnp.clip(lo, 0, x.shape[-1] - 1)
    safe_hi = jnp.clip(hi, 0, x.shape[-1] - 1)
    a = jnp.take_along_axis(x, safe_lo, axis=-1)
    b = jnp.take_along_axis(x, safe_hi, axis=-1)
    out = quantile_lerp(a, b, pos - lo.astype(F32))
    return jnp.where(counts[:, None] > 0, out, jnp.nan)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------
def ssd_naive(x, dt, A, B, C, h0=None):
    """Per-timestep recurrence oracle.

    x: (b,s,h,p); dt: (b,s,h); A: (h,); B,C: (b,s,g,n) with g==1.
    Returns y: (b,s,h,p) fp32 and final state (b,h,p,n) fp32.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    xf, dtf, Bf, Cf = x.astype(F32), dt.astype(F32), B.astype(F32), C.astype(F32)
    state = jnp.zeros((b, h, p, n), F32) if h0 is None else h0

    def step(state, args):
        xt, dtt, Bt, Ct = args                        # (b,h,p),(b,h),(b,n),(b,n)
        decay = jnp.exp(A * dtt)                      # (b,h)
        state = state * decay[..., None, None]
        state = state + jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, Bt)
        y = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, y

    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bf[:, :, 0].transpose(1, 0, 2), Cf[:, :, 0].transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state


def ssd_chunked(x, dt, A, B, C, *, chunk: int, h0=None):
    """Chunked SSD (state-space duality): the kernel's exact math.

    Scans over chunks (carrying the (b,h,p,n) state) so only ONE chunk's
    (b,L,L,h) decay tensor is live at a time — sharded over batch and heads
    this keeps the working set in tens of MB/chip even for jamba's h=128.
    The chunk body is rematerialized in the backward pass.
    """
    from repro.distributed.sharding import shard
    from repro.util import cost_mode

    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc, L = s // chunk, chunk
    if cost_mode():
        # cost lowering is never executed: the vectorized form compiles to a
        # handful of einsums (fast) and reports exact trip-counted FLOPs.
        return _ssd_vectorized(x, dt, A, B, C, chunk=chunk, h0=h0)
    # (nc, b, L, ...) leading chunk axis for the scan.  Inputs keep their
    # storage dtype (bf16): the scan xs are saved for backward, so an
    # upfront f32 cast would double the dominant temp buffer.
    xf = x.reshape(b, nc, L, h, p).transpose(1, 0, 2, 3, 4)
    dtf = dt.astype(F32).reshape(b, nc, L, h).transpose(1, 0, 2, 3)
    Bf = B[:, :, 0].reshape(b, nc, L, n).transpose(1, 0, 2, 3)
    Cf = C[:, :, 0].reshape(b, nc, L, n).transpose(1, 0, 2, 3)
    causal = jnp.tril(jnp.ones((L, L), bool))

    @jax.checkpoint
    def body(hprev, args):
        xc, dtc, Bc, Cc = args              # (b,L,h,p),(b,L,h),(b,L,n),(b,L,n)
        xc, Bc, Cc = xc.astype(F32), Bc.astype(F32), Cc.astype(F32)
        a = A * dtc
        cum = jnp.cumsum(a, axis=1)                              # (b,L,h)
        # intra: M[t,s] = (C_t.B_s) exp(cum_t - cum_s) dt_s,  t >= s
        seg = cum[:, :, None, :] - cum[:, None, :, :]            # (b,t,s,h)
        # mask BEFORE exp: masked entries can overflow to inf, and
        # where(mask, inf, 0) still produces NaN gradients.
        decay = jnp.exp(jnp.where(causal[None, :, :, None], seg, -1e30))
        decay = shard(decay, "batch", None, None, "mamba_heads")
        cb = jnp.einsum("btn,bsn->bts", Cc, Bc)
        M = cb[..., None] * decay * dtc[:, None, :, :]           # (b,t,s,h)
        y = jnp.einsum("btsh,bshp->bthp", M, xc)
        # inter: y[t] += exp(cum_t) * C_t . h_prev
        y = y + jnp.einsum("blh,bln,bhpn->blhp", jnp.exp(cum), Cc, hprev)
        # state: h = exp(cum_L) h_prev + sum_s exp(cum_L - cum_s) dt_s B_s x_s
        w = jnp.exp(cum[:, -1:, :] - cum) * dtc                  # (b,L,h)
        upd = jnp.einsum("blh,bln,blhp->bhpn", w, Bc, xc)
        hnew = hprev * jnp.exp(cum[:, -1, :])[:, :, None, None] + upd
        from repro.util import opt_flags
        if "ssd_shard_state" in opt_flags():
            # perf opt: the (b,h,p,n) inter-chunk state is the scan carry the
            # backward saves per chunk (jamba: 2.1 GB/chip x 16 boundaries x
            # 7 layers unsharded) -> shard it over "model" via heads.
            hnew = shard(hnew, "batch", "mamba_heads", None, None)
        return hnew, y

    init = jnp.zeros((b, h, p, n), F32) if h0 is None else h0.astype(F32)
    hN, ys = jax.lax.scan(body, init, (xf, dtf, Bf, Cf))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, hN


def _ssd_vectorized(x, dt, A, B, C, *, chunk: int, h0=None):
    """All chunks at once (memory-heavy, compile-light): cost-mode path."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    nc, L = s // chunk, chunk
    xf = x.astype(F32).reshape(b, nc, L, h, p)
    dtf = dt.astype(F32).reshape(b, nc, L, h)
    Bf = B.astype(F32)[:, :, 0].reshape(b, nc, L, n)
    Cf = C.astype(F32)[:, :, 0].reshape(b, nc, L, n)
    a = A * dtf
    cum = jnp.cumsum(a, axis=2)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.exp(jnp.where(causal[None, None, :, :, None], seg, -1e30))
    cb = jnp.einsum("bctn,bcsn->bcts", Cf, Bf)
    M = cb[..., None] * decay * dtf[:, :, None, :, :]
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", M, xf)
    dec_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
    Sc = jnp.einsum("bclh,bcln,bclhp->bchpn", dec_to_end * dtf, Bf, xf)
    chunk_decay = jnp.exp(cum[:, :, -1, :])

    def step(hprev, args):
        dcy, sc = args
        return hprev * dcy[..., None, None] + sc, hprev

    init = jnp.zeros((b, h, p, n), F32) if h0 is None else h0.astype(F32)
    hN, hprevs = jax.lax.scan(step, init, (chunk_decay.transpose(1, 0, 2),
                                           Sc.transpose(1, 0, 2, 3, 4)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)
    y_inter = jnp.einsum("bclh,bcln,bchpn->bclhp", jnp.exp(cum), Cf, hprevs)
    return (y_intra + y_inter).reshape(b, s, h, p), hN
