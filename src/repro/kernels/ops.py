"""Public kernel ops with impl dispatch.

impl = "auto"   -> Pallas on TPU, jnp reference elsewhere (CPU container)
       "pallas" -> pl.pallas_call (interpret mode off-TPU: kernel-body tests)
       "ref"    -> pure-jnp reference (also the dry-run lowering path)

``REPRO_FORCE_IMPL`` env var overrides "auto" globally.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.kernels import ref as _ref


def _resolve(impl: str) -> str:
    if impl == "auto":
        impl = os.environ.get("REPRO_FORCE_IMPL", "auto")
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


#: public alias — the vector runtime resolves its impl knob up front so
#: the choice can enter its jit-cache key
resolve_impl = _resolve


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    impl: str = "auto", chunk: int = 512):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd) -> (B,S,H,hd)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.chunked_attention(q, k, v, causal=causal, window=window, chunk=chunk)
    from repro.kernels import flash_attention as fa
    return fa.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=_interpret())


def decode_attention(q, k, v, *, lengths, key_positions=None, q_pos=None,
                     window: Optional[int] = None, impl: str = "auto"):
    """q: (B,H,hd); k,v: (B,T,KV,hd); lengths: (B,) -> (B,H,hd)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.decode_attention(q, k, v, lengths=lengths,
                                     key_positions=key_positions, q_pos=q_pos,
                                     window=window)
    from repro.kernels import decode_attention as da
    return da.decode_attention(q, k, v, lengths=lengths,
                               key_positions=key_positions, q_pos=q_pos,
                               window=window, interpret=_interpret())


def vector_slot_advance(family: str, consts: dict, carry, xs, *,
                        impl: str = "auto"):
    """One vector-runtime scan step ("scalar" or "batched" family).

    Called from inside the runtime's ``lax.scan`` body; resolution is
    trace-time static.  The ref path and the interpret-mode Pallas path
    execute the same step math (see ``vector_step``) — bit-equal.

    Soft-mode consts carry a ``"tau"`` temperature: the Pallas kernels
    implement only the hard step math, so those always take the jnp
    reference path (structural, trace-time-static routing).
    """
    impl = _resolve(impl)
    if "tau" in consts:
        impl = "ref"
    if impl == "ref":
        return _ref.vector_slot_advance(family, consts, carry, xs)
    from repro.kernels import vector_step as vs
    fn = (vs.scalar_slot_advance if family == "scalar"
          else vs.batched_slot_advance)
    return fn(consts, carry, xs, interpret=_interpret())


def vector_quantiles(lat, counts, *, impl: str = "auto"):
    """Fused p50/p95/p99 for every grid cell in one launch.

    ``lat``: [C, K] f32 rows padded with +inf past ``counts[i]``;
    ``counts``: [C] int32 -> [C, 3] (NaN rows where the count is 0).
    The Pallas radix-select kernel and the sort oracle both select
    exact order statistics: their outputs are bit-equal.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.fused_quantiles(lat, counts)
    from repro.kernels import vector_quantiles as vq
    return vq.fused_quantiles(lat, counts, interpret=_interpret())


def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, h0=None, impl: str = "auto"):
    """Mamba-2 SSD. x: (b,s,h,p); dt: (b,s,h); A: (h,); B,C: (b,s,1,n)."""
    impl = _resolve(impl)
    s = x.shape[1]
    pad = (-s) % chunk
    if pad:  # dt=0 padding is state-neutral (decay 1, zero update)
        import jax.numpy as jnp
        padt = lambda a: jnp.pad(a, [(0, pad if i == 1 else 0) for i in range(a.ndim)])
        x, dt, B, C = padt(x), padt(dt), padt(B), padt(C)
    if impl == "ref":
        y, h = _ref.ssd_chunked(x, dt, A, B, C, chunk=chunk, h0=h0)
    else:
        from repro.kernels import ssd_scan as sk
        y, h = sk.ssd_scan(x, dt, A, B, C, chunk=chunk, h0=h0, interpret=_interpret())
    return (y[:, :s] if pad else y), h
