"""Fused streaming-quantile kernel for the vector runtime.

One ``pl.pallas_call`` produces p50/p95/p99 for EVERY grid cell in a
single launch, replacing the per-cell ``quantiles_partition`` loop of
the extraction path.  Rows are ``[cell, sample]`` f32 tiles padded
with ``+inf`` past each cell's count.

Sorting networks are awkward on TPU tiles; instead the kernel runs an
exact **radix select**: non-negative f32 latencies bitcast to uint32
order-preservingly (``+inf`` padding sorts last), and 32 bit-sliced
rounds recover the floor/ceil order statistics of every quantile by
counting values below each candidate prefix.  The selected values are
true array elements — bit-equal to the ``jnp.sort`` oracle
(``ref.fused_quantiles``), which the kernel shares its rank and lerp
math with (``ref.quantile_ranks`` / ``ref.quantile_lerp``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import VECTOR_QS, quantile_lerp, quantile_ranks

#: cells per kernel instance (f32 sublane tile)
CELL_TILE = 8
#: sample-axis padding multiple (f32 lane tile)
LANE = 128


def _quantile_kernel(lat_ref, cnt_ref, out_ref):
    x = lat_ref[...]                              # [CT, K] f32
    n = cnt_ref[...][:, 0]                        # [CT] int32
    pos, lo, hi = quantile_ranks(n, VECTOR_QS)    # [CT, Q]
    ranks = jnp.concatenate([lo, hi], axis=-1)    # [CT, 2Q]
    u = jax.lax.bitcast_convert_type(x, jnp.uint32)

    def bit_round(b, prefix):
        bit = jax.lax.shift_right_logical(jnp.uint32(0x80000000),
                                          b.astype(jnp.uint32))
        cand = prefix | bit
        below = (u[:, None, :] < cand[:, :, None])
        n_below = jnp.sum(below.astype(jnp.int32), axis=-1)   # [CT, 2Q]
        # fewer than rank+1 values below the candidate -> the rank-th
        # order statistic is >= cand -> the bit survives
        return jnp.where(n_below <= ranks, cand, prefix)

    prefix = jax.lax.fori_loop(0, 32, bit_round,
                               jnp.zeros(ranks.shape, jnp.uint32))
    sel = jax.lax.bitcast_convert_type(prefix, jnp.float32)
    q = len(VECTOR_QS)
    a, b = sel[:, :q], sel[:, q:]
    out = quantile_lerp(a, b, pos - lo.astype(jnp.float32))
    out_ref[...] = jnp.where(n[:, None] > 0, out, jnp.nan)


def fused_quantiles(lat, counts, *, interpret: bool = False,
                    cell_tile: int = CELL_TILE):
    """``lat``: [C, K] f32 (+inf padded past ``counts``); ``counts``:
    [C] int32 -> [C, 3] p50/p95/p99 (NaN rows where count is 0)."""
    C, K = lat.shape
    q = len(VECTOR_QS)
    c_pad = -(-C // cell_tile) * cell_tile
    k_pad = -(-max(K, 1) // LANE) * LANE
    lat = jnp.pad(lat.astype(jnp.float32),
                  ((0, c_pad - C), (0, k_pad - K)),
                  constant_values=jnp.inf)
    cnt = jnp.pad(counts.astype(jnp.int32), (0, c_pad - C))[:, None]
    out = pl.pallas_call(
        _quantile_kernel,
        grid=(c_pad // cell_tile,),
        in_specs=[pl.BlockSpec((cell_tile, k_pad), lambda i: (i, 0)),
                  pl.BlockSpec((cell_tile, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((cell_tile, q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c_pad, q), jnp.float32),
        interpret=interpret,
    )(lat, cnt)
    return out[:C]
