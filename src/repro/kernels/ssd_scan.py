"""Mamba-2 SSD chunked-scan kernel (Pallas, TPU).

TPU adaptation of the SSD algorithm: instead of a GPU warp-level selective
scan, each chunk is a dense (L×L) decay-masked attention-like product that
runs on the MXU; the (P×N) recurrent state is carried across the innermost
(sequential) grid axis in VMEM scratch.  Grid (B, H, nChunks).

VMEM per step (L=256, P=128, N=128, fp32): x 128KB + B/C 2×128KB + M 256KB +
state 64KB ≈ 0.8 MB — comfortably resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hN_ref,
            state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0, :, :].astype(F32)

    x = x_ref[0, :, 0, :].astype(F32)                     # (L, P)
    dt = dt_ref[0, :, 0].astype(F32)                      # (L,)
    A = a_ref[0, 0]                                       # scalar (this head)
    Bm = b_ref[0, :, :].astype(F32)                       # (L, N)
    Cm = c_ref[0, :, :].astype(F32)                       # (L, N)

    a = A * dt                                            # (L,) log-decay
    cum = jnp.cumsum(a)                                   # (L,)
    # intra-chunk quadratic term: M[t,s] = (C_t.B_s) exp(cum_t - cum_s) dt_s, t>=s
    seg = cum[:, None] - cum[None, :]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(t_idx >= s_idx, seg, -1e30))  # mask pre-exp
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=F32)  # (L, L)
    M = cb * decay * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=F32)   # (L, P)
    # inter-chunk: y += exp(cum_t) * C_t . h_prev^T      (h_prev: (P, N))
    h_prev = state_ref[...]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h_prev, (((1,), (1,)), ((), ())), preferred_element_type=F32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    # state update: h = exp(cum_L) h_prev + sum_s exp(cum_L-cum_s) dt_s x_s ⊗ B_s
    w = jnp.exp(cum[-1] - cum) * dt                       # (L,)
    upd = jax.lax.dot_general(x, Bm * w[:, None], (((0,), (0,)), ((), ())),
                              preferred_element_type=F32)  # (P, N)
    state_ref[...] = jnp.exp(cum[-1]) * h_prev + upd

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hN_ref[0, 0, :, :] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, h0=None,
             interpret: bool = False):
    """x: (b,s,h,p); dt: (b,s,h); A: (h,); B,C: (b,s,1,n) -> (y fp32, hN fp32)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), F32)
    Bs, Cs = B[:, :, 0, :], C[:, :, 0, :]                 # (b,s,n)
    a2 = A.reshape(h, 1).astype(F32)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    y, hN = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, c_: (b_, c_, h_)),
            pl.BlockSpec((1, 1), lambda b_, h_, c_: (h_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), F32),
            jax.ShapeDtypeStruct((b, h, p, n), F32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), F32)],
        interpret=interpret,
    )(x, dt, a2, Bs, Cs, h0)
    return y, hN
