"""Flash-decode kernel: one query token vs a long KV cache (Pallas, TPU).

Grid (B, KV, nT) — KV-sequence blocks innermost; online-softmax state in
VMEM scratch.  The GQA q-head group (G = H/KV rows) rides the MXU M
dimension.  Per-sequence cache lengths, per-slot absolute key positions
(ring buffers for SWA layers), and the query position arrive as scalar /
position inputs so ragged batches mask correctly.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _kernel(meta_ref, q_ref, k_ref, v_ref, kp_ref, o_ref, m_ref, l_ref,
            acc_ref, *, block_t: int, n_t: int, window: Optional[int],
            scale: float):
    b, ti = pl.program_id(0), pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :, :].astype(F32) * scale              # (G, hd)
    k = k_ref[0, :, 0, :].astype(F32)                      # (BT, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32)    # (G, BT)
    kp = kp_ref[0, :]                                      # (BT,) abs positions
    length = meta_ref[b, 0]
    ok = (kp < length) & (kp >= 0)
    if window is not None:
        q_pos = meta_ref[b, 1]
        ok &= kp > q_pos - window
    s = jnp.where(ok[None, :], s, NEG)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(ok[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    v = v_ref[0, :, 0, :].astype(F32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)
    m_ref[...] = m_new

    @pl.when(ti == n_t - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_t", "interpret"))
def decode_attention(q, k, v, *, lengths, key_positions=None, q_pos=None,
                     window: Optional[int] = None, block_t: int = 512,
                     interpret: bool = False):
    """q: (B,H,hd); k,v: (B,T,KV,hd); lengths: (B,) -> (B,H,hd)."""
    b, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    block_t = min(block_t, t)
    assert t % block_t == 0, (t, block_t)
    n_t = t // block_t
    if key_positions is None:
        key_positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    if q_pos is None:
        q_pos = jnp.maximum(lengths - 1, 0)
    meta = jnp.stack([lengths.astype(jnp.int32), q_pos.astype(jnp.int32)], axis=1)
    qg = q.reshape(b, kv, g, hd)

    kernel = functools.partial(_kernel, block_t=block_t, n_t=n_t,
                               window=window, scale=hd ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kv, n_t),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, k_, ti, meta: (b_, k_, 0, 0)),
            pl.BlockSpec((1, block_t, 1, hd), lambda b_, k_, ti, meta: (b_, ti, k_, 0)),
            pl.BlockSpec((1, block_t, 1, hd), lambda b_, k_, ti, meta: (b_, ti, k_, 0)),
            pl.BlockSpec((1, block_t), lambda b_, k_, ti, meta: (b_, ti)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b_, k_, ti, meta: (b_, k_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), F32),
            pltpu.VMEM((g,), F32),
            pltpu.VMEM((g, hd), F32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(meta, qg, k, v, key_positions.astype(jnp.int32))
    return out.reshape(b, h, hd)
