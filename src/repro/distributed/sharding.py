"""Logical-axis sharding rules (MaxText-style) -> NamedShardings.

Logical names are assigned greedily onto mesh axes with divisibility checks:
a rule maps a logical axis to a tuple of mesh axes; axes already consumed by
an earlier dim of the same tensor are skipped, and a prefix whose product
divides the dim size is used (else the dim stays replicated).  This resolves
e.g. GQA kv_heads=8 on a 16-way "model" axis (-> replicated / seq-sharded
instead) and batch=1 long-context decode (-> KV-sequence takes data+model).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rules.  Params and activations use distinct vocabularies so that "embed"
# (FSDP-sharded on params) never collides with activation batch sharding.
# ---------------------------------------------------------------------------
PARAM_RULES: dict[str, tuple] = {
    "layer": (),
    "vocab": ("model",),
    "embed": ("data",),          # FSDP / ZeRO-3: gathered just-in-time
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "expert": ("model",),
    "expert_embed": ("data",),   # expert-weight FSDP dim
    "expert_mlp": ("model",),    # per-expert d_ff TP (mixtral-style)
    "conv": (),
    "mamba_inner": ("model",),
    "mamba_heads": ("model",),
    "mamba_state": (),
}

ACT_RULES: dict[str, tuple] = {
    "batch": ("pod", "data"),
    "seq": (),
    "res_seq": (),                 # inter-block residual (SP shards this)
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "expert_mlp": ("model",),
    "kv_seq": ("data", "model"),   # decode KV-sequence sharding (flash-decode)
    "mamba_heads": ("model",),
    "mamba_inner": ("model",),
    "mamba_state": (),
    "layer": (),
}


def strategy_rules(strategy: str) -> tuple[dict, dict]:
    """-> (param_rules, act_rules) for a sharding strategy.

    "tp": megatron tensor parallel — heads/mlp/experts on "model";
          residual replicated across model.  Right for decode/prefill
          (small per-chip batch, KV-sequence sharded).
    "sp": fully-sharded sequence parallel — the residual stream's seq dim
          on "model", params ZeRO-3 over (data, model), attention runs
          q-local vs all-gathered KV.  Right for training (activations
          dominate: 64k tokens/chip at train_4k).
    """
    if strategy == "tp":
        return dict(PARAM_RULES), dict(ACT_RULES)
    if strategy == "tp_infer":
        # serving layout: weights REPLICATED across "data" (no per-step
        # weight all-gathers), sharded only on "model"; batch rides "data".
        # Expert banks keep their (data x model) sharding — GSPMD resolves
        # the contraction with activation all-reduces instead of gathers.
        param = dict(PARAM_RULES, embed=())
        return param, dict(ACT_RULES)
    assert strategy == "sp", strategy
    param = dict(PARAM_RULES, embed=("data", "model"), heads=(), kv_heads=(),
                 mlp=(), vocab=("model",), mamba_inner=())
    act = dict(ACT_RULES, res_seq=("model",), heads=(), kv_heads=(), mlp=(),
               mamba_inner=())
    return param, act


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]], rules: dict,
             mesh: Mesh) -> P:
    used: set[str] = set()
    out = []
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, name in zip(shape, axes):
        assigned: tuple = ()
        if name is not None:
            cand = tuple(a for a in rules.get(name, ()) if a in sizes and a not in used)
            # take the longest prefix whose product divides the dim
            while cand:
                prod = int(np.prod([sizes[a] for a in cand]))
                if prod > 1 and dim % prod == 0:
                    assigned = cand
                    break
                cand = cand[:-1]
        used.update(assigned)
        out.append(assigned if assigned else None)
    # PartitionSpec wants single names or tuples
    return P(*[a[0] if (a and len(a) == 1) else a for a in out])


def named_sharding(shape, axes, mesh: Mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, axes, rules or PARAM_RULES, mesh))


def tree_shardings(axes, abstract, mesh: Mesh, rules=None):
    """Zip Axes tree with ShapeDtypeStruct tree -> NamedSharding tree."""
    from repro.models.param import Axes

    rules = rules or PARAM_RULES
    return jax.tree_util.tree_map(
        lambda ax, a: named_sharding(a.shape, tuple(ax), mesh, rules),
        axes, abstract,
        is_leaf=lambda x: isinstance(x, Axes),
    )


# ---------------------------------------------------------------------------
# Activation constraints inside model code: shard(x, "batch", "seq", "embed").
# No-op when no mesh context is active (single-device smoke tests).
# ---------------------------------------------------------------------------
_CTX = threading.local()


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = getattr(_CTX, "mesh", None), getattr(_CTX, "rules", None)
    _CTX.mesh, _CTX.rules = mesh, dict(ACT_RULES, **(rules or {}))
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return getattr(_CTX, "mesh", None)


def shard(x, *axes):
    mesh = getattr(_CTX, "mesh", None)
    if mesh is None:
        return x
    rules = getattr(_CTX, "rules", ACT_RULES)
    spec = spec_for(x.shape, axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
