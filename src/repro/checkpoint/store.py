"""Fault-tolerant checkpointing: atomic, async, resumable.

Layout:   <dir>/step_<N>/arrays.npz + manifest.json     (tmp dir + rename)
Restore picks the highest complete step; partially written checkpoints
(no manifest) are ignored — a crash mid-write can never corrupt restore.
``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and
writes on a background thread so the train loop keeps stepping.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_BF16 = np.dtype(ml_dtypes.bfloat16)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out


def save(tree, directory: str, step: int, extra: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    dtypes = {}
    stored = {}
    for k, v in arrays.items():
        if v.dtype == _BF16:        # npz has no bf16: store the raw bits
            dtypes[k] = "bfloat16"
            v = v.view(np.uint16)
        stored[k] = v
    np.savez(os.path.join(tmp, "arrays.npz"), **stored)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "extra": extra or {},
                   "keys": sorted(arrays), "dtypes": dtypes}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    s = steps(directory)
    return s[-1] if s else None


def restore(tree_like, directory: str, step: Optional[int] = None):
    """Restore into the structure of ``tree_like`` -> (tree, step, extra)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    dtypes = manifest.get("dtypes", {})
    flat = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    treedef = jax.tree_util.tree_structure(tree_like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        arr = data[key]
        if dtypes.get(key) == "bfloat16":
            arr = arr.view(_BF16)
        want = np.dtype(leaf.dtype)
        leaves.append(jax.numpy.asarray(arr).astype(want))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"], manifest["extra"]


class AsyncCheckpointer:
    """Background writer; ``wait()`` before exit or next save."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, tree, step: int, extra: Optional[dict] = None):
        self.wait()
        host = jax.tree_util.tree_map(np.asarray, jax.device_get(tree))

        def _write():
            try:
                save(host, self.directory, step, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self):
        all_steps = steps(self.directory)
        for s in all_steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
