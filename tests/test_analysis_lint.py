"""Golden-file tests for the determinism linter.

Every fixture in ``tests/analysis_fixtures/`` carries its own
expectations inline: a line containing ``# F: <rule>`` must produce
exactly one active finding of that rule on that line, and a fixture
with no markers must produce none.  ``# lint-path:`` directives place
fixtures inside the scoped packages without living there.
"""
from __future__ import annotations

import re
from pathlib import Path

import pytest

from repro.analysis.lint.engine import (
    SourceFile,
    default_rules,
    exit_code,
    lint_paths,
    lint_text,
    render_json,
    summarize,
)

FIXTURES = Path(__file__).parent / "analysis_fixtures"
_MARK = re.compile(r"#\s*F:\s*([a-z0-9-]+)")


def _fixture_files():
    return sorted(FIXTURES.rglob("*.py"))


def _expected(path: Path) -> set:
    out = set()
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        for m in _MARK.finditer(line):
            out.add((m.group(1), lineno))
    return out


@pytest.mark.parametrize("path", _fixture_files(),
                         ids=lambda p: str(p.relative_to(FIXTURES)))
def test_fixture_matches_markers(path):
    findings = lint_paths([str(path)])
    active = {(f.rule, f.line) for f in findings if not f.suppressed}
    assert active == _expected(path)


def test_every_rule_has_flag_and_near_miss_fixtures():
    for rule in default_rules():
        flag = FIXTURES / f"{rule.name}_flag.py"
        ok = FIXTURES / f"{rule.name}_ok.py"
        assert flag.exists(), f"missing flagging fixture for {rule.name}"
        assert ok.exists(), f"missing near-miss fixture for {rule.name}"
        assert any(r == rule.name for r, _ in _expected(flag)), \
            f"{flag.name} never expects {rule.name}"
        assert not any(r == rule.name for r, _ in _expected(ok))


def test_regression_corpus_catches_historical_bugs():
    pr4 = lint_paths([str(FIXTURES / "regression" / "pr4_hash_seed.py")])
    assert any(f.rule == "seed-from-hash" and not f.suppressed
               for f in pr4)
    pr1 = lint_paths([str(FIXTURES / "regression" /
                          "pr1_unseeded_rep_rng.py")])
    rules = {f.rule for f in pr1 if not f.suppressed}
    assert "unseeded-rng" in rules
    assert "seed-convention" in rules


# ---------------------------------------------------------------------------
# Engine mechanics
# ---------------------------------------------------------------------------
SNIPPET = """\
import numpy as np


def build():
    return np.random.default_rng()
"""


def test_suppression_by_rule_name():
    text = SNIPPET.replace(
        "np.random.default_rng()",
        "np.random.default_rng()  # repro: noqa[unseeded-rng]")
    findings = lint_text(text, rel="core/x.py")
    assert [f.rule for f in findings] == ["unseeded-rng"]
    assert findings[0].suppressed


def test_blanket_suppression_and_wrong_name():
    blanket = SNIPPET.replace("default_rng()",
                              "default_rng()  # repro: noqa")
    assert all(f.suppressed for f in lint_text(blanket, rel="core/x.py"))
    wrong = SNIPPET.replace(
        "default_rng()", "default_rng()  # repro: noqa[broad-except]")
    findings = lint_text(wrong, rel="core/x.py")
    assert findings and not findings[0].suppressed


def test_scope_gating_via_rel_path():
    assert lint_text(SNIPPET, rel="core/x.py")
    assert not lint_text(SNIPPET, rel="figures/x.py")


def test_lint_path_directive_overrides_rel():
    text = "# lint-path: core/x.py\n" + SNIPPET
    sf = SourceFile("/tmp/anywhere/thing.py", text)
    assert sf.rel == "core/x.py"


def test_exit_code_and_strict():
    errors = lint_text(SNIPPET, rel="core/x.py")
    assert exit_code(errors) == 1
    warn_only = lint_text(
        "import numpy as np\nrng = np.random.default_rng(7)\n",
        rel="core/x.py")
    assert {f.severity for f in warn_only} == {"warning"}
    assert exit_code(warn_only) == 0
    assert exit_code(warn_only, strict=True) == 1
    suppressed = lint_text(
        SNIPPET.replace("default_rng()",
                        "default_rng()  # repro: noqa"),
        rel="core/x.py")
    assert exit_code(suppressed, strict=True) == 0
    assert summarize(suppressed)["suppressed"] == 1


def test_json_output_round_trips():
    import json
    findings = lint_text(SNIPPET, rel="core/x.py")
    doc = json.loads(render_json(findings))
    assert doc["summary"]["errors"] == 1
    assert doc["findings"][0]["rule"] == "unseeded-rng"


def test_repo_source_is_clean_under_strict():
    src = Path(__file__).parent.parent / "src" / "repro"
    findings = lint_paths([str(src)])
    active = [f for f in findings if not f.suppressed]
    assert active == [], "\n".join(f.format() for f in active)
    assert exit_code(findings, strict=True) == 0
    # the sanctioned suppressions stay visible as audit trail
    assert summarize(findings)["suppressed"] >= 6


def test_cli_lint_smoke(capsys, tmp_path):
    from repro.analysis.__main__ import main
    bad = tmp_path / "bad.py"
    bad.write_text("# lint-path: core/bad.py\n"
                   "import random\n")
    assert main(["lint", str(bad)]) == 1
    assert "stdlib-random" in capsys.readouterr().out
    assert main(["lint", "--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in default_rules():
        assert rule.name in out
