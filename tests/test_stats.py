"""Unit tests: streaming estimators + degenerate-input statistics fixes."""
import math

import numpy as np
import pytest

from repro.core.client import (ClientConfig, ClientGenerator, ConstantQPS,
                               PiecewiseQPS, TraceQPS)
from repro.core.profiles import FixedProfile
from repro.core.stats import (LatencyRecorder, MetricsPipeline, P2Quantile,
                              ReservoirSample, StreamingStat, Summary,
                              confidence95, welch_ttest)


# ---------------------------------------------------------------------------
# P² / reservoir estimators
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q", [0.5, 0.95, 0.99])
def test_p2_matches_numpy_on_lognormal(q):
    rng = np.random.default_rng(1)
    xs = rng.lognormal(mean=0.0, sigma=0.8, size=50_000)
    est = P2Quantile(q)
    for x in xs:
        est.add(float(x))
    exact = float(np.percentile(xs, q * 100))
    assert est.value() == pytest.approx(exact, rel=0.05)


def test_p2_small_n_exact():
    est = P2Quantile(0.5)
    for x in (3.0, 1.0, 2.0):
        est.add(x)
    assert est.value() == pytest.approx(2.0)
    assert math.isnan(P2Quantile(0.5).value())


def test_reservoir_exact_below_k_and_bounded_above():
    r = ReservoirSample(k=10, seed=0)
    for x in range(5):
        r.add(float(x))
    assert sorted(r.data) == [0.0, 1.0, 2.0, 3.0, 4.0]
    for x in range(5, 1000):
        r.add(float(x))
    assert len(r.data) == 10 and r.n == 1000
    assert all(0.0 <= x < 1000.0 for x in r.data)


def test_streaming_stat_summary():
    rng = np.random.default_rng(2)
    xs = rng.exponential(size=20_000)
    st = StreamingStat(reservoir_k=256, use_p2=True)
    for x in xs:
        st.add(float(x))
    s = st.summary()
    assert s.n == 20_000
    assert s.mean == pytest.approx(float(xs.mean()))
    assert s.p99 == pytest.approx(float(np.percentile(xs, 99)), rel=0.1)


def test_streaming_recorder_tracks_exact():
    class _R:
        def __init__(self, cid, created, completed):
            self.client_id = cid
            self.created = created
            self.enqueued = created
            self.started = created
            self.completed = completed

    rng = np.random.default_rng(3)
    exact = LatencyRecorder(1.0, mode="exact")
    stream = LatencyRecorder(1.0, mode="streaming")
    for i in range(30_000):
        t0 = rng.uniform(0, 30)
        req = _R(i % 3, t0, t0 + rng.lognormal(-6, 0.5))
        exact.record(req)
        stream.record(req)
    se, ss = exact.overall(), stream.overall()
    assert ss.n == se.n
    assert ss.mean == pytest.approx(se.mean)
    assert ss.p99 == pytest.approx(se.p99, rel=0.1)
    assert stream.clients() == exact.clients()
    assert set(stream.intervals()) == set(exact.intervals())
    # per-interval counts are exact in streaming mode too
    for ivl, s in exact.intervals().items():
        assert stream.intervals()[ivl].n == s.n


def test_recorder_rejects_unknown_mode():
    with pytest.raises(ValueError):
        LatencyRecorder(1.0, mode="approximate")


# ---------------------------------------------------------------------------
# Degenerate-input fixes
# ---------------------------------------------------------------------------
def test_welch_degenerate_inputs():
    w = welch_ttest([1.0], [1.0, 2.0, 3.0])      # n<2 on one side
    assert math.isnan(w.t_stat) and math.isnan(w.p_value)
    assert not w.significant
    w = welch_ttest([], [])
    assert math.isnan(w.t_stat)
    # both zero-variance, equal means: no evidence of a difference
    w = welch_ttest([2.0, 2.0, 2.0], [2.0, 2.0])
    assert w.t_stat == 0.0 and w.p_value == 1.0
    # both zero-variance, different means: maximal evidence
    w = welch_ttest([2.0, 2.0], [3.0, 3.0])
    assert math.isinf(w.t_stat) and w.p_value == 0.0


def test_welch_regular_path_unchanged():
    a = [2.1, 2.0, 1.9, 2.2, 2.05]
    c = [5.1, 5.3, 4.9, 5.2, 5.0]
    w = welch_ttest(a, c)
    assert w.p_value < 0.001 and w.significant


def test_confidence95_degenerate():
    m, h = confidence95([])
    assert math.isnan(m) and math.isnan(h)
    m, h = confidence95([4.2])
    assert m == 4.2 and math.isnan(h)    # one rep: CI undefined, not zero
    m, h = confidence95([1.0, 2.0, 3.0])
    assert m == pytest.approx(2.0) and h > 0.0


def test_trace_qps_empty_and_bounds():
    assert math.isnan(TraceQPS([]).rate(0.0))
    t = TraceQPS([10, 20, 30], dt=1.0)
    assert t.rate(0.5) == 10 and t.rate(1.5) == 20 and t.rate(99) == 30


def test_piecewise_bisect_lookup():
    p = PiecewiseQPS([(0, 100), (10, 300), (20, 500)])
    assert p.rate(-1.0) == 0.0
    assert p.rate(0.0) == 100 and p.rate(9.999) == 100
    assert p.rate(10.0) == 300 and p.rate(25.0) == 500
    # unsorted input is normalized instead of producing order-dependent junk
    p2 = PiecewiseQPS([(10, 300), (0, 100)])
    assert p2.rate(5.0) == 100 and p2.rate(15.0) == 300


def test_empty_trace_exhausts_generator_instead_of_nan_arrival():
    """Regression: a NaN rate (empty TraceQPS) slipped past the `rate <= 0`
    guard and produced a NaN arrival timestamp."""
    cfg = ClientConfig(0, TraceQPS([]), end_time=5.0)
    gen = ClientGenerator(cfg, FixedProfile("x", 1e-3))
    assert gen.next_arrival() is None
    assert gen.sent == 0


def test_streaming_recorder_hides_raw_sample_api():
    """Streaming mode must not expose permanently-empty exact-mode lists."""
    rec = LatencyRecorder(1.0, mode="streaming")
    with pytest.raises(AttributeError):
        _ = rec.all
    with pytest.raises(AttributeError):
        _ = rec.queue_times
    assert LatencyRecorder(1.0, mode="exact").all == []


def test_exhausted_explicit_time_zero():
    """t=0.0 is a real timestamp — the old `(t or self.t)` treated it as
    unset and read the generator clock instead."""
    cfg = ClientConfig(0, ConstantQPS(10), end_time=5.0)
    gen = ClientGenerator(cfg, FixedProfile("x", 1e-3))
    gen.t = 10.0                      # generator clock past the end
    assert gen.exhausted(0.0) is False
    assert gen.exhausted(10.0) is True
    assert gen.exhausted() is True    # no argument -> generator clock


# ---------------------------------------------------------------------------
# MetricsPipeline (telemetry layer)
# ---------------------------------------------------------------------------
def _fake_req(rid, cid, created, completed, started=None):
    from repro.core.request import Request
    r = Request(rid, cid, created, 0.0)
    r.enqueued = created
    r.started = created if started is None else started
    r.completed = completed
    return r


def test_pipeline_delegates_bit_identically():
    rec = LatencyRecorder(1.0)
    pipe = MetricsPipeline(rec, 1.0)
    rng = np.random.default_rng(0)
    for i in range(500):
        t = float(rng.uniform(0, 5))
        rec.record(_fake_req(i, i % 3, t, t + float(rng.exponential(0.01))))
    assert pipe.overall() == rec.overall()
    assert pipe.client(1) == rec.client(1)
    assert pipe.series() == rec.intervals()
    assert pipe.series(2) == rec.intervals(2)
    assert pipe.window("p99", 1, 4) == \
        [s.p99 for t, s in rec.intervals().items() if 1 <= t < 4]


def test_pipeline_frames_qps_and_slo():
    rec = LatencyRecorder(1.0)
    pipe = MetricsPipeline(rec, 1.0, slo=0.1)
    # interval 0: 4 fast; interval 1: 2 fast + 2 slow
    for i, (t, lat) in enumerate([(0.1, 0.01), (0.2, 0.01), (0.3, 0.01),
                                  (0.4, 0.01), (1.1, 0.01), (1.2, 0.01),
                                  (1.3, 0.5), (1.4, 0.5)]):
        rec.record(_fake_req(i, 0, t, t + lat))
    frames = {f.t: f for f in pipe.frames()}
    assert frames[0].n == 4 and frames[0].qps == 4.0
    assert frames[0].slo_violation_frac == 0.0
    assert frames[1].slo_violation_frac == pytest.approx(0.5)


def test_pipeline_gauges_join_frames():
    class _Srv:
        def __init__(self, sid, busy, queued, workers):
            self.server_id, self.busy, self.workers = sid, busy, workers
            self._q = queued

        def load(self):
            return self.busy + self._q

    rec = LatencyRecorder(1.0)
    pipe = MetricsPipeline(rec, 1.0)
    rec.record(_fake_req(0, 0, 0.2, 0.3))
    pipe.sample_servers(1.0, [_Srv(0, 2, 3, 4), _Srv(1, 0, 0, 4)])
    f = [fr for fr in pipe.frames() if fr.t == 0][0]
    assert f.util == {0: 0.5, 1: 0.0}
    assert f.qdepth == {0: 3, 1: 0}
    rows = pipe.to_rows()
    assert rows[0]["total_qdepth"] == 3
    assert rows[0]["mean_util"] == pytest.approx(0.25)


class _GaugeSrv:
    """Configurable fake server: any combination of workers / max_batch /
    busy_time / tokens_done attribute shapes."""

    def __init__(self, sid, busy=0, queued=0, **attrs):
        self.server_id = sid
        self.busy = busy
        self._q = queued
        for k, v in attrs.items():
            setattr(self, k, v)

    def load(self):
        return self.busy + self._q


def test_capacity_workers_zero_is_not_max_batch():
    """Regression: `workers or max_batch` silently mapped workers=0 to
    the max_batch fallback — a zero-capacity server must read util 0,
    not borrow batch slots it does not have."""
    rec = LatencyRecorder(1.0)
    pipe = MetricsPipeline(rec, 1.0)
    pipe.sample_servers(1.0, [_GaugeSrv(0, busy=0, queued=3, workers=0,
                                        max_batch=4)])
    f = pipe.frames()[0]
    assert f.util == {0: 0.0}
    assert f.occupancy == {0: 0.0}
    assert f.qdepth == {0: 3}


def test_capacity_both_attribute_shapes():
    """workers-shaped (SimServer) and max_batch-shaped (engine handles /
    batched servers) both resolve their own capacity."""
    rec = LatencyRecorder(1.0)
    pipe = MetricsPipeline(rec, 1.0)
    pipe.sample_servers(1.0, [
        _GaugeSrv(0, busy=2, workers=4),               # scalar: 2/4 busy
        _GaugeSrv(1, busy=3, workers=None, max_batch=6),   # batch slots
        _GaugeSrv(2, busy=5),                          # neither -> cap 1
    ])
    f = pipe.frames()[0]
    assert f.util[0] == pytest.approx(0.5)
    assert f.occupancy[0] == pytest.approx(0.5)
    assert f.util[1] == pytest.approx(0.5)      # 3/6 resident
    assert f.occupancy[1] == pytest.approx(0.5)
    assert f.util[2] == 1.0                     # clipped at capacity 1


def test_occupancy_and_tokens_gauges_for_batched_servers():
    """A batched server (declares serializes_ops) gets: util normalized
    per server, occupancy normalized by batch slots, and a tokens/sec
    rate from the cumulative counter."""
    rec = LatencyRecorder(1.0)
    pipe = MetricsPipeline(rec, 1.0)
    srv = _GaugeSrv(0, busy=4, queued=2, workers=None, max_batch=8,
                    serializes_ops=True, busy_time=0.9, tokens_done=1200)
    scalar = _GaugeSrv(1, busy=1, workers=2)
    pipe.sample_servers(1.0, [srv, scalar])
    f = pipe.frames()[0]
    assert f.util[0] == pytest.approx(0.9)          # op-seconds / interval
    assert f.occupancy[0] == pytest.approx(0.5)     # 4 of 8 slots resident
    assert f.tokens_per_sec == {0: 1200.0}          # scalar servers absent
    srv.busy_time = 1.7
    srv.tokens_done = 1800
    pipe.sample_servers(2.0, [srv, scalar])
    f2 = [fr for fr in pipe.frames() if fr.t == 1][0]
    assert f2.util[0] == pytest.approx(0.8)         # delta op-seconds
    assert f2.tokens_per_sec[0] == pytest.approx(600.0)
    rows = pipe.to_rows()
    assert rows[0]["tokens_per_sec"] == pytest.approx(1200.0)
    assert rows[0]["mean_occupancy"] == pytest.approx((0.5 + 0.5) / 2)


def test_token_counter_alone_does_not_serialize_util():
    """Counting tokens must not imply serialized ops: a concurrent server
    that happens to expose tokens_done still normalizes util by its
    capacity, not per server."""
    rec = LatencyRecorder(1.0)
    pipe = MetricsPipeline(rec, 1.0)
    srv = _GaugeSrv(0, busy=2, workers=4, busy_time=2.0, tokens_done=500)
    pipe.sample_servers(1.0, [srv])
    f = pipe.frames()[0]
    assert f.util[0] == pytest.approx(0.5)      # 2.0 op-seconds / 4 slots
    assert f.tokens_per_sec == {0: 500.0}       # the counter still feeds rate


def test_pipeline_frames_streaming_mode():
    rec = LatencyRecorder(1.0, mode="streaming")
    pipe = MetricsPipeline(rec, 1.0, slo=0.05)
    rng = np.random.default_rng(1)
    for i in range(2000):
        t = float(rng.uniform(0, 3))
        rec.record(_fake_req(i, 0, t, t + (0.1 if i % 10 == 0 else 0.01)))
    frames = pipe.frames()
    assert sum(f.n for f in frames) == 2000
    # well-populated intervals see the ~10% true violation rate (the last
    # interval only catches slow-tail spillover, so skip sparse frames)
    full = [f for f in frames if f.n > 300]
    assert full
    for f in full:
        assert 0.05 < f.slo_violation_frac < 0.2


# ---------------------------------------------------------------------------
# Summary hot path + empty-summary unification + memoization
# ---------------------------------------------------------------------------
def test_summary_of_single_call_matches_three_calls():
    """One vectorized np.percentile call must be bit-identical to the
    historical three separate calls, for list and ndarray inputs."""
    rng = np.random.default_rng(3)
    xs = rng.lognormal(-6, 0.5, 4001)
    for inp in (xs, list(xs), iter(list(xs))):
        s = Summary.of(inp)
        assert s.n == len(xs)
        assert s.mean == float(xs.mean())
        for name, q in (("p50", 50), ("p95", 95), ("p99", 99)):
            assert getattr(s, name) == float(np.percentile(xs, q))


def test_empty_summary_single_code_path():
    """Every empty-input consumer shares Summary.empty(): NaN-filled,
    n=0 — and the SLO math follows the same contract."""
    from repro.core.stats import pctl, slo_violation_frac
    empties = [Summary.of([]), Summary.of(np.empty(0)), Summary.empty(),
               StreamingStat().summary()]
    for s in empties:
        assert s.n == 0
        assert all(math.isnan(v) for v in (s.mean, s.p50, s.p95, s.p99))
    assert math.isnan(pctl([], 99))
    assert math.isnan(slo_violation_frac([], 0.1))
    assert math.isnan(slo_violation_frac([1.0, 2.0], None))
    assert slo_violation_frac([1.0, 2.0, 3.0, 4.0], 2.5) == 0.5
    # an interval with gauges but no latency samples renders the same
    # empty summary inside frames() — no bespoke emptiness branch
    rec = LatencyRecorder(1.0)
    pipe = MetricsPipeline(rec, 1.0, slo=0.05)
    pipe.sample_servers(1.0, [])
    rec.record(_fake_req(0, 0, 1.2, 1.25))       # interval 1 only
    frames = {f.t: f for f in pipe.frames()}
    assert frames[0].n == 0
    assert math.isnan(frames[0].p99)
    assert math.isnan(frames[0].slo_violation_frac)
    assert frames[1].n == 1


def test_quantiles_partition_matches_percentile():
    from repro.core.stats import quantiles_partition
    rng = np.random.default_rng(5)
    for n in (1, 2, 7, 100, 9999):
        xs = rng.lognormal(0, 1, n)
        got = quantiles_partition(xs, (50.0, 95.0, 99.0))
        want = np.percentile(xs, (50, 95, 99))
        assert np.allclose(got, want, rtol=0, atol=0) or \
            np.array_equal(got, want)
    assert np.isnan(quantiles_partition(np.empty(0), (50.0,))).all()


def test_pipeline_memoizes_until_dirty():
    """frames()/series() are rebuilt only when a new sample or gauge
    lands — repeated windowed reads hit the cache."""
    rec = LatencyRecorder(1.0)
    pipe = MetricsPipeline(rec, 1.0)
    for i in range(50):
        rec.record(_fake_req(i, 0, 0.1 * i, 0.1 * i + 0.02))
    f1 = pipe.frames()
    assert pipe.frames() is f1                   # cache hit
    s1 = pipe.series()
    assert pipe.series() is s1
    assert pipe.window("p99") == [s.p99 for s in s1.values()]
    rec.record(_fake_req(99, 0, 1.0, 1.5))       # new sample -> dirty
    f2 = pipe.frames()
    assert f2 is not f1
    assert sum(f.n for f in f2) == 51
    pipe.sample_servers(1.0, [])                 # gauge write -> dirty
    assert pipe.frames() is not f2
    # streaming mode uses the recorder's O(1) counters the same way
    rec2 = LatencyRecorder(1.0, mode="streaming")
    pipe2 = MetricsPipeline(rec2, 1.0)
    rec2.record(_fake_req(0, 0, 0.5, 0.52))
    g1 = pipe2.frames()
    assert pipe2.frames() is g1
    rec2.record(_fake_req(1, 0, 0.6, 0.62))
    assert pipe2.frames() is not g1


# ---------------------------------------------------------------------------
# stdlib-random -> np.random.Generator migration (PR 6 regression capture)
# ---------------------------------------------------------------------------
def test_exact_mode_constructs_no_rng():
    # exact mode is the bit-compatibility contract: the migration must
    # not touch it, and it never owns an RNG at all
    rec = LatencyRecorder(1.0, mode="exact")
    assert not hasattr(rec, "_rand")


def test_exact_mode_outputs_are_pure_arithmetic():
    # regression capture: exact-mode summaries are a deterministic
    # function of the recorded samples alone (no sampling anywhere)
    rec = LatencyRecorder(1.0, mode="exact")
    lats = []
    for i in range(200):
        t0 = 0.01 * i
        lat = 0.001 * ((i * 37) % 100 + 1)
        rec.record(_fake_req(i % 4, 0, t0, t0 + lat))
        lats.append(lat)
    s = rec.overall()
    assert s.n == 200
    assert s.mean == pytest.approx(float(np.mean(lats)))
    assert s.p50 == pytest.approx(float(np.percentile(lats, 50)))
    assert s.p99 == pytest.approx(float(np.percentile(lats, 99)))


def test_streaming_reservoir_keyed_by_seed_and_rep():
    def fill(seed, rep):
        rec = LatencyRecorder(1.0, mode="streaming", seed=seed, rep=rep)
        for i in range(5000):
            t0 = 0.01 * i
            rec.record(_fake_req(0, 0, t0, t0 + 0.001 * (i % 97)))
        return rec

    a, b = fill(7, 0), fill(7, 0)
    assert a._all.res.data == b._all.res.data    # same key -> same sample
    c = fill(7, 1)
    assert a._all.res.data != c._all.res.data    # rep threads the stream
    assert a.overall().n == c.overall().n == 5000
    d = fill(8, 0)
    assert a._all.res.data != d._all.res.data    # seed threads it too


def test_reservoir_default_stream_is_deterministic():
    r1, r2 = ReservoirSample(k=8, seed=3), ReservoirSample(k=8, seed=3)
    r3 = ReservoirSample(k=8, seed=4)
    for x in range(2000):
        r1.add(float(x))
        r2.add(float(x))
        r3.add(float(x))
    assert r1.data == r2.data
    assert r1.data != r3.data


def test_quantiles_partition_batched_bitwise_scalar():
    """The fused extraction's contract: the batched row-wise path is
    bit-for-bit the scalar `quantiles_partition`, hoisted plan and all
    (NaN rows where a count is zero)."""
    from repro.core.stats import (quantiles_partition,
                                  quantiles_partition_batched)
    rng = np.random.default_rng(7)
    counts = np.array([0, 1, 2, 17, 100, 64])
    K = int(counts.max())
    mat = np.zeros((counts.size, K))
    for i, n in enumerate(counts):
        mat[i, :n] = rng.gamma(2.0, 0.01, n)
    qs = (50.0, 95.0, 99.0)
    got = quantiles_partition_batched(mat, counts, qs)
    for i, n in enumerate(counts):
        if n == 0:
            assert np.all(np.isnan(got[i]))
        else:
            want = quantiles_partition(mat[i, :n], qs)
            assert got[i].tobytes() == np.asarray(want).tobytes()


def test_quantile_plan_hoisting_stable():
    """Repeated calls reuse one hoisted order-statistic plan — and the
    plan cache cannot change results (cleared vs warm: same bits)."""
    from repro.core import stats as st
    xs = np.random.default_rng(11).random(501)
    qs = (50.0, 95.0, 99.0)
    st._QPLAN_CACHE.clear()
    cold = st.quantiles_partition(xs, qs)
    assert (501, qs) in {(k[0], k[1]) for k in st._QPLAN_CACHE}
    warm = st.quantiles_partition(xs, qs)
    assert np.asarray(cold).tobytes() == np.asarray(warm).tobytes()


def test_quantile_plan_lru_eviction_never_changes_results():
    """The plan memo is a capped LRU now: force a cap of 1 so every
    distinct (n, qs) evicts the last, and verify bits never move."""
    from repro.core import stats as st
    rng = np.random.default_rng(13)
    sizes = (101, 257, 512, 101)        # revisit 101 after eviction
    qs = (50.0, 95.0, 99.0)
    st._QPLAN_CACHE.clear()
    baseline = [st.quantiles_partition(rng.random(n), qs) for n in sizes]
    old_cap = st._QPLAN_CACHE_CAP
    st._QPLAN_CACHE_CAP = 1
    try:
        st._QPLAN_CACHE.clear()
        rng = np.random.default_rng(13)
        capped = [st.quantiles_partition(rng.random(n), qs) for n in sizes]
        assert len(st._QPLAN_CACHE) <= 1
    finally:
        st._QPLAN_CACHE_CAP = old_cap
        st._QPLAN_CACHE.clear()
    for a, b in zip(baseline, capped):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
