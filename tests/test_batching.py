"""Batch-aware service layer: BatchedService roofline costs, the shared
BatchScheduler dynamics, the simulator's continuous-batching serve loop,
and sim-vs-stub-engine agreement (the measurement-fidelity property the
refactor exists for)."""
import math

import numpy as np
import pytest

from repro.core.client import ClientConfig, ClientGenerator, ConstantQPS
from repro.core.harness import Experiment, ServerSpec, run
from repro.core.profiles import (BatchedService, BatchScheduler, FixedProfile,
                                 ScalarService, TokenLengths,
                                 resolve_service_model, tailbench_profile)
from repro.core.runtime import EngineRuntime, VirtualClock, run_scenario
from repro.core.scenario import ClientArrival, Scenario, ServerFail
from repro.scenarios import get
from repro.scenarios.backends import build_stub_engines
from repro.scenarios.canonical import default_batched_service
from repro.serving.engine import BatchedStubEngine


SVC = BatchedService("toy", t_memory=1e-3, t_compute_per_seq=2e-4,
                     t_prefill_per_token=1e-5)


# ---------------------------------------------------------------------------
# ServiceModel cost shapes
# ---------------------------------------------------------------------------
def test_batched_service_roofline_max():
    # memory-bound below the ridge (1e-3 / 2e-4 = batch 5), compute past it
    assert SVC.step_time(1) == 1e-3
    assert SVC.step_time(5) == 1e-3
    assert SVC.step_time(8) == pytest.approx(1.6e-3)
    assert SVC.ridge_batch == pytest.approx(5.0)
    # prefill proportional to prompt tokens, floored at one weight pass
    assert SVC.prefill_time(500) == pytest.approx(5e-3)
    assert SVC.prefill_time(10) == 1e-3                  # floor


def test_batched_service_throughput_sublinear():
    """Tokens/sec rises with occupancy but saturates past the ridge —
    the continuous-batching curve the scalar model cannot express."""
    rates = [SVC.service_rate(b) for b in (1, 2, 5, 10, 20)]
    assert all(b >= a for a, b in zip(rates, rates[1:]))  # monotone
    assert rates[2] > rates[1] > rates[0]                # rising below ridge
    assert rates[1] == pytest.approx(2 * rates[0])       # linear while mem-bound
    assert rates[4] == pytest.approx(rates[3])           # flat when compute-bound
    assert SVC.service_rate(20) < 20 * SVC.service_rate(1) / 2


def test_scalar_service_wraps_profile():
    prof = tailbench_profile("xapian")
    svc = ScalarService(prof)
    assert svc.kind == "scalar" and svc.mean == prof.mean
    rng = np.random.default_rng(0)
    rng2 = np.random.default_rng(0)
    assert svc.sample(rng) == prof.sample(rng2)
    assert resolve_service_model(None, prof).profile is prof
    assert resolve_service_model(SVC, prof) is SVC


# ---------------------------------------------------------------------------
# Shared scheduler core
# ---------------------------------------------------------------------------
def test_batch_scheduler_prefill_priority_and_completion():
    core = BatchScheduler(SVC, max_batch=2)
    core.submit("a", prompt_tokens=100, max_new_tokens=2)
    core.submit("b", prompt_tokens=100, max_new_tokens=1)
    core.submit("c", prompt_tokens=100, max_new_tokens=3)
    # op 1: prefill a (emits its first token)
    assert core.start_op() == pytest.approx(1e-3)
    assert core.occupancy() == 1
    assert core.finish_op() == []
    # op 2: prefill b -> its only token completes it at the op end
    core.start_op()
    assert core.finish_op() == ["b"]
    # op 3: batch full? a active, b done, c waiting, slots=2 -> prefill c
    core.start_op()
    assert core.op[0] == "prefill"
    assert core.finish_op() == []
    # op 4: decode step of {a, c}: a emits token 2 of 2 -> done,
    # c emits token 2 of 3
    dur = core.start_op()
    assert core.op[0] == "decode" and dur == pytest.approx(1e-3)
    assert core.finish_op() == ["a"]
    # one more decode emits c's last token
    core.start_op()
    assert core.finish_op() == ["c"]
    assert core.idle()
    assert core.tokens_done == 2 + 1 + 3


def test_batch_scheduler_respects_max_batch():
    core = BatchScheduler(SVC, max_batch=2)
    for k in range(4):
        core.submit(k, 10, 5)
    core.start_op(); core.finish_op()          # prefill 0
    core.start_op(); core.finish_op()          # prefill 1 -> batch full
    core.start_op()
    assert core.op[0] == "decode"              # 2 and 3 must wait
    assert core.pending() == 2
    assert core.occupancy() == 2


def test_batch_scheduler_ready_predicate_holds_head():
    core = BatchScheduler(SVC, max_batch=4)
    core.submit("later", 10, 2)
    core.submit("now", 10, 2)
    # FIFO head not yet arrived at the op boundary -> no admission (and
    # no queue-jumping by "now"), fall through to idle
    assert core.start_op(ready=lambda k: k == "now") is None
    assert core.start_op(ready=lambda k: True) is not None
    assert core.op[1].key == "later"           # FIFO preserved


# ---------------------------------------------------------------------------
# Token-size semantics
# ---------------------------------------------------------------------------
def test_token_lengths_deterministic_and_bounded():
    tl = TokenLengths(prompt_median=100, prompt_sigma=0.5, new_median=20,
                      new_sigma=0.5, prompt_max=256, new_max=64)
    rng = np.random.default_rng(1)
    sizes = [tl.sample(rng) for _ in range(2000)]
    assert all(1 <= p <= 256 and 1 <= n <= 64 for p, n in sizes)
    med_p = np.median([p for p, _ in sizes])
    assert 80 < med_p < 125
    rng2 = np.random.default_rng(1)
    assert sizes[:50] == [tl.sample(rng2) for _ in range(50)]


def test_sizes_identical_across_backends_and_separate_stream():
    """Both backends draw the same (arrival, demand, sizes) streams; and
    configuring lengths must NOT perturb the arrival-time draws."""
    prof = tailbench_profile("xapian")
    cfg = ClientConfig(3, ConstantQPS(200), seed=17, total_requests=200)
    tl = TokenLengths()

    def drain(gen):
        out = []
        while True:
            nxt = gen.next_arrival()
            if nxt is None:
                return out
            out.append((nxt[0], nxt[1], gen.last_sizes))

    a = drain(ClientGenerator(cfg, prof, rng_stream=0, lengths=tl))
    b = drain(ClientGenerator(cfg, prof, rng_stream=0, lengths=tl))
    assert a == b
    assert len({s for _, _, s in a}) > 20          # sizes actually vary
    unsized = drain(ClientGenerator(cfg, prof, rng_stream=0))
    assert [(t, d) for t, d, _ in a] == [(t, d) for t, d, _ in unsized]
    assert all(s == (0, 0) for _, _, s in unsized)


# ---------------------------------------------------------------------------
# Simulator batched serve loop
# ---------------------------------------------------------------------------
def _batched_exp(qps=60.0, duration=10.0, max_batch=8, n_servers=1,
                 seed=5, **kw):
    clients = [ClientConfig(i, ConstantQPS(qps / 2), seed=seed)
               for i in range(2)]
    return Experiment(
        clients=clients, duration=duration, seed=seed, policy="jsq",
        servers=tuple(ServerSpec(i, max_batch=max_batch)
                      for i in range(n_servers)),
        service_model=SVC, lengths=TokenLengths(new_median=16, new_max=64),
        **kw)


def test_sim_batched_end_to_end():
    sim = run(_batched_exp())
    s = sim.telemetry.overall()
    assert s.n > 400
    assert sim.dropped == 0
    srv = sim.servers[0]
    assert srv.total_served == s.n
    assert srv.tokens_done > 16 * s.n / 2      # ~16 tokens per request
    assert 0 < s.p50 <= s.p99
    # latency at low load ~ new_tokens * step_time: tens of ms
    assert 5e-3 < s.p50 < 0.2


def test_sim_batched_occupancy_and_tokens_gauges():
    sim = run(_batched_exp(qps=100.0))
    frames = [f for f in sim.telemetry.frames() if 1 <= f.t <= 8]
    assert frames
    assert all(0.0 <= f.occupancy[0] <= 1.0 for f in frames)
    assert any(f.occupancy[0] > 0.2 for f in frames)
    assert all(f.tokens_per_sec[0] > 0 for f in frames)
    # tokens/sec can never exceed the roofline service rate at full batch
    cap = SVC.service_rate(8)
    assert all(f.tokens_per_sec[0] <= cap * 1.05 for f in frames)


def test_sim_batched_deterministic():
    a = run(_batched_exp()).recorder.all
    b = run(_batched_exp()).recorder.all
    assert a and a == b


def test_sim_batched_knee_moves_with_max_batch():
    """Sub-linear but real: capacity grows with batch slots, so at a load
    that saturates max_batch=2, max_batch=8 still serves flat."""
    hot = run(_batched_exp(qps=120.0, max_batch=2, duration=12.0))
    cool = run(_batched_exp(qps=120.0, max_batch=8, duration=12.0))
    assert cool.telemetry.overall().p99 < hot.telemetry.overall().p99 / 3
    assert hot.servers[0].load() > 20          # saturated: queue built up
    assert cool.servers[0].load() <= 10        # stable residency, no backlog


def test_sim_batched_server_failure_loses_batch():
    sc = Scenario(
        name="bfail", duration=10.0, seed=7, policy="jsq",
        servers=(ServerSpec(0, max_batch=4), ServerSpec(1, max_batch=4)),
        service_model=SVC, lengths=TokenLengths(),
        events=[ClientArrival(0.0, 120.0, count=2),
                ServerFail(5.0, 1)])
    rt = run_scenario(sc, "sim")
    assert rt.sim.servers[1].failed
    assert rt.dropped > 0                      # resident batch + queue lost
    assert rt.telemetry.overall().n > 0        # survivor keeps serving
    late = sum(rt.telemetry.window("n", 6, 10))
    assert late > 0


# ---------------------------------------------------------------------------
# Sim vs stub engine: agreement by construction
# ---------------------------------------------------------------------------
def _run_both(qps, max_batch=4, duration=12.0, seed=9):
    sc = get("batched-serving", seed=seed, duration=duration, qps=qps,
             n_clients=2, n_servers=1, max_batch=max_batch, service=SVC,
             lengths=TokenLengths(new_median=16, new_max=64))
    sim_rt = run_scenario(sc, "sim")
    clock = VirtualClock()
    exp = sc.compile()
    engines, factory = build_stub_engines(exp, clock, seed)
    eng_rt = EngineRuntime.from_experiment(exp, engines,
                                           engine_factory=factory,
                                           clock=clock, sleep=clock.sleep)
    eng_rt.run()
    return sim_rt.telemetry.overall(), eng_rt.telemetry.overall()


def test_stub_fleet_is_batched_for_batched_experiments():
    sc = get("batched-serving", seed=1, n_servers=2, service=SVC)
    engines, factory = build_stub_engines(sc.compile(), VirtualClock(), 0)
    assert all(isinstance(e, BatchedStubEngine) for e in engines.values())
    assert isinstance(factory(0), BatchedStubEngine)


@pytest.mark.parametrize("qps", [40.0, 120.0])
def test_sim_vs_stub_engine_latency_parity(qps):
    """Same scenario, both backends, shared BatchScheduler dynamics:
    latency percentiles agree tightly below AND near the knee."""
    s_sim, s_eng = _run_both(qps)
    assert abs(s_sim.n - s_eng.n) <= max(10, 0.02 * s_sim.n)
    assert s_eng.p50 == pytest.approx(s_sim.p50, rel=0.10)
    assert s_eng.p99 == pytest.approx(s_sim.p99, rel=0.15)


def test_scalar_service_model_profile_is_honored():
    """Experiment(service_model=ScalarService(p)) must serve with p, not
    silently fall back to the app's default profile."""
    fixed = FixedProfile("fixed", 0.05)
    exp = Experiment(clients=[ClientConfig(0, ConstantQPS(5), seed=2,
                                           total_requests=20)],
                     duration=30.0, seed=2,
                     service_model=ScalarService(fixed))
    assert exp.resolved_profile() is fixed
    s = run(exp).telemetry.overall()
    assert s.n == 20
    assert s.p50 == pytest.approx(0.05)
    # an explicit profile= still wins over the wrapper
    other = FixedProfile("other", 0.01)
    assert Experiment(clients=[], profile=other,
                      service_model=ScalarService(fixed)
                      ).resolved_profile() is other


def test_batched_experiment_defaults_lengths():
    """A batched service_model with lengths unset must not silently
    degenerate every request to one prompt token and zero decode steps —
    resolved_lengths falls back to the stock TokenLengths."""
    exp = _batched_exp()
    exp = Experiment(clients=exp.clients, duration=exp.duration,
                     seed=exp.seed, policy=exp.policy, servers=exp.servers,
                     service_model=SVC)          # lengths=None
    assert isinstance(exp.resolved_lengths(), TokenLengths)
    sim = run(exp)
    s = sim.telemetry.overall()
    assert s.n > 100
    # stock TokenLengths median is 16 new tokens: multi-step decode, so
    # latencies sit well above a single prefill+decode op pair
    assert sim.servers[0].tokens_done > 4 * s.n
    # scalar experiments keep lengths=None (no spurious size sampling)
    assert Experiment(clients=exp.clients).resolved_lengths() is None


def test_stub_engines_honor_service_noise():
    """service_noise configured on a ServerSpec reaches the stub engines
    (the simulator already applied it; the engine backend must too)."""
    def total_busy(noise):
        sc = get("batched-serving", seed=3, duration=8.0, qps=40.0,
                 n_clients=2, n_servers=1, max_batch=4, service=SVC,
                 lengths=TokenLengths(new_median=8, new_max=16))
        exp = sc.compile()
        exp = Experiment(
            clients=exp.clients, duration=exp.duration, seed=exp.seed,
            policy=exp.policy, service_model=exp.service_model,
            lengths=exp.lengths,
            servers=tuple(ServerSpec(s.server_id, max_batch=s.max_batch,
                                     service_noise=noise)
                          for s in exp.servers))
        clock = VirtualClock()
        engines, factory = build_stub_engines(exp, clock, 3)
        assert all(e.service_noise == noise for e in engines.values())
        rt = EngineRuntime.from_experiment(exp, engines,
                                           engine_factory=factory,
                                           clock=clock, sleep=clock.sleep)
        rt.run()
        return sum(h.busy_time for h in rt.handles.values())

    quiet, noisy = total_busy(0.0), total_busy(1.0)
    assert quiet > 0
    assert noisy != quiet                        # noise draws actually bite


def test_batched_scenario_runs_via_cli_entry():
    from repro.scenarios.__main__ import main
    assert main(["batched-serving", "--duration", "4"]) == 0
    assert main(["batched-serving", "--duration", "4", "--backend",
                 "engine", "--stub"]) == 0
