"""Spec-validator tests: capability matrix, seed collisions, schedule."""
from __future__ import annotations

from dataclasses import replace

import pytest

from repro import scenarios
from repro.analysis.check import (
    CAPABILITIES,
    check_scenario,
    check_sweep,
    format_matrix,
    has_errors,
    required_features,
    unsupported_on,
)
from repro.analysis.check.schedule import check_schedule, offered_rho
from repro.analysis.check.seeds import check_sweep_seeds
from repro.core.scenario import Injection
from repro.sweep.spec import Sweep, scenario_factory
from repro.vector.compile import compile_experiment


# ---------------------------------------------------------------------------
# Capability matrix
# ---------------------------------------------------------------------------
def test_every_canonical_scenario_passes_without_backend():
    for name in scenarios.names():
        findings = check_scenario(scenarios.get(name))
        assert not has_errors(findings), \
            "\n".join(f.format() for f in findings)


def test_set_hedge_rejected_on_vector_and_engine():
    scn = scenarios.get("churn-storm")
    for backend in ("vector", "engine"):
        findings = check_scenario(scn, backend=backend)
        cap = [f for f in findings if f.rule == "capability"]
        assert cap and cap[0].severity == "error"
        assert "set_hedge" in cap[0].message
        assert "capability matrix" in cap[0].message
    assert not has_errors(check_scenario(scn, backend="sim"))


def test_capability_matrix_mirrors_runtime_contracts():
    exp = scenarios.get("churn-storm").compile()
    feats = dict(required_features(exp))
    assert "injection:set_hedge" in feats
    assert unsupported_on(exp, "sim") == []
    assert any(f == "injection:set_hedge"
               for f, _ in unsupported_on(exp, "vector"))
    # speed scaling: sim+vector yes, engine no
    assert "injection:server_speed" in CAPABILITIES["sim"]
    assert "injection:server_speed" in CAPABILITIES["vector"]
    assert "injection:server_speed" not in CAPABILITIES["engine"]
    with pytest.raises(ValueError):
        unsupported_on(exp, "warp-drive")
    assert "set_hedge" in format_matrix(exp)


# ---------------------------------------------------------------------------
# Seed collisions
# ---------------------------------------------------------------------------
def _sweep(seeder, points=3, reps=3):
    return Sweep(name="t", factory=scenario_factory("steady"),
                 axes=[("qps", [100.0 * (i + 1) for i in range(points)])],
                 fixed={"duration": 2.0}, reps=reps, seeder=seeder)


def test_spawn_seeder_is_collision_free():
    assert check_sweep_seeds(_sweep("spawn")) == []


def test_run_repeated_seeder_collides_across_points():
    findings = check_sweep_seeds(_sweep("run-repeated"))
    assert findings and all(f.severity == "error" for f in findings)
    assert "correlated" in findings[0].message


def test_fixed_seeder_exempt_by_contract():
    assert check_sweep_seeds(_sweep("fixed")) == []


def test_check_sweep_validates_points_and_backend():
    sweep = _sweep("spawn")
    assert not has_errors(check_sweep(sweep))
    hedged = Sweep(name="h", factory=scenario_factory("churn-storm"),
                   axes=[("client_qps", [50.0, 100.0])],
                   fixed={"duration": 4.0}, reps=2, runtime="vector")
    findings = check_sweep(hedged)
    cap = [f for f in findings if f.rule == "capability"]
    assert cap and all(f.severity == "error" for f in cap)
    assert "[0]" in cap[0].target


# ---------------------------------------------------------------------------
# Schedule sanity
# ---------------------------------------------------------------------------
def test_overload_draws_rho_warning():
    exp = scenarios.get("steady", qps=100000.0, n_servers=1,
                        duration=5.0).compile()
    findings = check_schedule(exp, "steady")
    assert any("rho>=1" in f.message for f in findings
               if f.rule == "schedule")
    rho, offered, capacity = offered_rho(compile_experiment(exp, dt=0.05))
    assert float(rho.max()) >= 1.0


def test_sane_schedule_is_quiet():
    exp = scenarios.get("steady", duration=5.0).compile()
    assert check_schedule(exp, "steady") == []


def test_injection_after_horizon_warns():
    exp = scenarios.get("steady", duration=5.0).compile()
    late = replace(exp, injections=list(exp.injections) +
                   [Injection(99.0, "set_policy", {"policy": "jsq"})])
    findings = check_schedule(late, "late")
    assert any("never happens" in f.message for f in findings)


def test_zero_duration_is_an_error():
    exp = replace(scenarios.get("steady", duration=5.0).compile(),
                  duration=0.0)
    findings = check_schedule(exp, "zero")
    assert has_errors(findings)


def test_batched_overload_uses_token_law():
    exp = scenarios.get("batched-serving", qps=100000.0,
                        duration=5.0).compile()
    prog = compile_experiment(exp, dt=0.05)
    assert prog.batched
    rho, _, _ = offered_rho(prog)
    assert float(rho.max()) >= 1.0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_cli_check_default_is_clean(capsys):
    from repro.analysis.__main__ import main
    assert main(["check"]) == 0
    out = capsys.readouterr().out
    assert "0 error(s)" in out


def test_cli_check_rejects_vector_hedge(capsys):
    from repro.analysis.__main__ import main
    rc = main(["check", "--scenario", "churn-storm",
               "--backend", "vector"])
    assert rc == 1
    out = capsys.readouterr().out
    assert "capability matrix" in out
    assert "set_hedge" in out


def test_cli_check_json(capsys):
    import json
    from repro.analysis.__main__ import main
    assert main(["check", "--scenario", "steady", "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["summary"]["errors"] == 0
