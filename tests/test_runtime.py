"""Runtime layer: the wall-clock EngineRuntime reuses the simulator's
client/balancer/recorder machinery, honors the balancer lifecycle, and
accepts the same compiled Scenario as the virtual-time backend."""
import warnings

import numpy as np
import pytest

from repro.core.client import ClientConfig, ConstantQPS
from repro.core.harness import (Experiment, ServerSpec, run,
                                run_engine_experiment)
from repro.core.profiles import FixedProfile, tailbench_profile
from repro.core.runtime import (EngineRuntime, SimulatorRuntime,
                                VirtualClock, run_scenario)
from repro.core.scenario import (ClientArrival, Scenario, ServerFail,
                                 SetPolicy)
from repro.serving.engine import StubEngine


def _stub_fleet(n, clock, profile=None, workers=2, seed=0):
    prof = profile or FixedProfile("svc", 2e-3)
    return [StubEngine(prof, workers=workers, seed=seed + i, clock=clock)
            for i in range(n)]


def _make_runtime(clients, n_engines=2, profile=None, **kw):
    clock = VirtualClock()
    engines = _stub_fleet(n_engines, clock, profile)
    rt = EngineRuntime(engines, clients, clock=clock, sleep=clock.sleep, **kw)
    return rt


def test_engine_runtime_serves_all_clients():
    clients = [ClientConfig(i, ConstantQPS(100), seed=i + 1,
                            total_requests=200) for i in range(3)]
    rt = _make_runtime(clients, policy="round_robin", duration=30.0)
    rt.run()
    s = rt.telemetry.overall()
    assert s.n == 600
    assert sorted(rt.recorder.clients()) == [0, 1, 2]
    assert all(rt.telemetry.client(i).n == 200 for i in range(3))
    # balancer lifecycle: exhausted clients released their connections
    assert rt.assignment == {}


def test_engine_runtime_arrivals_match_simulator():
    """Same configs + seeds + profile -> bit-identical arrival timelines
    (the generators are shared verbatim across backends)."""
    from repro.core.harness import build_simulator
    clients = [ClientConfig(i, ConstantQPS(150), seed=7,
                            total_requests=150) for i in range(2)]
    exp = Experiment(clients=clients, servers=(ServerSpec(0), ServerSpec(1)),
                     app="xapian", duration=30.0, seed=7)

    def drain(gen):
        out = []
        while True:
            nxt = gen.next_arrival()
            if nxt is None:
                break
            out.append(nxt)              # (time, service_demand) pairs
        return out

    # pull the arrival streams out of each backend's own generators
    # before running anything: they must be the exact same draws
    sim_gens = build_simulator(exp).clients
    eng_rt = EngineRuntime.from_experiment(
        exp, _stub_fleet(2, VirtualClock(), tailbench_profile("xapian")))
    for cid in (0, 1):
        assert drain(sim_gens[cid]) == drain(eng_rt._gens[cid])

    # and end-to-end both backends serve every generated request
    sim = run(exp)
    clock = VirtualClock()
    engines = _stub_fleet(2, clock, tailbench_profile("xapian"))
    rt = EngineRuntime.from_experiment(exp, engines, clock=clock,
                                       sleep=clock.sleep)
    rt.run()
    assert rt.telemetry.overall().n == sim.telemetry.overall().n == 300


def test_engine_runtime_telemetry_frames():
    clients = [ClientConfig(0, ConstantQPS(200), seed=3, end_time=10.0)]
    rt = _make_runtime(clients, duration=10.0, slo=1e-9)
    rt.run()
    frames = rt.telemetry.frames()
    assert len(frames) >= 9
    assert sum(f.n for f in frames) == rt.telemetry.overall().n
    mid = frames[len(frames) // 2]
    assert mid.qps > 0 and 0 <= mid.slo_violation_frac <= 1.0
    assert mid.util and all(0.0 <= u <= 1.0 for u in mid.util.values())


def test_engine_runtime_load_aware_release_on_churn():
    """Short-lived clients must not leave ghost subscriptions behind."""
    from repro.core.balancer import LoadAware
    bal = LoadAware()
    clients = [ClientConfig(0, ConstantQPS(400), seed=1, total_requests=50),
               ClientConfig(1, ConstantQPS(100), seed=2, total_requests=400)]
    rt = _make_runtime(clients, policy=bal, duration=30.0)
    rt.run()
    assert rt.telemetry.overall().n == 450
    assert bal._client_sub == {}           # every departure released


def test_scenario_parity_sim_vs_engine():
    """One Scenario, both backends: same arrival count, same ordering of
    light vs heavy intervals, plausibly-scaled latencies."""
    sc = Scenario(
        name="parity", duration=20.0, seed=11, app="xapian", policy="jsq",
        servers=(ServerSpec(0, workers=2), ServerSpec(1, workers=2)),
        events=[ClientArrival(0.0, 300.0, count=2),
                ClientArrival(8.0, 600.0, count=2, leave_at=14.0)])
    sim_rt = run_scenario(sc, "sim")
    clock = VirtualClock()
    exp = sc.compile()
    engines = _stub_fleet(2, clock, tailbench_profile("xapian"), seed=11)
    eng_rt = run_scenario(sc, "engine", engines=engines,
                          clock=clock, sleep=clock.sleep)
    s_sim, s_eng = sim_rt.telemetry.overall(), eng_rt.telemetry.overall()
    # identical client machinery -> identical arrivals; served counts may
    # differ only by the horizon cutoff (the sim truncates completions at
    # t=duration, the engine drains its last in-flight handful)
    assert s_sim.n > 0 and s_eng.n > 0
    assert abs(s_sim.n - s_eng.n) <= 20
    # plausibly-ordered latencies: positive, tail >= median, same decade
    for s in (s_sim, s_eng):
        assert 0 < s.p50 <= s.p95 <= s.p99
    assert 0.2 < s_eng.p50 / s_sim.p50 < 5.0
    # both see the mid-run surge
    for rt in (sim_rt, eng_rt):
        base = np.mean(rt.telemetry.window("n", 2, 8))
        surge = np.mean(rt.telemetry.window("n", 9, 14))
        assert surge > 1.5 * base


def test_engine_runtime_server_fail_injection():
    sc = Scenario(
        name="fail", duration=15.0, seed=5, policy="jsq",
        servers=(ServerSpec(0), ServerSpec(1)),
        events=[ClientArrival(0.0, 300.0, count=2),
                ServerFail(6.0, 1),
                SetPolicy(8.0, "round_robin")])
    clock = VirtualClock()
    engines = _stub_fleet(2, clock, FixedProfile("svc", 1e-3))
    rt = run_scenario(sc, "engine", engines=engines,
                      clock=clock, sleep=clock.sleep)
    assert rt.handles[1].failed
    assert rt.handles[0].total_served > 0
    # served requests only stopped on the failed replica
    assert rt.telemetry.overall().n > 0
    from repro.core.balancer import RoundRobin
    assert isinstance(rt.balancer, RoundRobin)


def test_engine_runtime_unsupported_injections_surface():
    from repro.core.scenario import SetHedge
    sc = Scenario(name="h", duration=5.0,
                  events=[ClientArrival(0.0, 100.0),
                          SetHedge(2.0, 0.01)])
    clock = VirtualClock()
    engines = _stub_fleet(1, clock)
    rt = run_scenario(sc, "engine", engines=engines,
                      clock=clock, sleep=clock.sleep)
    assert [i.kind for i in rt.unsupported] == ["set_hedge"]


def test_run_engine_experiment_shim_deprecated():
    # the legacy shim runs on the real wall clock; 50 requests at 100 QPS
    # complete in well under a second against a 2ms-service stub
    engines = [StubEngine(FixedProfile("svc", 2e-3), workers=2)]
    clients = [ClientConfig(0, ConstantQPS(100), seed=1, total_requests=50)]
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        rec = run_engine_experiment(engines, clients, duration=5.0)
    deprecations = [x for x in w
                    if issubclass(x.category, DeprecationWarning)]
    assert len(deprecations) == 1            # exactly once per call
    assert "EngineRuntime" in str(deprecations[0].message)
    assert rec.overall().n == 50
    # the replacement path serves the same workload without warning
    clients = [ClientConfig(0, ConstantQPS(100), seed=1, total_requests=50)]
    clock = VirtualClock()
    rt = EngineRuntime([StubEngine(FixedProfile("svc", 2e-3), workers=2,
                                   clock=clock)],
                       clients, duration=5.0, clock=clock, sleep=clock.sleep)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        rt.run()
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert rt.telemetry.overall().n == 50


def test_simulator_runtime_adapter():
    exp = Experiment(clients=[ClientConfig(0, ConstantQPS(200), seed=9)],
                     duration=10.0, seed=9)
    rt = SimulatorRuntime(exp)
    rt.run()
    assert rt.telemetry.overall().n > 0
    assert rt.recorder is rt.sim.recorder


def test_engine_runtime_time_scale_aligns_telemetry():
    """time_scale stretches wall time; interval indices must stay in
    virtual time so frames align with gauges and the QPS schedule."""
    clock = VirtualClock()
    eng = [StubEngine(FixedProfile("s", 2e-3), workers=2, clock=clock)]
    rt = EngineRuntime(eng, [ClientConfig(0, ConstantQPS(50), seed=1,
                                          end_time=4.0)],
                       duration=4.0, time_scale=4.0,
                       clock=clock, sleep=clock.sleep)
    rt.run()
    frames = rt.telemetry.frames()
    assert max(f.t for f in frames) <= 4
    full = [f for f in frames if f.n > 20]
    assert full and all(25 < f.qps < 75 for f in full)


def test_engine_runtime_refused_connection_kills_client():
    """Parity with Simulator._connect: a client refused at connect time
    generates no traffic and counts one drop."""
    clock = VirtualClock()
    eng = _stub_fleet(1, clock)
    eng[0].accepting = False          # unused by handle; refuse via policy
    from repro.core.balancer import Balancer

    class _RefuseAll(Balancer):
        def assign(self, client, servers):
            return None

    rt = EngineRuntime(eng, [ClientConfig(0, ConstantQPS(100), seed=1,
                                          end_time=5.0)],
                       policy=_RefuseAll(), duration=5.0,
                       clock=clock, sleep=clock.sleep)
    rt.run()
    assert rt.dropped == 1
    assert rt.telemetry.overall().n == 0
