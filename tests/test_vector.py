"""Vector backend: statistical equivalence vs the event engine on the
dynamic edge cases, and the seeded-determinism contract.

Equivalence assertions follow the repo's fig4 methodology — repeated
seeded runs per backend, then 95%-CI-overlap (plus relative-error
guard-rails) on the pooled summary metrics.  Everything is a
deterministic function of the fixed seeds below, so these tests are
exact regressions, not flaky statistical coin flips.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.client import ClientConfig, ConstantQPS, DiurnalQPS
from repro.core.harness import Experiment, ServerSpec
from repro.core.runtime import SimulatorRuntime, run_scenario
from repro.core.stats import confidence95
from repro.scenarios import get
from repro.sweep import Axis, Sweep, run_sweep, scenario_factory
from repro.sweep.spec import spawn_seed
from repro.vector import (VectorCompileError, VectorConfig, VectorRuntime,
                          compile_experiment, has_jax, run_cells)

REPS = 5


def _repeat(exp_builder, backend: str, metric=("p50", "p95")):
    """metric means + CI over REPS seeded repetitions on one backend."""
    vals: dict[str, list] = {m: [] for m in metric}
    for rep in range(REPS):
        exp = exp_builder(spawn_seed(11, 0, rep))
        if backend == "sim":
            rt = SimulatorRuntime(exp, rep=rep)
        else:
            rt = VectorRuntime(exp, rep=rep)
        rt.run()
        s = rt.telemetry.overall()
        for m in metric:
            vals[m].append(getattr(s, m))
    return {m: confidence95(v) for m, v in vals.items()}


def _assert_ci_overlap(sim_stats, vec_stats, rel_slack: float = 0.10):
    """The fig4-style gate: per metric, the 95% CIs overlap (with a
    small relative slack so a razor-thin CI pair cannot flake)."""
    for m, (ms, cs) in sim_stats.items():
        mv, cv = vec_stats[m]
        gap = abs(ms - mv)
        allowed = (0.0 if np.isnan(cs) else cs) + \
            (0.0 if np.isnan(cv) else cv) + rel_slack * ms
        assert gap <= allowed, \
            f"{m}: sim {ms:.6g}+-{cs:.2g} vs vector {mv:.6g}+-{cv:.2g}"


# ---------------------------------------------------------------------------
# Equivalence edge cases
# ---------------------------------------------------------------------------
def test_diurnal_trough_zero_rate_gaps():
    """amplitude >= base clips the sinusoid to zero for whole
    sub-intervals: both backends must go quiet there and agree on the
    overall latency law."""
    def build(seed):
        sched = DiurnalQPS(300.0, 500.0, period=10.0)   # deep trough
        return Experiment(
            clients=[ClientConfig(i, sched, seed=0) for i in range(2)],
            servers=(ServerSpec(0), ServerSpec(1)),
            app="xapian", duration=20.0, seed=seed)
    _assert_ci_overlap(_repeat(build, "sim"), _repeat(build, "vector"))
    # the trough intervals really are dead air on the vector backend
    exp = build(7)
    v = VectorRuntime(exp, rep=0)
    v.run()
    series = v.telemetry.series()
    trough = [series[t].n for t in series
              if (t % 10) in (6, 7, 8)]       # clipped phase of each period
    peak = [series[t].n for t in series if (t % 10) in (1, 2, 3)]
    assert sum(trough) < 0.02 * sum(peak)


def test_flash_crowd_step():
    """A 3x offered-load step mid-run: the burst window's latency jump
    must match the event engine within CI overlap."""
    def build(seed):
        return get("flash-crowd", seed=seed, duration=24.0).compile()
    _assert_ci_overlap(_repeat(build, "sim"), _repeat(build, "vector"))
    # the step itself is visible: burst intervals are markedly slower
    v = VectorRuntime(build(3), rep=0)
    v.run()
    series = v.telemetry.series()
    pre = np.mean([series[t].p95 for t in range(3, 7)])
    burst = np.mean([series[t].p95 for t in range(9, 13)])
    assert burst > 1.4 * pre


def test_server_failure_mid_run():
    """One of three servers dies mid-run: queued work is lost, load
    re-homes, and the post-failure latency regime matches the sim."""
    def build(seed):
        return get("server-failure", seed=seed, duration=30.0).compile()
    _assert_ci_overlap(_repeat(build, "sim"), _repeat(build, "vector"))
    v = VectorRuntime(build(5), rep=0)
    v.run()
    series = v.telemetry.series()
    calm = np.mean([series[t].p95 for t in range(3, 9)])
    degraded = np.mean([series[t].p95 for t in range(11, 19)])
    assert degraded > 1.3 * calm
    # failed server's gauges go dark after the failure instant
    fail_ivl = 12
    frames = v.telemetry.frames()
    assert frames[fail_ivl + 2].util[2] == 0.0


def test_batched_service_equivalence():
    """Continuous-batching cells: the roofline step law per slot must
    reproduce the event engine's batched latency scale."""
    def build(seed):
        return get("batched-serving", seed=seed, duration=15.0).compile()
    _assert_ci_overlap(_repeat(build, "sim"), _repeat(build, "vector"),
                       rel_slack=0.20)


def test_legacy_mode_rejected():
    exp = Experiment(clients=(ClientConfig(0, ConstantQPS(10.0)),),
                     legacy_mode=True, duration=1.0)
    with pytest.raises(VectorCompileError):
        compile_experiment(exp)


def test_hedge_surfaced_as_unsupported():
    exp = Experiment(clients=(ClientConfig(0, ConstantQPS(50.0)),),
                     duration=2.0, hedge_delay=0.02)
    rt = VectorRuntime(exp)
    assert any(i.kind == "set_hedge" for i in rt.unsupported)


# ---------------------------------------------------------------------------
# Seeded determinism
# ---------------------------------------------------------------------------
def _grid():
    progs, seeds = [], []
    for pi, qps in enumerate((300.0, 900.0)):
        exp = get("steady", seed=1, duration=6.0, qps=qps).compile()
        prog = compile_experiment(exp)
        for rep in range(3):
            progs.append(prog)
            seeds.append((spawn_seed(1, pi, rep), rep))
    return progs, seeds


def _fingerprint(results):
    return [(r.n, r.mean, r.p50, r.p95, r.p99, r.dropped,
             r.samples.tobytes()) for r in results]


def test_bit_identical_across_jit_and_nojit():
    if not has_jax():
        pytest.skip("jax not importable")
    progs, seeds = _grid()
    a = run_cells(progs, seeds, VectorConfig(backend="jax", jit=True))
    b = run_cells(progs, seeds, VectorConfig(backend="jax", jit=False))
    assert _fingerprint(a) == _fingerprint(b)


def test_grid_cell_independent_of_grid_shape():
    """A (point, rep) cell returns bit-identical results whether it
    runs alone or inside any grid — per-cell RNG derivation."""
    progs, seeds = _grid()
    grid = run_cells(progs, seeds, VectorConfig())
    alone = run_cells([progs[4]], [seeds[4]], VectorConfig())[0]
    assert _fingerprint([grid[4]]) == _fingerprint([alone])


def test_rows_identical_across_executors_and_workers():
    """runtime=vector sweep rows cannot depend on executor choice or
    worker count (the grid path runs in-process either way)."""
    sweep = Sweep(name="vec-det", factory=scenario_factory("steady"),
                  axes=(Axis("qps", (200.0, 500.0)),),
                  fixed={"duration": 4.0}, reps=2, base_seed=3,
                  runtime="vector",
                  metrics=("n", "mean", "p50", "p95", "p99"))
    serial = run_sweep(sweep, executor="serial", progress=None)
    procs = run_sweep(sweep, executor="process", workers=2, progress=None)
    assert [r.to_dict() for r in serial.rows] == \
        [r.to_dict() for r in procs.rows]
    # and the grid path equals the per-task path bit-for-bit
    from repro.sweep.executor import run_task
    single = run_task(sweep, 1, {"qps": 500.0, "duration": 4.0}, 1)
    match = [r for r in serial.rows
             if r.index == 1 and r.rep == 1][0]
    assert single.to_dict() == match.to_dict()


def test_scenario_cli_vector_backend(capsys):
    from repro.scenarios.__main__ import main
    assert main(["steady", "--backend", "vector", "--duration", "4"]) == 0
    out = capsys.readouterr().out
    assert "backend=vector" in out


def test_run_scenario_vector_entry():
    rt = run_scenario(get("steady", seed=2, duration=4.0), "vector")
    assert rt.telemetry.overall().n > 0
